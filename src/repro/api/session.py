"""``Session`` — the one lifecycle object over the whole Deal pipeline.

    cfg = DealConfig(...)                       # or DealConfig.load(path)
    with Session.build(cfg) as s:
        H = s.infer_all()                       # offline: all-node epoch
        eng = s.serve()                         # online: store + engine
        s.apply_mutations().add_edges(src, dst)
        s.refresh()
        print(s.stats())

``build`` owns every stage the launchers used to hand-wire: dataset ->
distributed CSR construction -> layer-wise sampling -> feature/param
init -> executor selection (``ExecutorSpec.build``: device checks,
dist->ref fallback, mesh creation) — and ``serve`` adds the online
half: full epoch -> versioned store (budget / eviction / onboarding)
-> recompute-on-miss wiring -> continuous-batching engine with optional
multi-tenant QoS.  Every stage draws randomness only from the config's
seeds, so two Sessions built from equal configs are bitwise-identical
worlds — which is what makes the deprecation shims in the launchers
exactly equivalent to the code they replaced.

``infer_all`` runs the canonical full-graph path (``run_model`` over
the bound executor — op-for-op the pre-API launcher computation);
``serve`` builds its store from ``DeltaReinference.full_levels`` (the
delta engine's level layout), exactly as the serving launcher always
did.
"""
from __future__ import annotations

import copy
import time
from typing import Any, Dict, Optional

import numpy as np

from repro import obs
from repro.api.config import ConfigError, DealConfig
from repro.api.registry import MODELS


class Session:
    """Build once from a validated ``DealConfig``; drive offline
    inference and/or online serving; tear down with ``close``."""

    def __init__(self, cfg: DealConfig):
        # construct via build() for eager validation; __init__ assumes a
        # valid config
        self.cfg = cfg
        self._closed = False
        self.timings: Dict[str, float] = {}
        # telemetry first: the pipeline stages below record through it.
        # When enabled it becomes the PROCESS-current telemetry for the
        # session's lifetime (close() restores the previous one); when
        # disabled the current telemetry is left alone, so tests can
        # still scope their own via obs.use().
        self.telemetry = cfg.telemetry.build()
        self._prev_telemetry = (obs.install(self.telemetry)
                                if self.telemetry is not None else None)
        # session-scoped subset-plan cache counters: stats() must report
        # THIS session's hits/misses, not every session in the process
        from repro.core.partition import install_plan_cache_counters
        self._plan_cache_counters = install_plan_cache_counters()
        self._build_pipeline()
        self._H: Optional[np.ndarray] = None
        self._engine = None
        self._endpoint = None
        self._cluster = None

    @classmethod
    def build(cls, cfg: DealConfig) -> "Session":
        """Validate eagerly (every bad field named) and assemble the
        offline pipeline.  The online half (store/engine) is built
        lazily by the first ``serve()``."""
        cfg.validate()
        return cls(cfg)

    # -- pipeline assembly ----------------------------------------------
    def _build_pipeline(self) -> None:
        import jax

        from repro.core.graph import (csr_from_edges_distributed,
                                      make_dataset, rmat_edges)
        from repro.core.sampler import sample_layer_graphs
        cfg = self.cfg
        g, m = cfg.graph, cfg.model

        with obs.span("construct.dataset") as sp:
            t0 = time.perf_counter()
            if g.dataset == "rmat":
                n = int(g.n_nodes * g.scale)
                src, dst = rmat_edges(n, int(n * g.avg_degree),
                                      seed=g.seed)
            else:
                src, dst, n = make_dataset(g.dataset, seed=g.seed,
                                           scale=g.scale)
            self.src, self.dst, self.n_nodes = src, dst, n
            if sp:
                sp.set(dataset=g.dataset, n_nodes=n, n_edges=src.size)
        self.graph, self.construct_stats = csr_from_edges_distributed(
            src, dst, n, n_workers=g.n_construct_workers)
        self.timings["construct_s"] = time.perf_counter() - t0

        t1 = time.perf_counter()
        with obs.span("sample.layer_graphs") as sp:
            self.layer_graphs = sample_layer_graphs(
                self.graph, fanout=g.fanout, n_layers=m.n_layers,
                seed=g.seed)
            if sp:
                sp.set(n_layers=m.n_layers, fanout=g.fanout)
        self.timings["sample_s"] = time.perf_counter() - t1

        with obs.span("featprep.init") as sp:
            rng = np.random.default_rng(g.seed)
            self.X = rng.standard_normal((n, m.d_feature),
                                         dtype=np.float32)
            dims = [m.d_feature] * (m.n_layers + 1)
            plugin = MODELS.get(m.name)
            self.params = plugin.init(jax.random.PRNGKey(g.seed), dims,
                                      heads=m.heads)
            if sp:
                sp.set(d_feature=m.d_feature, bytes=int(self.X.nbytes))
        with obs.span("session.executor_build",
                      {"executor": cfg.executor.name}):
            self.executor = cfg.executor.build(cfg.partition, n_nodes=n)

    # -- offline: all-node inference ------------------------------------
    def infer_all(self) -> np.ndarray:
        """One full layer-by-layer epoch for ALL nodes through the bound
        executor.  Cached; bitwise-identical to the pre-API launcher
        path (same spec interpreter, same graph bindings)."""
        self._check_open()
        if self._H is not None:
            return self._H
        from repro.core.gnn_models import model_spec
        from repro.core.ops import DenseIO, DistExecutor, run_model
        spec = model_spec(self.cfg.model.name, self.params)
        lgs = self.layer_graphs[:len(spec.layers)]
        ex = self.executor
        t0 = time.perf_counter()
        with obs.span("session.infer_all",
                      {"model": self.cfg.model.name}) as sp:
            if isinstance(ex, DistExecutor):
                need_sddmm = any(op.kind == "attn_scores"
                                 for layer in spec.layers
                                 for op in layer.ops)
                ios = ex.bind(lgs, need_sddmm=need_sddmm)
            else:
                ios = [DenseIO.from_layer_graph(lg) for lg in lgs]
            self._H = np.asarray(run_model(ex, spec, ios, self.X))
            if sp:
                sp.set(rows=int(self._H.shape[0]))
        self.timings["infer_s"] = time.perf_counter() - t0
        assert not np.isnan(self._H).any()
        return self._H

    # -- online: store + serving engine ---------------------------------
    def serve(self):
        """Stand up (once) and return the online serving engine: full
        epoch -> versioned store (budget / eviction / tail onboarding)
        -> ``EmbeddingServeEngine`` with the config's QoS schedule.

        With ``cluster.n_shards > 0`` the engine is a router-backed
        ``ClusterEngine`` instead: shard-worker processes are spawned
        (each builds the same world from this config), readiness is
        health-checked, and the returned facade routes transparently —
        same surface, same served bytes."""
        self._check_open()
        if self._engine is not None:
            return self._engine
        cfg = self.cfg
        if cfg.cluster.n_shards > 0:
            from repro.gnnserve.cluster import ClusterDeployment
            with obs.span("serve.cluster_launch") as sp:
                self._cluster = ClusterDeployment(cfg)
                if sp:
                    sp.set(n_shards=cfg.cluster.n_shards)
            # the workers paid the epoch; the deployment's ready wait
            # (spawn -> world build -> socket up) is the launch cost
            self.timings["epoch_s"] = self._cluster.ready_wait_s
            self._engine = self._cluster.engine
            return self._engine
        from repro.gnnserve import (DeltaReinference, attach_recompute,
                                    store_from_inference)
        st = cfg.store
        self.reinfer = DeltaReinference(
            [copy.deepcopy(lg) for lg in self.layer_graphs],
            cfg.model.name, self.params,
            sample_seed=cfg.refresh.sample_seed, executor=self.executor,
            local_cutover=cfg.refresh.dist_local_cutover)
        t0 = time.perf_counter()
        with obs.span("serve.epoch") as sp:
            levels = self.reinfer.full_levels(self.X)
            if sp:
                sp.set(n_levels=len(levels))
        self.timings["epoch_s"] = time.perf_counter() - t0
        store = store_from_inference(
            self.X, levels[1:], n_shards=st.n_shards,
            budget_rows=st.budget_rows or None,
            evict_policy=st.evict_policy, admission=st.admission,
            onboarding=st.onboarding)
        if st.budget_rows:
            attach_recompute(store, self.reinfer)
        return self._attach_engine(store)

    def _attach_engine(self, store):
        """Wire a ready store (+ ``self.reinfer``/``self.graph``) into
        the serving engine, health options, and the telemetry endpoint.
        ``serve()`` calls this after the full epoch; checkpoint restore
        (``gnnserve.checkpoint.restore_into_session``) calls it with a
        restored store INSTEAD of running an epoch."""
        from repro.gnnserve import EmbeddingServeEngine
        cfg = self.cfg
        q = cfg.qos
        self._engine = EmbeddingServeEngine(
            store, self.reinfer, self.graph,
            batch_slots=q.batch_slots, rows_per_step=q.rows_per_step,
            staleness_bound=q.staleness_bound,
            tenants=q.tenant_registry(), refresh_charge=q.refresh_charge,
            refresh_chunk_rows=cfg.refresh.chunk_rows)
        t = cfg.telemetry
        self._engine.health_opts = {
            "window": t.health_window,
            "error_budget": t.slo_error_budget,
            "burn_threshold": t.burn_threshold,
            "wait_slo_ms": t.wait_slo_ms,
        }
        if self.telemetry is not None and (t.http_port >= 0
                                           or t.snapshot_path):
            from repro.obs.endpoint import TelemetryEndpoint
            self._endpoint = TelemetryEndpoint(
                self, port=t.http_port, snapshot_path=t.snapshot_path,
                snapshot_every_s=t.snapshot_every_s).start()
        return self._engine

    @classmethod
    def from_checkpoint(cls, path, cfg: DealConfig) -> "Session":
        """Build a Session whose serving world comes from a
        ``gnnserve.checkpoint.save_world`` artifact instead of a fresh
        full epoch: the offline pipeline still builds from ``cfg`` (the
        checkpoint stores no params/features below level 0), then the
        checkpointed graph/layer-graphs/store swap in and the engine
        attaches without recomputing the epoch.  The restored engine
        serves bitwise the rows the dumped one served."""
        cfg.validate()
        if cfg.cluster.n_shards > 0:
            raise ConfigError(
                "cluster.n_shards: from_checkpoint restores a single-"
                "process engine; cluster workers restore their own "
                "checkpoints via the deployment's run_dir")
        session = cls(cfg)
        from repro.gnnserve.checkpoint import restore_into_session
        restore_into_session(session, path)
        return session

    @property
    def cluster(self):
        """The live ``ClusterDeployment`` (None in single-process
        mode)."""
        return self._cluster

    @property
    def engine(self):
        """The serving engine (built on first access)."""
        return self.serve()

    @property
    def endpoint(self):
        """The live telemetry endpoint, or None (configure it via
        ``telemetry.http_port`` / ``telemetry.snapshot_path``)."""
        return self._endpoint

    @property
    def store(self):
        """The engine's CURRENT embedding store (a ``full_epoch`` fold
        swaps in a rebuilt one, so never cache this reference)."""
        return self.serve().store

    def apply_mutations(self):
        """The engine's writable mutation log (``add_edges`` /
        ``remove_edges`` / ``update_features`` / ``add_nodes``)."""
        return self.serve().mutate()

    def refresh(self) -> Dict[str, Any]:
        """Drain pending mutations into the store via delta
        re-inference (incremental node onboarding included when
        ``store.onboarding == "tail"``)."""
        return self.serve().refresh()

    def full_epoch(self, n_shards: Optional[int] = None) -> Dict[str, Any]:
        """Re-partition epoch: fold any onboarded tail partitions back
        into the main 1-D partitioning."""
        return self.serve().full_epoch(n_shards)

    # -- observability / lifecycle --------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Pipeline timings + construction stats, plus the full serve/
        store/QoS counter tree once the engine exists (the legacy keys,
        unchanged), plus:

          ``plan_cache``   ``build_subset_plan_cached`` hit/miss counters
          ``metrics``      the flat UNIFIED metric view (``obs.compat``
                           naming: ``store.evictions``,
                           ``delta.frontier_rows.layer<l>``,
                           ``qos.tenant.<name>.*``, ...), with live
                           telemetry histograms merged on top when the
                           session runs with ``telemetry.enabled``.
          ``attribution``  per-tenant critical-path latency breakdowns
                           (queue_wait / pin / recompute / gather /
                           refresh_wait / sched_wait) once the engine
                           has served queries under telemetry.
          ``health``       SLO burn rates + structured alert events
                           from the serving-tier ``HealthMonitor``.
        """
        self._check_open()
        from repro.obs import compat
        out: Dict[str, Any] = {"n_nodes": self.n_nodes,
                               "n_edges": self.graph.n_edges,
                               **{f"t_{k}": v
                                  for k, v in self.timings.items()}}
        engine_stats = refresh_stats = cutover = None
        if self._cluster is not None:
            # router-merged tree: same engine/attribution/health schema
            # as the single-process branch below, plus a ``cluster``
            # subtree (per-shard statuses, restart count, router stats)
            merged = self._cluster.stats()
            out.update(merged)
            engine_stats = {
                k: v for k, v in merged.items()
                if k not in ("attribution", "health", "cluster",
                             "refresh_cutover")}
            refresh_stats = self._engine.last_refresh_stats
            cutover = merged.get("refresh_cutover")
        elif self._engine is not None:
            engine_stats = self._engine.stats()
            refresh_stats = self._engine.last_refresh_stats
            out.update(engine_stats)
            cutover = {
                "threshold": self.reinfer.local_cutover,
                "n_local": self.reinfer.n_local_cutovers,
                "n_dist": self.reinfer.n_dist_layers,
                "n_tail": self.reinfer.n_tail_routed}
            out["refresh_cutover"] = cutover
        out["plan_cache"] = dict(self._plan_cache_counters)
        out["metrics"] = compat.unified_metrics(
            engine_stats=engine_stats,
            construct_stats=self.construct_stats,
            refresh_stats=refresh_stats,
            plan_cache=out["plan_cache"],
            timings=self.timings,
            live=(self.telemetry.metrics.to_dict()
                  if self.telemetry is not None else None),
            cutover=cutover)
        if self._cluster is None:
            if (self._engine is not None
                    and self._engine.attrib is not None):
                out["attribution"] = self._engine.attrib.summary()
            if (self._engine is not None
                    and self._engine.health is not None):
                out["health"] = self._engine.health.summary()
        return out

    def dump_trace(self, path) -> Dict[str, Any]:
        """Write the session's span trace as Chrome/Perfetto trace-event
        JSON (load it at https://ui.perfetto.dev), with the metrics
        registry embedded under ``deal_metrics``.  Returns the document.
        Needs ``telemetry.enabled: true`` in the config."""
        self._check_open()
        if self.telemetry is None:
            raise ConfigError(
                "dump_trace needs telemetry enabled: set "
                "telemetry.enabled = true in the DealConfig")
        extra: Dict[str, Any] = {}
        attrib = getattr(self._engine, "attrib", None)
        health = getattr(self._engine, "health", None)
        if attrib is not None:
            extra["deal_attribution"] = attrib.summary()
            extra["deal_top_queries"] = attrib.top_paths()
        if health is not None:
            extra["deal_health"] = health.summary()
        return obs.dump_chrome_trace(
            self.telemetry.tracer, path, self.telemetry.metrics,
            process_name=f"deal.{self.cfg.model.name}",
            extra=extra or None)

    def prometheus_text(self) -> str:
        """The metrics registry in Prometheus exposition format (empty
        when telemetry is disabled)."""
        self._check_open()
        if self.telemetry is None:
            return ""
        return obs.prometheus_text(self.telemetry.metrics)

    def _check_open(self) -> None:
        if self._closed:
            raise ConfigError("session is closed")

    def close(self) -> None:
        """Release the big arrays (graph, features, store, engine) and
        hand the process-current telemetry back to whoever held it."""
        if not self._closed:
            if self._endpoint is not None:
                self._endpoint.stop()
                self._endpoint = None
            if self._cluster is not None:
                self._cluster.shutdown()
                self._cluster = None
            if self.telemetry is not None:
                obs.install(self._prev_telemetry)
            from repro.core.partition import uninstall_plan_cache_counters
            uninstall_plan_cache_counters(self._plan_cache_counters)
        self._closed = True
        self._engine = None
        for name in ("X", "graph", "layer_graphs", "reinfer", "_H",
                     "src", "dst", "params", "executor"):
            if hasattr(self, name):
                setattr(self, name, None)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
