"""``DealConfig`` — one declarative, serializable config tree for the
whole Deal pipeline (construction -> sampling -> partition -> executor ->
store/engine/QoS), with exact JSON round-trip and eager validation.

Every entry point (launchers, examples, benchmarks) is a thin client
that builds one of these and hands it to ``api.session.Session``; a
full run is reproducible from the JSON artifact alone because every
random draw in the pipeline is seeded from the config.

Design rules:

  * ``from_dict(to_dict(cfg)) == cfg`` and ``from_json(to_json(cfg)) ==
    cfg`` are EXACT (dataclass equality) — dump a config, check it in,
    and the rerun is the same run.
  * ``validate()`` checks every field eagerly and reports ALL problems
    in one error, each prefixed with its dotted field path
    (``store.evict_policy: ...``) — never just the first one.
  * names that select plugins (executor, model, evict_policy,
    admission) validate against the live registries
    (``api.registry``), so a third-party registration is immediately a
    legal config value.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

from repro.api import registry as _reg


class ConfigError(ValueError):
    """Raised by ``DealConfig.validate`` with every bad field listed."""


def _load_builtin_plugins() -> None:
    """Importing the defining modules registers the built-in plugins
    (executors in ``core.ops``, models in ``core.gnn_models``, eviction/
    admission in ``gnnserve.store``).  Local imports: config stays
    importable without pulling jax until validation time."""
    import repro.core.gnn_models   # noqa: F401
    import repro.core.ops          # noqa: F401
    import repro.gnnserve.store    # noqa: F401


# ----------------------------------------------------------------------
# the spec tree
# ----------------------------------------------------------------------

@dataclasses.dataclass
class GraphSpec:
    """Stage 1+2: dataset -> distributed CSR -> layer-wise sampling."""
    dataset: str = "ogbn-products"  # named dataset, or "rmat" (explicit)
    scale: float = 1.0              # node-count multiplier (CI smoke)
    n_nodes: int = 0                # dataset == "rmat" only
    avg_degree: int = 0             # dataset == "rmat": E = n * avg_degree
    fanout: int = 8                 # fixed fanout of the layer graphs
    seed: int = 0                   # dataset + sampling + features seed
    n_construct_workers: int = 4    # distributed CSR construction width


@dataclasses.dataclass
class ModelSpec:
    """Which registered GNN model, its depth and widths."""
    name: str = "gcn"
    n_layers: int = 3
    d_feature: int = 64
    heads: int = 1                  # attention heads (gat)


@dataclasses.dataclass
class PartitionSpec:
    """The 1-D collaborative partition geometry: ``p`` graph partitions
    x ``m`` feature partitions (the ("data", "model") mesh)."""
    p: int = 2
    m: int = 1


@dataclasses.dataclass
class ExecutorSpec:
    """Backend selection + the construction/validation logic that used
    to be copy-pasted across every launcher.

    ``fused_gather`` / ``block_table`` are first-class pallas kernel
    knobs (the fused gather+spmm path and the autotuned block-size
    table source — "default" = ``configs/tuned_blocks.json``); left at
    None they are omitted entirely, so executors that don't take them
    never see them."""
    name: str = "ref"               # a registered executor
    fallback_to_ref: bool = True    # dist on a trivial (p*m <= 1) mesh
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)
    fused_gather: Optional[bool] = None
    block_table: Optional[str] = None

    def _options(self) -> Dict[str, Any]:
        opts = dict(self.options)
        if self.fused_gather is not None:
            opts.setdefault("fused_gather", self.fused_gather)
        if self.block_table is not None:
            opts.setdefault("block_table", self.block_table)
        return opts

    def build(self, partition: Optional[PartitionSpec] = None, *,
              n_nodes: Optional[int] = None):
        """Resolve this spec into an executor INSTANCE — the one place
        that owns the device-count check, the dist -> ref fallback on a
        trivial mesh, the dist geometry checks, and mesh creation.
        Raises ``ConfigError`` naming the offending field; unknown
        executor names list every registered one."""
        _load_builtin_plugins()
        if self.name not in _reg.EXECUTORS:
            raise ConfigError(
                f"executor.name: unknown executor {self.name!r}; "
                f"registered: {', '.join(_reg.EXECUTORS.names())}")
        from repro.core.ops import get_executor
        if self.name != "dist":
            return get_executor(self.name, **self._options())

        part = partition or PartitionSpec()
        p, m = part.p, part.m
        if p * m <= 1 and self.fallback_to_ref:
            return get_executor("ref")      # no mesh to run on
        import jax
        if len(jax.devices()) < p * m:
            raise ConfigError(
                f"executor.name: \"dist\" needs p*m = {p * m} devices "
                f"(found {len(jax.devices())}); run under XLA_FLAGS="
                f"--xla_force_host_platform_device_count={p * m}")
        if n_nodes is not None and n_nodes % p != 0:
            raise ConfigError(
                f"partition.p: {p} must divide the node count {n_nodes}")
        if m & (m - 1) != 0:
            raise ConfigError(
                f"partition.m: {m} must be a power of two "
                "(row-subset pad buckets)")
        from repro.launch.mesh import make_host_mesh
        return get_executor("dist", mesh=make_host_mesh(p, m),
                            **self.options)


@dataclasses.dataclass
class StoreSpec:
    """The versioned embedding store: sharding, memory budget, and
    incremental node onboarding."""
    n_shards: int = 4
    budget_rows: int = 0            # 0 = unbudgeted; else rows per level
    evict_policy: str = "heat"      # a registered eviction policy
    admission: str = "probation"    # a registered admission policy
    onboarding: str = "none"        # "tail": node adds append a tail
    #                                 partition served via delta refresh


@dataclasses.dataclass
class QoSSpec:
    """Serving and freshness: the engine's batching geometry plus the
    optional multi-tenant schedule (empty ``tenants`` = single implicit
    tenant at ``staleness_bound``)."""
    staleness_bound: int = 64
    batch_slots: int = 4
    rows_per_step: int = 256
    refresh_charge: float = 1.0
    tenants: Tuple[Dict[str, Any], ...] = ()

    def tenant_registry(self):
        """The runtime ``gnnserve.qos.TenantRegistry`` (None when no
        tenants are declared)."""
        if not self.tenants:
            return None
        from repro.gnnserve.qos import TenantRegistry, TenantSpec
        return TenantRegistry([TenantSpec(**dict(t)) for t in self.tenants])


@dataclasses.dataclass
class RefreshSpec:
    """Delta re-inference knobs: the content-addressed resample seed
    and the dist frontier-size cutover — a refresh layer whose gathered
    universe is below ``dist_local_cutover`` rows runs on a local
    executor instead of the mesh (0 = never cut over; routing decisions
    surface in ``Session.stats()`` and the ``refresh.route`` trace
    spans).

    ``chunk_rows`` makes refresh preemptible under QoS: the delta
    frontier splits into chunks of this many rows and the scheduler
    interleaves them with tenant gathers, one chunk per serve step
    (0 = the whole refresh runs inline inside one step).  Chunking is
    bitwise-invariant — any value serves the exact bits of the inline
    refresh."""
    sample_seed: int = 0
    dist_local_cutover: int = 0
    chunk_rows: int = 0


@dataclasses.dataclass
class TelemetrySpec:
    """The ``repro.obs`` layer: per-Session span tracing + unified
    metrics.  Disabled by default — the no-op mode's overhead at every
    instrumentation site is a single attribute check, so leaving the
    hooks compiled in costs nothing measurable.

    The serving-tier health extras (all gated on ``enabled``):
    ``http_port`` starts a stdlib Prometheus/JSON scrape endpoint on
    ``Session.serve()`` (-1 = off, 0 = an ephemeral port published as
    ``session.endpoint.port``); ``snapshot_path`` adds a periodic JSON
    stats-snapshot writer; the ``health_*`` / ``slo_*`` fields tune the
    engine's burn-rate monitor (rolling window length, SLO error
    budget, the burn rate at which an alert fires, and an optional
    wall-clock queue-wait SLO in ms applied to every tenant — 0
    disables the wait detector)."""
    enabled: bool = False
    capacity: int = 65536           # span ring-buffer size (oldest drop)
    clock: str = "monotonic"        # "monotonic" | "fake" (deterministic
    #                                 auto-advancing test clock)
    http_port: int = -1             # -1 = no endpoint, 0 = ephemeral
    snapshot_path: str = ""         # "" = no periodic JSON snapshots
    snapshot_every_s: float = 1.0
    health_window: int = 128        # rolling-window observations
    slo_error_budget: float = 0.01  # allowed violating fraction
    burn_threshold: float = 4.0     # alert at burn >= threshold
    wait_slo_ms: float = 0.0        # 0 = wait-burn detector off

    def build(self):
        """The runtime ``obs.Telemetry`` (None when disabled — the
        session then leaves the process-current telemetry alone)."""
        if not self.enabled:
            return None
        from repro import obs
        clock = obs.FakeClock() if self.clock == "fake" else None
        return obs.Telemetry(enabled=True, clock=clock,
                             capacity=self.capacity)


@dataclasses.dataclass
class ClusterSpec:
    """Multi-process serving tier (``gnnserve.cluster``): shard-worker
    processes along the existing 1-D partitioning behind an RPC router.

    ``n_shards = 0`` (the default) keeps single-process serving;
    ``n_shards > 0`` makes ``Session.serve()`` spawn that many
    ``ShardWorker`` processes, health-check their readiness, and return
    a router-backed engine with the same surface — existing clients
    don't change.  ``ports`` pins worker ports (empty = ephemeral,
    published via per-shard port files in ``run_dir``); ``http_port``
    starts the router's aggregated ``/healthz`` + ``/stats`` endpoint.
    ``run_dir`` holds the per-shard WAL segments and world checkpoints
    that make kill/restart/replay bitwise ("" = a fresh temp dir, so
    restarts within one deployment replay but nothing persists across
    deployments).  ``overrides`` tunes individual shards — entries are
    dicts with a ``shard`` index plus any of ``budget_rows`` /
    ``evict_policy`` / ``admission`` (store) or ``staleness_bound`` /
    ``batch_slots`` / ``rows_per_step`` (engine geometry); none of
    these change served bytes (residency and batching are
    bitwise-invariant), only footprint and scheduling."""
    n_shards: int = 0               # 0 = single-process serving
    host: str = "127.0.0.1"
    ports: Tuple[int, ...] = ()     # empty = ephemeral ports
    http_port: int = -1             # router endpoint; -1 off, 0 ephemeral
    run_dir: str = ""               # "" = fresh temp dir per deployment
    ready_timeout_s: float = 120.0  # worker world build/restore budget
    hang_timeout_s: float = 60.0    # heartbeat staleness => wedged
    overrides: Tuple[Dict[str, Any], ...] = ()


_OVERRIDE_FIELDS = ("shard", "budget_rows", "evict_policy", "admission",
                    "staleness_bound", "batch_slots", "rows_per_step")

_TENANT_FIELDS = ("name", "priority", "slot_quota", "rate", "staleness_slo")


def tenants_from_string(text: str) -> Tuple[Dict[str, Any], ...]:
    """The CLI ``--tenants`` format ("name:priority:quota:rate:slo,...")
    as config-tree tenant dicts — delegates to the canonical parser
    (``gnnserve.qos.parse_tenants``, including its TenantSpec value
    checks) and re-raises every problem as ``ConfigError``."""
    from repro.gnnserve.qos import parse_tenants
    try:
        reg = parse_tenants(text)
    except (ValueError, AssertionError) as exc:
        raise ConfigError(f"qos.tenants: {exc}") from None
    return tuple({"name": t.name, "priority": t.priority,
                  "slot_quota": t.slot_quota, "rate": t.rate,
                  "staleness_slo": t.staleness_slo} for t in reg)


# ----------------------------------------------------------------------
# the root
# ----------------------------------------------------------------------

_SECTIONS = {"graph": GraphSpec, "model": ModelSpec,
             "partition": PartitionSpec, "executor": ExecutorSpec,
             "store": StoreSpec, "qos": QoSSpec, "refresh": RefreshSpec,
             "telemetry": TelemetrySpec, "cluster": ClusterSpec}


@dataclasses.dataclass
class DealConfig:
    graph: GraphSpec = dataclasses.field(default_factory=GraphSpec)
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    partition: PartitionSpec = dataclasses.field(
        default_factory=PartitionSpec)
    executor: ExecutorSpec = dataclasses.field(
        default_factory=ExecutorSpec)
    store: StoreSpec = dataclasses.field(default_factory=StoreSpec)
    qos: QoSSpec = dataclasses.field(default_factory=QoSSpec)
    refresh: RefreshSpec = dataclasses.field(default_factory=RefreshSpec)
    telemetry: TelemetrySpec = dataclasses.field(
        default_factory=TelemetrySpec)
    cluster: ClusterSpec = dataclasses.field(default_factory=ClusterSpec)

    # -- serialization --------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        # JSON has no tuples; normalize here so to_dict output and a
        # json.loads round-trip are the same object shapes
        d["qos"]["tenants"] = [dict(t) for t in d["qos"]["tenants"]]
        d["cluster"]["ports"] = list(d["cluster"]["ports"])
        d["cluster"]["overrides"] = [dict(o)
                                     for o in d["cluster"]["overrides"]]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DealConfig":
        """Strict: an unknown section or field is an error that names
        it — a typo must not silently fall back to a default."""
        if not isinstance(d, dict):
            raise ConfigError(f"config root must be a dict, got {type(d)}")
        errors: List[str] = []
        kw = {}
        for key, sub in d.items():
            if key not in _SECTIONS:
                errors.append(f"{key}: unknown config section; valid: "
                              + ", ".join(_SECTIONS))
                continue
            if not isinstance(sub, dict):
                errors.append(f"{key}: must be a dict of fields, got "
                              f"{type(sub).__name__}")
                continue
            spec_cls = _SECTIONS[key]
            known = {f.name for f in dataclasses.fields(spec_cls)}
            bad = [f"{key}.{k}: unknown field; valid: " + ", ".join(known)
                   for k in sub if k not in known]
            if bad:
                errors.extend(bad)
                continue
            kw[key] = spec_cls(**sub)
        if errors:
            raise ConfigError("invalid DealConfig:\n  - "
                              + "\n  - ".join(errors))
        cfg = cls(**kw)
        if isinstance(cfg.qos.tenants, (list, tuple)):
            # normalize JSON lists to tuples for exact dataclass
            # equality; non-dict entries pass through for validate()
            # to name
            cfg.qos.tenants = tuple(dict(t) if isinstance(t, dict) else t
                                    for t in cfg.qos.tenants)
        if isinstance(cfg.cluster.ports, (list, tuple)):
            cfg.cluster.ports = tuple(cfg.cluster.ports)
        if isinstance(cfg.cluster.overrides, (list, tuple)):
            cfg.cluster.overrides = tuple(
                dict(o) if isinstance(o, dict) else o
                for o in cfg.cluster.overrides)
        return cfg

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "DealConfig":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path) -> "DealConfig":
        with open(path) as f:
            return cls.from_json(f.read())

    def dump(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # -- validation -----------------------------------------------------
    def _type_errors(self) -> List[str]:
        """Per-field type check against each spec's defaults — runs (and
        raises) BEFORE the value checks, which assume sane types.  bool
        is not an int here; int is an acceptable float."""
        errs = []
        for sec in _SECTIONS:
            spec = getattr(self, sec)
            if not isinstance(spec, _SECTIONS[sec]):
                errs.append(f"{sec}: must be a {_SECTIONS[sec].__name__}")
                continue
            defaults = _SECTIONS[sec]()
            for f in dataclasses.fields(spec):
                v = getattr(spec, f.name)
                d = getattr(defaults, f.name)
                if isinstance(d, bool):
                    ok = isinstance(v, bool)
                elif isinstance(d, int):
                    ok = isinstance(v, int) and not isinstance(v, bool)
                elif isinstance(d, float):
                    ok = (isinstance(v, (int, float))
                          and not isinstance(v, bool))
                elif isinstance(d, str):
                    ok = isinstance(v, str)
                elif isinstance(d, dict):
                    ok = isinstance(v, dict)
                elif isinstance(d, tuple):
                    ok = isinstance(v, (list, tuple))
                else:
                    ok = True
                if not ok:
                    errs.append(f"{sec}.{f.name}: expected "
                                f"{type(d).__name__}, got "
                                f"{type(v).__name__} ({v!r})")
        return errs

    def validate(self) -> "DealConfig":
        """Eagerly check every field; raise one ``ConfigError`` listing
        EVERY bad field by dotted path.  Returns self (chainable)."""
        _load_builtin_plugins()
        from repro.core.graph import dataset_names
        type_errors = self._type_errors()
        if type_errors:
            raise ConfigError("invalid DealConfig:\n  - "
                              + "\n  - ".join(type_errors))
        e: List[str] = []
        g, m, pt, ex = self.graph, self.model, self.partition, self.executor
        st, q, r = self.store, self.qos, self.refresh

        known = dataset_names() + ["rmat"]
        if g.dataset not in known:
            e.append(f"graph.dataset: unknown dataset {g.dataset!r}; "
                     f"valid: {', '.join(known)}")
        if g.dataset == "rmat":
            if g.n_nodes <= 0:
                e.append("graph.n_nodes: must be > 0 for dataset \"rmat\"")
            if g.avg_degree <= 0:
                e.append("graph.avg_degree: must be > 0 for dataset "
                         "\"rmat\"")
        if g.scale <= 0:
            e.append(f"graph.scale: must be > 0, got {g.scale}")
        if g.fanout < 1:
            e.append(f"graph.fanout: must be >= 1, got {g.fanout}")
        if g.n_construct_workers < 1:
            e.append("graph.n_construct_workers: must be >= 1, got "
                     f"{g.n_construct_workers}")

        if m.name not in _reg.MODELS:
            e.append(f"model.name: unknown model {m.name!r}; registered: "
                     + ", ".join(_reg.MODELS.names()))
        if m.n_layers < 1:
            e.append(f"model.n_layers: must be >= 1, got {m.n_layers}")
        if m.d_feature < 1:
            e.append(f"model.d_feature: must be >= 1, got {m.d_feature}")
        if m.heads < 1:
            e.append(f"model.heads: must be >= 1, got {m.heads}")
        elif m.d_feature % m.heads != 0:
            e.append(f"model.heads: {m.heads} must divide d_feature "
                     f"{m.d_feature}")

        if pt.p < 1:
            e.append(f"partition.p: must be >= 1, got {pt.p}")
        if pt.m < 1:
            e.append(f"partition.m: must be >= 1, got {pt.m}")

        if ex.name not in _reg.EXECUTORS:
            e.append(f"executor.name: unknown executor {ex.name!r}; "
                     f"registered: {', '.join(_reg.EXECUTORS.names())}")
        if not isinstance(ex.options, dict):
            e.append("executor.options: must be a dict, got "
                     f"{type(ex.options).__name__}")
        if ex.fused_gather is not None and not isinstance(
                ex.fused_gather, bool):
            e.append("executor.fused_gather: must be a bool or None, "
                     f"got {ex.fused_gather!r}")
        if ex.block_table is not None and not isinstance(
                ex.block_table, str):
            e.append("executor.block_table: must be a str or None, "
                     f"got {ex.block_table!r}")

        if st.n_shards < 1:
            e.append(f"store.n_shards: must be >= 1, got {st.n_shards}")
        if st.budget_rows < 0:
            e.append(f"store.budget_rows: must be >= 0 (0 = unbudgeted), "
                     f"got {st.budget_rows}")
        if st.evict_policy not in _reg.EVICT_POLICIES:
            e.append(f"store.evict_policy: unknown policy "
                     f"{st.evict_policy!r}; registered: "
                     + ", ".join(_reg.EVICT_POLICIES.names()))
        if st.admission not in _reg.ADMISSIONS:
            e.append(f"store.admission: unknown policy {st.admission!r}; "
                     f"registered: {', '.join(_reg.ADMISSIONS.names())}")
        if st.onboarding not in ("none", "tail"):
            e.append(f"store.onboarding: must be \"none\" or \"tail\", "
                     f"got {st.onboarding!r}")

        if q.staleness_bound < 1:
            e.append(f"qos.staleness_bound: must be >= 1, got "
                     f"{q.staleness_bound}")
        if q.batch_slots < 1:
            e.append(f"qos.batch_slots: must be >= 1, got {q.batch_slots}")
        if q.rows_per_step < 1:
            e.append(f"qos.rows_per_step: must be >= 1, got "
                     f"{q.rows_per_step}")
        seen = set()
        _num = (int, float)
        tenant_types = {"name": (str, "str"), "priority": (_num, "number"),
                        "slot_quota": (int, "int"), "rate": (_num, "number"),
                        "staleness_slo": (int, "int")}
        for i, t in enumerate(q.tenants):
            path = f"qos.tenants[{i}]"
            if not isinstance(t, dict):
                e.append(f"{path}: must be a dict with fields "
                         + ", ".join(_TENANT_FIELDS))
                continue
            bad_types = False
            for k, v in t.items():
                if k not in _TENANT_FIELDS:
                    e.append(f"{path}.{k}: unknown tenant field; valid: "
                             + ", ".join(_TENANT_FIELDS))
                elif (not isinstance(v, tenant_types[k][0])
                      or isinstance(v, bool)):
                    e.append(f"{path}.{k}: expected {tenant_types[k][1]},"
                             f" got {type(v).__name__} ({v!r})")
                    bad_types = True
            if bad_types:
                continue            # value checks assume sane types
            name = t.get("name", "")
            if not name:
                e.append(f"{path}.name: required and non-empty")
            elif name in seen:
                e.append(f"{path}.name: duplicate tenant {name!r}")
            seen.add(name)
            if t.get("priority", 1.0) <= 0:
                e.append(f"{path}.priority: must be > 0, got "
                         f"{t.get('priority')}")
            if t.get("slot_quota", 1) < 0:
                e.append(f"{path}.slot_quota: must be >= 0, got "
                         f"{t.get('slot_quota')}")
            if t.get("staleness_slo", 64) < 1:
                e.append(f"{path}.staleness_slo: must be >= 1, got "
                         f"{t.get('staleness_slo')}")
        # (refresh.sample_seed's type is covered by the type pass above)
        if r.dist_local_cutover < 0:
            e.append(f"refresh.dist_local_cutover: must be >= 0 "
                     f"(0 = never cut over), got {r.dist_local_cutover}")
        if r.chunk_rows < 0:
            e.append(f"refresh.chunk_rows: must be >= 0 "
                     f"(0 = inline refresh), got {r.chunk_rows}")
        tel = self.telemetry
        if tel.capacity < 1:
            e.append(f"telemetry.capacity: must be >= 1, got "
                     f"{tel.capacity}")
        if tel.clock not in ("monotonic", "fake"):
            e.append(f"telemetry.clock: must be \"monotonic\" or "
                     f"\"fake\", got {tel.clock!r}")
        if not -1 <= tel.http_port <= 65535:
            e.append(f"telemetry.http_port: must be -1 (off), 0 "
                     f"(ephemeral) or a valid port, got {tel.http_port}")
        if tel.snapshot_every_s <= 0:
            e.append(f"telemetry.snapshot_every_s: must be > 0, got "
                     f"{tel.snapshot_every_s}")
        if tel.health_window < 2:
            e.append(f"telemetry.health_window: must be >= 2, got "
                     f"{tel.health_window}")
        if not 0 < tel.slo_error_budget <= 1:
            e.append(f"telemetry.slo_error_budget: must be in (0, 1], "
                     f"got {tel.slo_error_budget}")
        if tel.burn_threshold <= 0:
            e.append(f"telemetry.burn_threshold: must be > 0, got "
                     f"{tel.burn_threshold}")
        if tel.wait_slo_ms < 0:
            e.append(f"telemetry.wait_slo_ms: must be >= 0 (0 = wait "
                     f"detector off), got {tel.wait_slo_ms}")

        cl = self.cluster
        if cl.n_shards < 0:
            e.append(f"cluster.n_shards: must be >= 0 (0 = single-"
                     f"process serving), got {cl.n_shards}")
        if cl.ports and len(cl.ports) != cl.n_shards:
            e.append(f"cluster.ports: need one port per shard "
                     f"({cl.n_shards}) or none (ephemeral), got "
                     f"{len(cl.ports)}")
        for i, p in enumerate(cl.ports):
            if not (isinstance(p, int) and not isinstance(p, bool)
                    and 1 <= p <= 65535):
                e.append(f"cluster.ports[{i}]: must be a valid port, "
                         f"got {p!r}")
        if not -1 <= cl.http_port <= 65535:
            e.append(f"cluster.http_port: must be -1 (off), 0 "
                     f"(ephemeral) or a valid port, got {cl.http_port}")
        if cl.ready_timeout_s <= 0:
            e.append(f"cluster.ready_timeout_s: must be > 0, got "
                     f"{cl.ready_timeout_s}")
        if cl.hang_timeout_s <= 0:
            e.append(f"cluster.hang_timeout_s: must be > 0, got "
                     f"{cl.hang_timeout_s}")
        for i, ov in enumerate(cl.overrides):
            path = f"cluster.overrides[{i}]"
            if not isinstance(ov, dict):
                e.append(f"{path}: must be a dict with fields "
                         + ", ".join(_OVERRIDE_FIELDS))
                continue
            for k in ov:
                if k not in _OVERRIDE_FIELDS:
                    e.append(f"{path}.{k}: unknown override field; "
                             f"valid: " + ", ".join(_OVERRIDE_FIELDS))
            shard = ov.get("shard")
            if not (isinstance(shard, int) and not isinstance(shard, bool)
                    and 0 <= shard < max(cl.n_shards, 1)):
                e.append(f"{path}.shard: must be a shard index in "
                         f"[0, {cl.n_shards}), got {shard!r}")
            ev = ov.get("evict_policy")
            if ev is not None and ev not in _reg.EVICT_POLICIES:
                e.append(f"{path}.evict_policy: unknown policy {ev!r}; "
                         f"registered: "
                         + ", ".join(_reg.EVICT_POLICIES.names()))
            adm = ov.get("admission")
            if adm is not None and adm not in _reg.ADMISSIONS:
                e.append(f"{path}.admission: unknown policy {adm!r}; "
                         f"registered: "
                         + ", ".join(_reg.ADMISSIONS.names()))
            for k in ("budget_rows",):
                if k in ov and (not isinstance(ov[k], int)
                                or isinstance(ov[k], bool)
                                or ov[k] < 0):
                    e.append(f"{path}.{k}: must be an int >= 0, got "
                             f"{ov[k]!r}")
            for k in ("staleness_bound", "batch_slots", "rows_per_step"):
                if k in ov and (not isinstance(ov[k], int)
                                or isinstance(ov[k], bool)
                                or ov[k] < 1):
                    e.append(f"{path}.{k}: must be an int >= 1, got "
                             f"{ov[k]!r}")
        if cl.n_shards > 0 and ex.name == "dist":
            e.append("cluster.n_shards: the dist executor inside "
                     "cluster workers needs per-process device flags; "
                     "run dist single-process or workers with "
                     "ref/pallas")

        if e:
            raise ConfigError("invalid DealConfig:\n  - "
                              + "\n  - ".join(e))
        return self
