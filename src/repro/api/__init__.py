"""repro.api — the public, declarative API over the whole Deal pipeline.

One config tree, one lifecycle object, four plugin registries:

  ``DealConfig``   typed + serializable (exact JSON round-trip) +
                   eagerly validated (every bad field named);
                   sub-specs: GraphSpec, ModelSpec, PartitionSpec,
                   ExecutorSpec, StoreSpec, QoSSpec, RefreshSpec,
                   TelemetrySpec, ClusterSpec.
  ``Session``      ``Session.build(cfg)`` -> ``infer_all()`` /
                   ``serve()`` / ``apply_mutations()`` / ``refresh()``
                   / ``full_epoch()`` / ``stats()`` / ``close()``.
  registries       ``register_executor`` / ``register_model`` /
                   ``register_evict_policy`` / ``register_admission``
                   make ref/pallas/dist, gcn/sage/gat, heat/lru and
                   probation/full registered DEFAULTS — third-party
                   scenarios plug in without touching core.

Launchers, examples, and benchmarks are thin clients of this module:
argparse -> ``DealConfig`` -> ``Session`` (see ``launch/infer_gnn.py``,
``launch/serve_embeddings.py``), with ``--config``/``--dump-config``
making every run reproducible from one JSON artifact.
"""
from repro.api.config import (ClusterSpec, ConfigError, DealConfig,
                              ExecutorSpec, GraphSpec, ModelSpec,
                              PartitionSpec, QoSSpec, RefreshSpec,
                              StoreSpec, TelemetrySpec,
                              tenants_from_string)
from repro.api.registry import (ADMISSIONS, EVICT_POLICIES, EXECUTORS,
                                MODELS, Registry, register_admission,
                                register_evict_policy, register_executor,
                                register_model)
from repro.api.session import Session

__all__ = ["ClusterSpec", "ConfigError", "DealConfig", "ExecutorSpec",
           "GraphSpec",
           "ModelSpec", "PartitionSpec", "QoSSpec", "RefreshSpec",
           "StoreSpec", "TelemetrySpec", "tenants_from_string",
           "ADMISSIONS", "EVICT_POLICIES", "EXECUTORS", "MODELS",
           "Registry", "register_admission", "register_evict_policy",
           "register_executor", "register_model",
           "Session"]
