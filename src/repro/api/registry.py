"""String-keyed plugin registries — the extension seam of the public API.

Every pluggable axis of the pipeline resolves through one of these
registries instead of an if/elif chain buried in core code:

  ``EXECUTORS``       backend factories ("ref" / "pallas" / "dist", ...)
  ``MODELS``          GNN model plugins ("gcn" / "sage" / "gat", ...)
  ``EVICT_POLICIES``  store victim selection ("heat" / "lru", ...)
  ``ADMISSIONS``      store heat-admission policies ("probation" / "full")

The built-in entries register themselves where they are DEFINED
(``core.ops``, ``core.gnn_models``, ``gnnserve.store``), so this module
stays a leaf with no repro imports — anything may depend on it without
cycles.  Third-party scenarios extend the pipeline by registering a new
name and putting it in a ``DealConfig``; core code never changes:

    from repro.api import register_evict_policy

    @register_evict_policy("fifo")
    def fifo(store, level):
        return lambda shard: shard          # evict lowest shard id first

Lookups of unknown names raise ``KeyError`` with every registered name
in the message, so a typo is diagnosable from the error alone.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional


class Registry:
    """A named string -> object table with decorator registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, Any] = {}

    def register(self, name: str, obj: Optional[Any] = None,
                 *, overwrite: bool = False):
        """``register("name", obj)`` or ``@register("name")`` decorator.
        Re-registering an existing name requires ``overwrite=True`` —
        a silent replacement of a built-in is almost always a bug."""
        def _put(o):
            if not overwrite and name in self._items \
                    and self._items[name] is not o:
                raise ValueError(
                    f"{self.kind} {name!r} is already registered "
                    f"(pass overwrite=True to replace it)")
            self._items[name] = o
            return o
        if obj is None:
            return _put                     # decorator form
        return _put(obj)

    def unregister(self, name: str) -> None:
        self._items.pop(name, None)

    def get(self, name: str) -> Any:
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{', '.join(self.names()) or '(none)'}") from None

    def names(self) -> List[str]:
        return sorted(self._items)

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def __iter__(self):
        return iter(sorted(self._items))

    def __len__(self) -> int:
        return len(self._items)


EXECUTORS = Registry("executor")
MODELS = Registry("model")
EVICT_POLICIES = Registry("evict_policy")
ADMISSIONS = Registry("admission")


def register_executor(name: str, factory: Optional[Callable] = None, **kw):
    """Register an executor factory ``factory(mesh=None, **options) ->
    executor instance`` (``mesh`` is only meaningful for distributed
    backends; single-host factories must accept and ignore it)."""
    return EXECUTORS.register(name, factory, **kw)


def register_model(name: str, plugin: Optional[Any] = None, **kw):
    """Register a model plugin: an object with ``init(key, dims, heads)
    -> params`` and ``spec(params) -> core.gnn_models.ModelSpec`` (the
    declarative layer program every executor interprets)."""
    return MODELS.register(name, plugin, **kw)


def register_evict_policy(name: str, policy: Optional[Callable] = None,
                          **kw):
    """Register a store eviction policy ``policy(store, level) ->
    key_fn(shard) -> sortable`` — the shard minimizing the key is
    evicted first when the level is over budget."""
    return EVICT_POLICIES.register(name, policy, **kw)


def register_admission(name: str, policy: Optional[Callable] = None, **kw):
    """Register a store admission policy ``policy(local_ids, admitted)
    -> heat weight`` deciding how much heat a gather contributes to a
    shard (``admitted`` is the recompute-admitted subset, or None)."""
    return ADMISSIONS.register(name, policy, **kw)
