"""Sharing-ratio analytics (Table 5, Fig 5, Observation #2).

Cost model: one unit of work = one node's per-layer computation (GEMM row +
aggregation).  For a k-layer model over targets T:
  no-sharing cost   C_max  = sum_t sum_l |frontier_l(t)|   (every ego alone)
  DEAL cost         C_min  = k * N                          (each row once)
  batched (DGI)     C(B)   = sum_batches sum_l |frontier_l(batch)|
  P3-style          outermost-hop dedup only
  SALIENT++-style   cache of the hottest nodes absorbs repeated rows

sharing_ratio = (C_max - C) / (C_max - C_min)  — DEAL == 1.0 by design.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.sampler import LayerGraph


def _frontiers(layer_graphs: List[LayerGraph], targets: np.ndarray
               ) -> List[np.ndarray]:
    """needed[l] = nodes whose layer-l INPUT must be computed (l=0..k-1
    consume, plus the final target set)."""
    L = len(layer_graphs)
    needed = [None] * (L + 1)
    needed[L] = np.unique(targets)
    for l in range(L - 1, -1, -1):
        lg = layer_graphs[l]
        up = needed[l + 1]
        nbrs = lg.nbr[up][lg.mask[up]]
        needed[l] = np.unique(np.concatenate([up, nbrs]))
    return needed


def batched_cost(layer_graphs: List[LayerGraph], batch_size: int) -> int:
    N = layer_graphs[0].n_nodes
    total = 0
    for b0 in range(0, N, batch_size):
        t = np.arange(b0, min(b0 + batch_size, N))
        needed = _frontiers(layer_graphs, t)
        total += sum(f.size for f in needed[:-1])
    return total


def nosharing_cost(layer_graphs: List[LayerGraph],
                   sample_targets: int = 256, seed: int = 0) -> float:
    """Estimated from a target sample (exact is O(N * ego size))."""
    N = layer_graphs[0].n_nodes
    rng = np.random.default_rng(seed)
    t = rng.choice(N, size=min(sample_targets, N), replace=False)
    per_target = [sum(f.size for f in _frontiers(layer_graphs,
                                                 np.array([v]))[:-1])
                  for v in t]
    return float(np.mean(per_target)) * N


def p3_cost(layer_graphs: List[LayerGraph], batch_size: int,
            sample_targets: int = 256, seed: int = 0) -> float:
    """P3 shares only the OUTERMOST hop within a batch; inner hops are
    computed per ego network (hybrid parallelism redundancy) [41]."""
    N = layer_graphs[0].n_nodes
    L = len(layer_graphs)
    rng = np.random.default_rng(seed)
    t = rng.choice(N, size=min(sample_targets, N), replace=False)
    inner = [sum(f.size for f in _frontiers(layer_graphs,
                                            np.array([v]))[1:-1])
             for v in t]
    inner_total = float(np.mean(inner)) * N
    outer_total = 0.0
    for b0 in range(0, N, batch_size):
        tb = np.arange(b0, min(b0 + batch_size, N))
        outer_total += _frontiers(layer_graphs, tb)[0].size
    return inner_total + outer_total


def salientpp_cost(layer_graphs: List[LayerGraph], batch_size: int,
                   cache_fraction: float = 0.1) -> float:
    """SALIENT++-style: per-batch ego compute, but rows of the
    cache_fraction hottest nodes are free after first use [47]."""
    N = layer_graphs[0].n_nodes
    # hotness = in-degree under the sampled layer graphs
    counts = np.zeros(N, np.int64)
    for lg in layer_graphs:
        np.add.at(counts, lg.nbr[lg.mask], 1)
    hot = set(np.argsort(-counts)[:int(N * cache_fraction)].tolist())
    total = 0.0
    seen_hot = set()
    for b0 in range(0, N, batch_size):
        t = np.arange(b0, min(b0 + batch_size, N))
        needed = _frontiers(layer_graphs, t)
        for f in needed[:-1]:
            for v in f:
                if v in hot:
                    if v in seen_hot:
                        continue
                    seen_hot.add(v)
                total += 1
    return total


def sharing_table(layer_graphs: List[LayerGraph], batch_size: int
                  ) -> Dict[str, float]:
    N = layer_graphs[0].n_nodes
    L = len(layer_graphs)
    c_min = float(L * N)
    c_max = nosharing_cost(layer_graphs)
    span = max(c_max - c_min, 1.0)

    def ratio(c):
        return float(np.clip((c_max - c) / span, 0.0, 1.0))

    return {
        "deal": 1.0,
        "dgi_batched": ratio(batched_cost(layer_graphs, batch_size)),
        "p3": ratio(p3_cost(layer_graphs, batch_size)),
        "salientpp": ratio(salientpp_cost(layer_graphs, batch_size)),
        "c_max": c_max, "c_min": c_min,
    }


def sharing_vs_batch_size(layer_graphs: List[LayerGraph],
                          fractions=(0.01, 0.05, 0.25, 0.5, 1.0)
                          ) -> Dict[float, float]:
    """Fig 5: leveraged sharing vs batch size (fraction of all nodes)."""
    N = layer_graphs[0].n_nodes
    c_min = float(len(layer_graphs) * N)
    c_max = nosharing_cost(layer_graphs)
    out = {}
    for f in fractions:
        b = max(1, int(N * f))
        c = batched_cost(layer_graphs, b)
        out[f] = float(np.clip((c_max - c) / max(c_max - c_min, 1.0),
                               0.0, 1.0))
    return out
