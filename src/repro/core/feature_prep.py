"""Feature preparation (§3.5 Fig 13, evaluated in Fig 21).

Feature files on disk are NOT sorted by node id.  Three strategies to get a
(P x M)-partitioned feature tensor ready for layer 1:

  scan_all      every machine scans ALL files and keeps its rows
                (O(M*N) file traffic — the Fig 21 baseline);
  redistribute  each machine loads 1/M of the file then shuffles rows to
                owners (O(N/M) file + O((M-1)N/M) network);
  fused         each machine loads 1/M, records a location table, and the
                FIRST GNN primitive consumes loader-ordered rows directly —
                the shuffle disappears into layer-1's gather (Fig 13).

On one host we model "machines" as loop iterations and network as memcpy,
but the byte counts are exact and the fused variant genuinely skips the
standalone shuffle pass.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import numpy as np

from repro import obs


def _loader_span(strategy: str, stats: Dict) -> None:
    """One counter pair per loader run (file vs network rows) — the
    Fig 21 stage breakdown under the unified names."""
    obs.add(f"featprep.{strategy}.file_rows", stats["file_rows"])
    obs.add(f"featprep.{strategy}.net_rows", stats["net_rows"])


def write_feature_files(path, N: int, D: int, n_files: int = 8,
                        seed: int = 0) -> Tuple[list, np.ndarray]:
    """Unsorted feature files: (ids, rows) pairs."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(N)
    feats = rng.standard_normal((N, D), dtype=np.float32)
    files = []
    bounds = np.linspace(0, N, n_files + 1).astype(int)
    for i in range(n_files):
        ids = perm[bounds[i]:bounds[i + 1]]
        f = f"{path}/feat_{i}.npz"
        np.savez(f, ids=ids, rows=feats[ids])
        files.append(f)
    return files, feats


def scan_all_load(files, n_machines: int, N: int, D: int):
    """Every machine reads every file; file traffic = M * N rows."""
    with obs.span("featprep.scan_all",
                  {"n_machines": n_machines} if obs.enabled() else None):
        return _scan_all_load(files, n_machines, N, D)


def _scan_all_load(files, n_machines: int, N: int, D: int):
    t0 = time.perf_counter()
    bounds = np.linspace(0, N, n_machines + 1).astype(int)
    out = np.zeros((N, D), np.float32)
    file_rows = 0
    for m in range(n_machines):
        lo, hi = bounds[m], bounds[m + 1]
        for f in files:
            z = np.load(f)
            ids, rows = z["ids"], z["rows"]
            file_rows += ids.size
            sel = (ids >= lo) & (ids < hi)
            out[ids[sel]] = rows[sel]
    stats = {"seconds": time.perf_counter() - t0,
             "file_rows": file_rows, "net_rows": 0}
    _loader_span("scan_all", stats)
    return out, stats


def redistribute_load(files, n_machines: int, N: int, D: int):
    """Each machine loads 1/M of the files, then shuffles to owners."""
    with obs.span("featprep.redistribute",
                  {"n_machines": n_machines} if obs.enabled() else None):
        return _redistribute_load(files, n_machines, N, D)


def _redistribute_load(files, n_machines: int, N: int, D: int):
    t0 = time.perf_counter()
    bounds = np.linspace(0, N, n_machines + 1).astype(int)
    loaded = []          # per machine: (ids, rows)
    file_rows = 0
    for m in range(n_machines):
        ids_l, rows_l = [], []
        for f in files[m::n_machines]:
            z = np.load(f)
            ids_l.append(z["ids"]); rows_l.append(z["rows"])
            file_rows += z["ids"].size
        loaded.append((np.concatenate(ids_l) if ids_l else np.empty(0, int),
                       np.concatenate(rows_l) if rows_l
                       else np.empty((0, D), np.float32)))
    # shuffle pass (network)
    out = np.zeros((N, D), np.float32)
    net_rows = 0
    for m in range(n_machines):
        ids, rows = loaded[m]
        owner = np.searchsorted(bounds, ids, side="right") - 1
        net_rows += int((owner != m).sum())
        out[ids] = rows
    stats = {"seconds": time.perf_counter() - t0,
             "file_rows": file_rows, "net_rows": net_rows}
    _loader_span("redistribute", stats)
    return out, stats


def fused_load(files, n_machines: int, N: int, D: int, w: np.ndarray):
    """Fused: no shuffle pass; layer-1 GEMM gathers loader-ordered rows via
    the location table and emits output already partition-ordered.

    Returns H1 = X @ w computed WITHOUT materializing the ordered X, plus a
    location table for subsequent primitives.
    """
    with obs.span("featprep.fused",
                  {"n_machines": n_machines} if obs.enabled() else None):
        return _fused_load(files, n_machines, N, D, w)


def _fused_load(files, n_machines: int, N: int, D: int, w: np.ndarray):
    t0 = time.perf_counter()
    loaded_ids, loaded_rows = [], []
    file_rows = 0
    for m in range(n_machines):
        for f in files[m::n_machines]:
            z = np.load(f)
            loaded_ids.append(z["ids"]); loaded_rows.append(z["rows"])
            file_rows += z["ids"].size
    ids = np.concatenate(loaded_ids)
    rows = np.concatenate(loaded_rows)
    table = np.empty(N, np.int64)        # node id -> loader position
    table[ids] = np.arange(ids.size)
    h1 = rows[table] @ w                 # gather fused into the first GEMM
    stats = {"seconds": time.perf_counter() - t0,
             "file_rows": file_rows, "net_rows": 0, "table": table}
    _loader_span("fused", stats)
    return h1, stats


def fused_load_spmm(files, n_machines: int, N: int, D: int, w: np.ndarray,
                    lg, executor):
    """FULLY fused §3.5: loader-order GEMM + table-indirect layer-1
    aggregation — even the ``rows[table]`` copy that ``fused_load``
    still materializes disappears.

    The GEMM runs over rows IN LOADER ORDER (per-row dots don't care
    about row order, so ``(rows @ w)[table[i]] == (rows[table] @ w)[i]``
    bitwise) and the first aggregation consumes the location table
    directly through ``DenseIO.table`` — the gather+spmm kernel on the
    pallas executor, a lazy translated take on ref.  Returns the
    aggregated layer-1 output (node order, pre-activation) plus stats.
    ``lg`` is layer 1's sampled layer graph; ``executor`` a single-host
    executor from ``core.ops``.
    """
    with obs.span("featprep.fused_spmm",
                  {"n_machines": n_machines} if obs.enabled() else None):
        return _fused_load_spmm(files, n_machines, N, D, w, lg, executor)


def _fused_load_spmm(files, n_machines: int, N: int, D: int,
                     w: np.ndarray, lg, executor):
    from repro.core.ops import DenseIO      # lazy: avoid an import cycle

    t0 = time.perf_counter()
    loaded_ids, loaded_rows = [], []
    file_rows = 0
    for m in range(n_machines):
        for f in files[m::n_machines]:
            z = np.load(f)
            loaded_ids.append(z["ids"]); loaded_rows.append(z["rows"])
            file_rows += z["ids"].size
    ids = np.concatenate(loaded_ids)
    rows = np.concatenate(loaded_rows)
    table = np.empty(N, np.int64)        # node id -> loader position
    table[ids] = np.arange(ids.size)
    h1_rows = executor.gemm(executor.prepare(rows), w)   # loader order!
    io = DenseIO(lg.nbr, lg.mask, table=table)
    agg = executor.spmm(h1_rows, io.mean_w, io)
    stats = {"seconds": time.perf_counter() - t0,
             "file_rows": file_rows, "net_rows": 0, "table": table}
    _loader_span("fused_spmm", stats)
    return agg, stats
