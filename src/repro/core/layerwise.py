"""The DEAL engine: layer-by-layer all-node inference (§3.2, Fig 4).

Two engines:
  * ``local_*`` — single-host pure-jnp (oracle + CPU benchmarks);
  * ``DistributedLayerwise`` — shard_map on a ("data","model") mesh using
    the §3.4 primitives and the static CommPlan.

Plus the ego-network BASELINE (DGI/SALIENT++-style batched inference) used
by the Fig 14 comparison: identical math on the same sampled layer graphs,
but computed batch-by-batch over multi-hop dependency frontiers, so
cross-batch redundancy costs real work — exactly the waste DEAL removes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import primitives as prim
from repro.core.gnn_models import gat_head_scores, masked_softmax, mean_weights
from repro.core.partition import PartitionPlan, build_plan
from repro.core.sampler import LayerGraph


# ----------------------------------------------------------------------
# single-host engines
# ----------------------------------------------------------------------

def local_gcn_infer(layer_graphs: List[LayerGraph], X, params,
                    activation=jax.nn.relu):
    H = jnp.asarray(X)
    L = len(params["w"])
    for l, w in enumerate(params["w"]):
        lg = layer_graphs[l]
        wts = jnp.asarray(mean_weights(lg.mask))
        H = prim.ref_gemm(H, w)
        H = prim.ref_spmm(H, wts, jnp.asarray(lg.nbr), jnp.asarray(lg.mask))
        if l < L - 1:
            H = activation(H)
    return H


def local_gat_infer(layer_graphs: List[LayerGraph], X, params,
                    activation=jax.nn.elu):
    H = jnp.asarray(X)
    heads = params["heads"]
    L = len(params["layers"])
    for l, p in enumerate(params["layers"]):
        lg = layer_graphs[l]
        nbr, mask = jnp.asarray(lg.nbr), jnp.asarray(lg.mask)
        q = prim.ref_gemm(H, p["wq"])
        kf = prim.ref_gemm(H, p["wk"])
        v = prim.ref_gemm(H, p["wv"])
        s = gat_head_scores(q, kf, nbr, mask, heads)       # (N,F,h)
        alpha = masked_softmax(s.transpose(0, 2, 1),
                               mask[:, None, :]).transpose(0, 2, 1)
        N, D = v.shape
        dh = D // heads
        vn = jnp.take(v.reshape(N, heads, dh), nbr.reshape(-1),
                      axis=0).reshape(nbr.shape + (heads, dh))
        H = jnp.einsum("nfh,nfhd->nhd", alpha, vn).reshape(N, D)
        if l < L - 1:
            H = activation(H)
    return H


def local_sage_infer(layer_graphs: List[LayerGraph], X, params,
                     activation=jax.nn.relu):
    H = jnp.asarray(X)
    L = len(params["layers"])
    for l, p in enumerate(params["layers"]):
        lg = layer_graphs[l]
        wts = jnp.asarray(mean_weights(lg.mask))
        agg = prim.ref_spmm(H, wts, jnp.asarray(lg.nbr),
                            jnp.asarray(lg.mask))
        H = prim.ref_gemm(H, p["w_self"]) + prim.ref_gemm(agg, p["w_nbr"])
        if l < L - 1:
            H = activation(H)
    return H


LOCAL_ENGINES = {"gcn": local_gcn_infer, "gat": local_gat_infer,
                 "sage": local_sage_infer}


# ----------------------------------------------------------------------
# ego-network batched baseline (the DGI/SALIENT++-style computation)
# ----------------------------------------------------------------------

def ego_batched_gcn_infer(layer_graphs: List[LayerGraph], X, params,
                          batch_size: int, activation=jax.nn.relu):
    """Identical outputs to local_gcn_infer, computed per target batch over
    multi-hop frontiers; work scales with the summed frontier sizes."""
    X = jnp.asarray(X)
    N = layer_graphs[0].n_nodes
    L = len(params["w"])
    out = np.zeros((N, params["w"][-1].shape[1]), np.float32)
    work_rows = 0
    for b0 in range(0, N, batch_size):
        targets = np.arange(b0, min(b0 + batch_size, N))
        # dependency frontiers: needed[l] = inputs of layer l
        needed = [None] * (L + 1)
        needed[L] = targets
        for l in range(L - 1, -1, -1):
            lg = layer_graphs[l]
            up = needed[l + 1]
            nbrs = lg.nbr[up][lg.mask[up]]
            needed[l] = np.unique(np.concatenate([up, nbrs]))
        H = X[jnp.asarray(needed[0])]
        cur = needed[0]
        for l, w in enumerate(params["w"]):
            lg = layer_graphs[l]
            nxt = needed[l + 1]
            work_rows += cur.size
            Hw = prim.ref_gemm(H, w)
            # remap the layer graph of `nxt` onto positions in `cur`
            pos = np.searchsorted(cur, lg.nbr[nxt])
            pos = np.clip(pos, 0, cur.size - 1)
            valid = lg.mask[nxt] & (cur[pos] == lg.nbr[nxt])
            wts = jnp.asarray(mean_weights(lg.mask[nxt]) * valid)
            H = prim.ref_spmm(Hw, wts, jnp.asarray(pos), jnp.asarray(valid))
            if l < L - 1:
                H = activation(H)
            cur = nxt
        out[targets] = np.asarray(H[np.searchsorted(needed[L], targets)])
    return jnp.asarray(out), work_rows


# ----------------------------------------------------------------------
# distributed engine
# ----------------------------------------------------------------------

class DistributedLayerwise:
    """DEAL distributed inference on a ("data","model") mesh."""

    def __init__(self, mesh, layer_graphs: List[LayerGraph], model: str,
                 params, *, spmm_variant: str = "deal",
                 gemm_variant: str = "deal", sddmm_variant: str = "deal",
                 grouped: bool = True):
        self.mesh = mesh
        self.model = model
        self.params = params
        self.P = mesh.shape["data"]
        self.M = mesh.shape["model"]
        self.plan: PartitionPlan = build_plan(layer_graphs, self.P, self.M)
        self.layer_graphs = layer_graphs
        self._gemm = prim.make_gemm(mesh, gemm_variant)
        self._spmm = [prim.make_spmm(mesh, lp, spmm_variant, grouped)
                      for lp in self.plan.layers]
        if model == "gat":
            self._sddmm = [prim.make_sddmm(mesh, lp, sddmm_variant)
                           for lp in self.plan.layers]
        self._dev_plans = [prim.plan_device_arrays(lp)
                           for lp in self.plan.layers]
        self._row_spec = NamedSharding(mesh, P("data", None))
        self._hd_spec = NamedSharding(mesh, P("data", "model"))

    def _put(self, x, spec):
        return jax.device_put(jnp.asarray(x), spec)

    def _spmm_args(self, l, variant="deal"):
        d = self._dev_plans[l]
        if variant == "graph_exchange":
            return (d["mirror_src"], d["edge_dst"], d["edge_slot"],
                    d["edge_mask"])
        return (d["send_local"], d["edge_dst"], d["edge_slot"],
                d["edge_pos"], d["edge_mask"])

    def infer(self, X) -> jax.Array:
        H = self._put(X, self._hd_spec)
        if self.model == "gcn":
            ws = self.params["w"]
            L = len(ws)
            for l, w in enumerate(ws):
                wts = self._put(mean_weights(self.layer_graphs[l].mask),
                                self._row_spec)
                H = self._gemm(H, jnp.asarray(w))
                H = self._spmm[l](H, wts, *self._spmm_args(l))
                if l < L - 1:
                    H = jax.nn.relu(H)
            return H
        if self.model == "gat":
            return self._infer_gat(H)
        if self.model == "sage":
            return self._infer_sage(H)
        raise ValueError(self.model)

    def _infer_sage(self, H):
        layers = self.params["layers"]
        L = len(layers)
        for l, p in enumerate(layers):
            wts = self._put(mean_weights(self.layer_graphs[l].mask),
                            self._row_spec)
            agg = self._spmm[l](H, wts, *self._spmm_args(l))
            H = self._gemm(H, jnp.asarray(p["w_self"])) + \
                self._gemm(agg, jnp.asarray(p["w_nbr"]))
            if l < L - 1:
                H = jax.nn.relu(H)
        return H

    def _infer_gat(self, H):
        layers = self.params["layers"]
        heads = self.params["heads"]
        assert self.M % heads == 0, "feature parts must align to heads"
        L = len(layers)
        for l, p in enumerate(layers):
            lg = self.layer_graphs[l]
            mask = self._put(lg.mask.astype(np.float32), self._row_spec)
            q = self._gemm(H, jnp.asarray(p["wq"]))
            kf = self._gemm(H, jnp.asarray(p["wk"]))
            v = self._gemm(H, jnp.asarray(p["wv"]))
            # NOTE: the distributed engine scores edges with the FULL-width
            # dot (heads=1 semantics; the psum over `model` assembles the
            # full-D dot product) — matches local_gat_infer with heads=1.
            scores = self._sddmm[l](q, kf, *self._spmm_args(l))
            D = layers[l]["wq"].shape[1]
            alpha = masked_softmax(scores / np.sqrt(D), mask > 0)
            H = self._spmm[l](v, alpha, *self._spmm_args(l))
            if l < L - 1:
                H = jax.nn.elu(H)
        return H
