"""The DEAL engine: layer-by-layer all-node inference (§3.2, Fig 4).

All engines are thin drivers over the pluggable executor layer
(``core.ops``): each model's layer math is declared once in
``gnn_models.model_spec`` and interpreted against a backend —

  * ``local_*`` — single-host engines (oracle + CPU benchmarks); take an
    ``executor`` argument ("ref" default, "pallas" for the kernels in
    ``kernels/``);
  * ``DistributedLayerwise`` — ``DistExecutor`` on a ("data", "model")
    mesh using the §3.4 primitives and the static CommPlan.

Plus the ego-network BASELINE (DGI/SALIENT++-style batched inference) used
by the Fig 14 comparison: identical math on the same sampled layer graphs,
but computed batch-by-batch over multi-hop dependency frontiers, so
cross-batch redundancy costs real work — exactly the waste DEAL removes.
The baseline runs through the same executor primitives, so it too can
retarget backends.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gnn_models import mean_weights, model_spec
from repro.core.ops import DenseIO, DistExecutor, get_executor, run_model
from repro.core.sampler import LayerGraph


# ----------------------------------------------------------------------
# single-host engines
# ----------------------------------------------------------------------

def _local_infer(model: str, layer_graphs: List[LayerGraph], X, params,
                 activation=None, executor="ref"):
    ex = get_executor(executor)
    spec = model_spec(model, params)
    ios = [DenseIO.from_layer_graph(lg)
           for lg in layer_graphs[:len(spec.layers)]]
    return run_model(ex, spec, ios, X, activation=activation)


def local_gcn_infer(layer_graphs, X, params, activation=jax.nn.relu,
                    executor="ref"):
    return _local_infer("gcn", layer_graphs, X, params, activation,
                        executor)


def local_gat_infer(layer_graphs, X, params, activation=jax.nn.elu,
                    executor="ref"):
    return _local_infer("gat", layer_graphs, X, params, activation,
                        executor)


def local_sage_infer(layer_graphs, X, params, activation=jax.nn.relu,
                     executor="ref"):
    return _local_infer("sage", layer_graphs, X, params, activation,
                        executor)


LOCAL_ENGINES = {"gcn": local_gcn_infer, "gat": local_gat_infer,
                 "sage": local_sage_infer}


# ----------------------------------------------------------------------
# ego-network batched baseline (the DGI/SALIENT++-style computation)
# ----------------------------------------------------------------------

def ego_batched_gcn_infer(layer_graphs: List[LayerGraph], X, params,
                          batch_size: int, activation=jax.nn.relu,
                          executor="ref"):
    """Identical outputs to local_gcn_infer, computed per target batch over
    multi-hop frontiers; work scales with the summed frontier sizes."""
    ex = get_executor(executor)
    X = jnp.asarray(X)
    N = layer_graphs[0].n_nodes
    L = len(params["w"])
    out = np.zeros((N, params["w"][-1].shape[1]), np.float32)
    work_rows = 0
    for b0 in range(0, N, batch_size):
        targets = np.arange(b0, min(b0 + batch_size, N))
        # dependency frontiers: needed[l] = inputs of layer l
        needed = [None] * (L + 1)
        needed[L] = targets
        for l in range(L - 1, -1, -1):
            lg = layer_graphs[l]
            up = needed[l + 1]
            nbrs = lg.nbr[up][lg.mask[up]]
            needed[l] = np.unique(np.concatenate([up, nbrs]))
        H = X[jnp.asarray(needed[0])]
        cur = needed[0]
        for l, w in enumerate(params["w"]):
            lg = layer_graphs[l]
            nxt = needed[l + 1]
            work_rows += cur.size
            Hw = ex.gemm(H, w)
            # remap the layer graph of `nxt` onto positions in `cur`
            pos = np.searchsorted(cur, lg.nbr[nxt])
            pos = np.clip(pos, 0, cur.size - 1)
            valid = lg.mask[nxt] & (cur[pos] == lg.nbr[nxt])
            wts = jnp.asarray(mean_weights(lg.mask[nxt]) * valid)
            H = ex.spmm(Hw, wts, DenseIO(pos, valid))
            if l < L - 1:
                H = activation(H)
            cur = nxt
        out[targets] = np.asarray(H[np.searchsorted(needed[L], targets)])
    return jnp.asarray(out), work_rows


# ----------------------------------------------------------------------
# distributed engine
# ----------------------------------------------------------------------

class DistributedLayerwise:
    """DEAL distributed inference: a thin driver binding the model spec
    to a ``DistExecutor`` on a ("data", "model") mesh."""

    def __init__(self, mesh, layer_graphs: List[LayerGraph], model: str,
                 params, *, spmm_variant: str = "deal",
                 gemm_variant: str = "deal", sddmm_variant: str = "deal",
                 grouped: bool = True):
        self.mesh = mesh
        self.model = model
        self.params = params
        self.layer_graphs = layer_graphs
        self.ex = DistExecutor(mesh, spmm_variant=spmm_variant,
                               gemm_variant=gemm_variant,
                               sddmm_variant=sddmm_variant, grouped=grouped)
        self.P = self.ex.P
        self.M = self.ex.M
        self.spec = model_spec(model, params)
        self.ios = self.ex.bind(layer_graphs[:len(self.spec.layers)],
                                need_sddmm=(model == "gat"))
        self.plan = self.ex.plan

    def infer(self, X) -> jax.Array:
        return run_model(self.ex, self.spec, self.ios, X)
