"""Graph substrate: CSR construction (stage 1 of Fig 2), RMAT, datasets.

Construction is a host/file-system task in the paper too (their cluster
builds CSR from an on-disk edge list before any GNN compute); we implement
both the single-machine baseline (DistDGL-style, Fig 20 baseline) and DEAL's
distributed builder, modeled as chunk-parallel passes with counted exchange
volumes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro import obs


@dataclasses.dataclass
class Graph:
    """CSR over in-edges: row v lists the in-neighbors of v."""
    indptr: np.ndarray      # (N+1,) int64
    indices: np.ndarray     # (E,)  int32
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> Graph:
    """Single-machine baseline: one global counting sort by dst."""
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    order = np.argsort(dst, kind="stable")
    return Graph(indptr=indptr, indices=src[order].astype(np.int32),
                 n_nodes=n_nodes)


def csr_from_edges_distributed(src: np.ndarray, dst: np.ndarray,
                               n_nodes: int, n_workers: int = 4,
                               chunk_edges: int = 1 << 20
                               ) -> Tuple[Graph, Dict[str, float]]:
    """DEAL's distributed construction (modeled on one host).

    Each worker reads a disjoint chunk range of the edge list, histograms by
    destination partition and "ships" edges to the owning worker (we count
    the exchanged bytes); each worker then builds its local CSR
    independently.  The returned graph is the concatenation of local CSRs
    (node ranges are contiguous, so indptr/indices concatenate directly).
    """
    t0 = time.perf_counter()
    E = src.shape[0]
    bounds = np.linspace(0, n_nodes, n_workers + 1).astype(np.int64)
    part_of = np.searchsorted(bounds, dst, side="right") - 1
    exchanged = 0

    # pass 1 (parallel in production): per-chunk shuffle by owner
    buckets_src = [[] for _ in range(n_workers)]
    buckets_dst = [[] for _ in range(n_workers)]
    reader_bounds = np.linspace(0, E, n_workers + 1).astype(np.int64)
    shuffle_worker_s = []
    with obs.span("construct.shuffle") as sp:
        for w in range(n_workers):
            tw = time.perf_counter()
            lo, hi = reader_bounds[w], reader_bounds[w + 1]
            for c0 in range(lo, hi, chunk_edges):
                c1 = min(c0 + chunk_edges, hi)
                p = part_of[c0:c1]
                for q in range(n_workers):
                    sel = p == q
                    if not sel.any():
                        continue
                    buckets_src[q].append(src[c0:c1][sel])
                    buckets_dst[q].append(dst[c0:c1][sel])
                    if q != w:          # cross-worker traffic
                        exchanged += int(sel.sum()) * 8
            shuffle_worker_s.append(time.perf_counter() - tw)
        if sp:
            sp.set(n_workers=n_workers, exchanged_bytes=exchanged)
    obs.add("construct.exchanged_bytes", exchanged)
    t_shuffle = time.perf_counter() - t0

    # pass 2: local CSR build per worker
    t1 = time.perf_counter()
    indptr = np.zeros(n_nodes + 1, np.int64)
    chunks = []
    build_worker_s = []
    with obs.span("construct.local_build",
                  {"n_workers": n_workers} if obs.enabled() else None):
        for q in range(n_workers):
            tw = time.perf_counter()
            lo, hi = bounds[q], bounds[q + 1]
            s = (np.concatenate(buckets_src[q]) if buckets_src[q]
                 else np.empty(0, src.dtype))
            d = (np.concatenate(buckets_dst[q]) if buckets_dst[q]
                 else np.empty(0, dst.dtype))
            local = d - lo
            counts = np.bincount(local, minlength=hi - lo)
            indptr[lo + 1:hi + 1] = counts
            order = np.argsort(local, kind="stable")
            chunks.append(s[order].astype(np.int32))
            build_worker_s.append(time.perf_counter() - tw)
        np.cumsum(indptr, out=indptr)
        g = Graph(indptr=indptr, indices=np.concatenate(chunks),
                  n_nodes=n_nodes)
    # modeled wall time on a real cluster: slowest worker per parallel
    # phase + network (workers here run sequentially on one host).
    net_bw = 25e9 / 8                    # the paper's 25 Gbps Ethernet
    modeled = (max(shuffle_worker_s) + max(build_worker_s)
               + exchanged / net_bw)
    stats = {"shuffle_s": t_shuffle, "build_s": time.perf_counter() - t1,
             "exchanged_bytes": float(exchanged), "n_workers": n_workers,
             "modeled_parallel_s": modeled,
             "worker_shuffle_s": shuffle_worker_s,
             "worker_build_s": build_worker_s}
    return g, stats


# ----------------------------------------------------------------------
# generators / datasets
# ----------------------------------------------------------------------

def rmat_edges(n_nodes: int, n_edges: int, seed: int = 0,
               probs=(0.57, 0.19, 0.19, 0.05)) -> Tuple[np.ndarray, np.ndarray]:
    """RMAT [63] with the paper's edge probabilities; n_nodes = 2^k."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(n_nodes)))
    a, b, c, d = probs
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    for bit in range(scale):
        r = rng.random(n_edges)
        quad_src = (r >= a + b).astype(np.int64)     # lower half quads
        quad_dst = ((r >= a) & (r < a + b) | (r >= a + b + c)).astype(np.int64)
        src |= quad_src << bit
        dst |= quad_dst << bit
    src %= n_nodes
    dst %= n_nodes
    return src, dst


def planted_partition(n_nodes: int, n_comm: int, p_in: float, p_out: float,
                      seed: int = 0):
    """Community graph for the Table-6 accuracy study.

    Returns (src, dst, labels)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_comm, n_nodes)
    deg = 16
    n_edges = n_nodes * deg
    src = rng.integers(0, n_nodes, n_edges)
    same = rng.random(n_edges) < p_in / (p_in + p_out)
    # intra-community destinations: uniform over the src's community
    members = np.full((n_comm, n_nodes), 0, np.int64)
    sizes = np.zeros(n_comm, np.int64)
    for c in range(n_comm):
        idx = np.where(labels == c)[0]
        members[c, :idx.size] = idx
        sizes[c] = idx.size
    comm = labels[src]
    pick = rng.integers(0, np.maximum(sizes[comm], 1))
    dst_same = members[comm, pick]
    dst_rand = rng.integers(0, n_nodes, n_edges)
    dst = np.where(same, dst_same, dst_rand)
    return src.astype(np.int64), dst.astype(np.int64), labels


_DATASETS = {
    # laptop-scale stand-ins preserving the density character of Table 4
    # name: (n_nodes, avg_degree)
    "ogbn-products": (8_192, 51),      # sparse-ish co-purchase
    "social-spammer": (4_096, 153),    # dense multi-relation
    "ogbn-papers100M": (16_384, 14),   # large & sparse citation
}


def make_dataset(name: str, seed: int = 0,
                 scale: float = 1.0) -> Tuple[np.ndarray, np.ndarray, int]:
    """Synthetic edge list with the named dataset's density character."""
    n, deg = _DATASETS[name]
    n = int(n * scale)
    e = int(n * deg)
    src, dst = rmat_edges(n, e, seed=seed)
    return src, dst, n


def truncate_to_multiple(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                         mult: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """Trim the node count to a multiple of ``mult`` (the P*M mesh needs
    n % P == 0) and drop edges touching the removed tail."""
    n = n_nodes - n_nodes % mult
    keep = (src < n) & (dst < n)
    return src[keep], dst[keep], n


def dataset_names():
    return list(_DATASETS)
