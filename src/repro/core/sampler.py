"""Layer-wise 1-hop sampling — DEAL's sampling contribution (§3.2).

For a k-layer model we draw k INDEPENDENT 1-hop neighborhoods per node and
store each layer's samples for all nodes together as one layer graph
``G_l``, represented as a fixed-fanout neighbor matrix (N, F) + mask — the
static-shape TPU adaptation of the paper's per-layer edge lists.

The "column-wise" sharing of §3.2 (reusing the per-node sampling structure
across the k layers) is realized by building the per-node CSR row view once
and drawing all k layers from it in one vectorized pass; the ego-centric
baseline (``sample_ego_networks``) re-walks the CSR per hop per target —
the pointer-chasing DEAL eliminates.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro import obs
from repro.core.graph import Graph


@dataclasses.dataclass
class LayerGraph:
    """One layer's 1-hop ego networks of ALL nodes, fixed fanout."""
    nbr: np.ndarray     # (N, F) int32 — global in-neighbor ids (0 if none)
    mask: np.ndarray    # (N, F) bool
    fanout: int

    @property
    def n_nodes(self) -> int:
        return self.nbr.shape[0]


def draw_fixed_fanout(deg: np.ndarray, starts: np.ndarray,
                      indices: np.ndarray, n_edges: int, fanout: int,
                      rng: np.random.Generator
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """One fixed-fanout draw for the rows described by (deg, starts):
    uniform with replacement where deg > fanout, each neighbor once
    otherwise (see DESIGN.md §8).  The online row-resampler
    (``gnnserve.delta.resample_rows``) mirrors these take-all/mask
    semantics with a content-addressed counter-based draw (its
    batching-invariance guarantee needs per-row independent streams,
    which a shared sequential rng cannot give)."""
    has = deg > 0
    draw = rng.integers(0, np.maximum(deg, 1)[:, None],
                        size=(deg.size, fanout))
    take_all = deg[:, None] <= fanout      # small rows: take each nbr once
    seqidx = np.arange(fanout)[None, :]
    draw = np.where(take_all,
                    np.minimum(seqidx, np.maximum(deg - 1, 0)[:, None]),
                    draw)
    idx = starts[:, None] + draw
    nbr = indices[np.minimum(idx, max(n_edges - 1, 0))].astype(np.int32)
    mask = has[:, None] & ((seqidx < deg[:, None])
                           | (deg[:, None] > fanout))
    return nbr, mask


def sample_layer_graphs(g: Graph, fanout: int, n_layers: int,
                        seed: int = 0) -> List[LayerGraph]:
    """Sample k 1-hop layer graphs for all nodes, sharing the per-node
    sampling structure (degree/row offsets) across layers."""
    rng = np.random.default_rng(seed)
    deg = g.degrees()                      # the shared sampling structure:
    starts = g.indptr[:-1]                 # built ONCE, reused k times
    out = []
    for l in range(n_layers):
        with obs.span("sample.layer") as sp:
            nbr, mask = draw_fixed_fanout(deg, starts, g.indices,
                                          g.n_edges, fanout, rng)
            out.append(LayerGraph(nbr=nbr, mask=mask, fanout=fanout))
            if sp:
                sp.set(layer=l, rows=int(nbr.shape[0]), fanout=fanout)
    return out


def sample_ego_networks(g: Graph, targets: np.ndarray, fanout: int,
                        n_layers: int, seed: int = 0
                        ) -> List[List[np.ndarray]]:
    """Ego-centric baseline: per-target multi-hop frontier expansion
    (pointer-chasing).  Returns, per target, the node set of each hop."""
    rng = np.random.default_rng(seed)
    egos = []
    for t in targets:
        frontier = np.array([t], np.int64)
        hops = [frontier]
        for _ in range(n_layers):
            nxt = []
            for v in frontier:
                nbrs = g.neighbors(v)
                if nbrs.size == 0:
                    continue
                if nbrs.size > fanout:
                    nbrs = rng.choice(nbrs, size=fanout, replace=False)
                nxt.append(nbrs)
            frontier = (np.unique(np.concatenate(nxt))
                        if nxt else np.empty(0, np.int64))
            hops.append(frontier)
        egos.append(hops)
    return egos


def frontier_sizes(layer_graphs: List[LayerGraph],
                   targets: np.ndarray) -> List[np.ndarray]:
    """Dependency frontiers of a target batch under the LAYER graphs
    (used by the sharing-ratio analytics and the batched baseline)."""
    frontier = np.unique(targets)
    out = [frontier]
    for lg in layer_graphs:
        nbrs = lg.nbr[frontier][lg.mask[frontier]]
        frontier = np.unique(np.concatenate([frontier, nbrs]))
        out.append(frontier)
    return out
