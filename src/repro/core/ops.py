"""The pluggable layer-op executor layer (InferTurbo-style retargeting).

One layer's semantics — the GEMM -> SPMM / SDDMM dataflow over a sampled
layer graph (Deal §3.4) — is declared once per model in
``gnn_models.model_spec`` and executed here against one of three
interchangeable backends:

  ``RefExecutor``     pure-jnp oracle (the ``kernels.ref`` primitives);
                      bitwise-identical to the pre-executor engines.
  ``PallasExecutor``  the Pallas SPMM/SDDMM kernels from ``kernels/``:
                      compiled on TPU, interpret mode elsewhere.  Pads
                      rows/columns to kernel block multiples internally,
                      so non-aligned N/D shapes just work.
  ``DistExecutor``    the §3.4 shard_map primitives on a (data, model)
                      mesh with the static CommPlan — plus a ROW-SUBSET
                      mode (``run_rows``) that executes one layer for a
                      frontier of rows with a per-partition frontier
                      split (the ROADMAP "distributed delta refresh").

Executor primitives take a graph binding ``io`` object:
``DenseIO`` (neighbor matrix + mask indexing the source rows directly)
for the single-host executors, ``DistIO`` (plan tensors + sharded edge
weights) for the mesh.  ``run_layer`` interprets a ``LayerSpec`` over an
executor; ``run_model`` drives a whole forward pass.  The source slot
``h_src`` and target slot ``h_tgt`` decouple so the same spec serves
full-graph inference (h_src is h_tgt) and delta refresh (h_src is the
gathered universe) — see ``gnnserve.delta``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import obs, tuning
from repro.api.registry import EXECUTORS, register_executor
from repro.core import primitives as prim
from repro.core.gnn_models import (LayerSpec, ModelSpec, gat_head_scores,
                                   masked_softmax, mean_weights)
from repro.core.partition import build_plan, build_subset_plan_cached
from repro.core.sampler import LayerGraph
from repro.kernels import ops as kops
from repro.kernels.spmm import auto_block_n


# ----------------------------------------------------------------------
# graph bindings
# ----------------------------------------------------------------------

class DenseIO:
    """Graph binding for the single-host executors: a fixed-fanout
    neighbor matrix whose ids index the spmm/sddmm source rows directly
    (global ids in full-graph mode, universe positions in delta mode).

    An optional ``table`` adds one level of indirection — the ids in
    ``nbr`` index ``table`` and ``table[id]`` indexes the source rows
    (loader order in §3.5 fused feature prep, universe positions in
    delta refresh).  Executors with a fused gather kernel consume
    ``table`` directly; everything else reads ``nbr_resolved``, which
    materializes the translation lazily (and is bitwise-identical, so
    the two routes interchange freely)."""

    def __init__(self, nbr: np.ndarray, mask: np.ndarray, table=None):
        self.nbr_np = np.asarray(nbr)
        self.mask_np = np.asarray(mask)
        self.nbr = jnp.asarray(self.nbr_np)
        self.mask = jnp.asarray(self.mask_np)
        self.table = (None if table is None
                      else jnp.asarray(table, jnp.int32))
        self._nbr_resolved = None
        self._mean_w = None

    @classmethod
    def from_layer_graph(cls, lg: LayerGraph) -> "DenseIO":
        return cls(lg.nbr, lg.mask)

    @property
    def nbr_resolved(self):
        """``nbr`` with the table indirection applied (identity when no
        table) — the materialized-gather fallback path."""
        if self.table is None:
            return self.nbr
        if self._nbr_resolved is None:
            self._nbr_resolved = jnp.take(
                self.table, self.nbr.reshape(-1)).reshape(self.nbr.shape)
        return self._nbr_resolved

    @property
    def mean_w(self):
        """Mean-aggregation edge weights (computed lazily: gat never
        reads them)."""
        if self._mean_w is None:
            self._mean_w = jnp.asarray(mean_weights(self.mask_np))
        return self._mean_w


@dataclasses.dataclass
class DistIO:
    """Graph binding for DistExecutor: the jitted collectives plus the
    plan tensors they consume, and the sharded per-row edge weights.
    ``args`` follows the spmm variant's signature; ``sddmm_args`` is
    always the deal-style 5-tuple the SDDMM collective expects."""
    spmm: Callable
    args: Tuple                      # plan arrays, sharded over "data"
    mean_w: Any                      # (N, F) mean weights, row-sharded
    mask_f: Any                      # (N, F) float mask, row-sharded (gat)
    sddmm: Optional[Callable] = None
    sddmm_args: Tuple = ()


# ----------------------------------------------------------------------
# spec interpreter
# ----------------------------------------------------------------------

def _fusable_attn_pair(ex, layer: LayerSpec, i: int) -> bool:
    """True when ops[i] is an (attn_scores -> edge_softmax) pair the
    executor can collapse into one ``attn_scores_softmax`` call: the
    softmax must be the ONLY consumer of the raw scores (they are never
    materialized on the fused path)."""
    ops = layer.ops
    if (getattr(ex, "attn_scores_softmax", None) is None
            or ops[i].kind != "attn_scores" or i + 1 >= len(ops)
            or ops[i + 1].kind != "edge_softmax"
            or ops[i + 1].src[0] != ops[i].out):
        return False
    readers = [op for j, op in enumerate(ops)
               if j != i + 1 and ops[i].out in op.src]
    return not readers and layer.out != ops[i].out


def run_layer(ex, layer: LayerSpec, io, h_tgt, h_src, heads: int = 1):
    """Execute one LayerSpec.  ``h_tgt``/``h_src`` may be zero-arg
    callables, resolved on first use (delta refresh reads target rows
    from the store only for models that reference them).

    Peephole: an (attn_scores -> edge_softmax) pair collapses into one
    ``attn_scores_softmax`` call when the executor exposes it (the
    fused SDDMM+softmax kernel) — the (N, F) score tensor never
    round-trips through HBM."""
    env: Dict[str, Any] = {"h_tgt": h_tgt, "h_src": h_src}

    def get(name):
        v = env[name]
        if callable(v):
            v = v()
            env[name] = v
        return v

    skip = -1
    for i, op in enumerate(layer.ops):
        if i == skip:
            continue
        kind = op.kind
        out_slot = op.out
        if _fusable_attn_pair(ex, layer, i):
            kind = "attn_scores_softmax"
            out_slot = layer.ops[i + 1].out
            skip = i + 1
        with obs.span("ops." + kind) as sp:
            if kind == "gemm":
                out = ex.gemm(get(op.src[0]), op.param)
            elif kind == "spmm":
                out = ex.spmm(get(op.src[0]), io.mean_w, io)
            elif kind == "add":
                out = get(op.src[0]) + get(op.src[1])
            elif kind == "attn_scores":
                out = ex.attn_scores(get(op.src[0]), get(op.src[1]), io,
                                     heads)
            elif kind == "attn_scores_softmax":
                out = ex.attn_scores_softmax(get(op.src[0]),
                                             get(op.src[1]), io, heads)
            elif kind == "edge_softmax":
                out = ex.edge_softmax(get(op.src[0]), io)
            elif kind == "attend":
                out = ex.attend(get(op.src[0]), get(op.src[1]), io, heads)
            else:
                raise ValueError(f"unknown layer op {kind!r}")
            if sp:
                # make the span honest under async dispatch; value-neutral
                out = jax.block_until_ready(out)
                sp.set(executor=getattr(ex, "name", type(ex).__name__),
                       rows=int(out.shape[0]))
        env[out_slot] = out
    return env[layer.out]


def run_model(ex, spec: ModelSpec, ios: Sequence, X,
              activation: Optional[Callable] = None):
    """Full forward pass: layer l reads/writes the same row set
    (h_src == h_tgt == H), activation between layers."""
    act = activation or spec.activation
    H = ex.prepare(X)
    L = len(spec.layers)
    for l, layer in enumerate(spec.layers):
        H = run_layer(ex, layer, ios[l], H, H, spec.heads)
        if l < L - 1:
            H = act(H)
    return H


# ----------------------------------------------------------------------
# RefExecutor — the jnp oracle
# ----------------------------------------------------------------------

class RefExecutor:
    """Single-host pure-jnp backend; op-for-op the pre-refactor
    ``local_*_infer`` / delta math, so outputs are bitwise-preserved."""

    name = "ref"

    def prepare(self, X):
        return jnp.asarray(X)

    def gemm(self, H, W):
        return prim.ref_gemm(H, jnp.asarray(W))

    def spmm(self, H_src, w_edge, io: DenseIO):
        return prim.ref_spmm(H_src, w_edge, io.nbr_resolved, io.mask)

    def attn_scores(self, q, k, io: DenseIO, heads: int):
        """Per-head scaled dot scores (R, F, h); k rows may outnumber q
        rows (universe gather)."""
        return gat_head_scores(q, k, io.nbr_resolved, io.mask, heads)

    def edge_softmax(self, s, io: DenseIO):
        return masked_softmax(s.transpose(0, 2, 1),
                              io.mask[:, None, :]).transpose(0, 2, 1)

    def attend(self, alpha, v, io: DenseIO, heads: int):
        D = v.shape[-1]
        dh = D // heads
        vn = jnp.take(v.reshape(-1, heads, dh),
                      io.nbr_resolved.reshape(-1),
                      axis=0).reshape(io.nbr.shape + (heads, dh))
        return jnp.einsum("nfh,nfhd->nhd", alpha, vn).reshape(
            alpha.shape[0], D)


# ----------------------------------------------------------------------
# PallasExecutor — the kernels in kernels/ (compiled on TPU)
# ----------------------------------------------------------------------

def pad_to_blocks(block_n: int, nbr, mask, *row_arrays):
    """Pad the leading (row) axis of graph-shaped arrays to the next
    ``block_n`` multiple — the ONE pad-to-block helper every Pallas
    call site shares.  ``nbr`` pads with 0 (a valid in-range id) and
    ``mask`` with False, so padded slots contribute exactly 0.0 and the
    output slice-back is value-neutral.  Returns (Rp, nbr, mask,
    *row_arrays) with every extra array zero-padded the same way."""
    R = nbr.shape[0]
    Rp = -(-R // block_n) * block_n

    def pad(a, fill=0):
        if a.shape[0] == Rp:
            return a
        widths = [(0, Rp - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, widths, constant_values=fill)

    return (Rp, pad(nbr), pad(mask, fill=False)) + tuple(
        pad(a) for a in row_arrays)


class PallasExecutor(RefExecutor):
    """Routes spmm/sddmm through the Pallas kernels (``kernels.ops``
    dispatch: compiled on TPU, interpret mode elsewhere).  GEMM stays on
    XLA's MXU path — a hand-written matmul kernel would only lose.
    Rows are padded to block multiples and feature columns to a block
    that divides them, then sliced back — non-aligned shapes just work.

    ``fused_gather``: consume ``DenseIO.table`` via the fused
    gather+spmm kernel instead of materializing ``nbr_resolved``
    (bitwise-identical — same per-row accumulation order, masked slots
    multiply by exact 0.0).  ``fused_attention``: collapse GAT's
    attn_scores -> edge_softmax into the one-pass SDDMM+softmax kernel
    (all heads per call, no HBM score round-trip) via the ``run_layer``
    peephole.  ``block_table``: a ``tuning.BlockTable`` source
    ("default" = configs/tuned_blocks.json) consulted per (kernel,
    shape-bucket, dtype) at bind time; block sizes never change the
    per-row accumulation order, so tuned vs untuned is bitwise too.
    ``block_n=None`` auto-sizes from the padded row count.
    """

    name = "pallas"

    def __init__(self, block_n: Optional[int] = None, block_d: int = 128,
                 use_kernel: bool = True, fused_gather: bool = True,
                 fused_attention: bool = True, block_table=None):
        self.block_n = block_n
        self.block_d = block_d
        self.use_kernel = use_kernel
        self.fused_gather = fused_gather
        self.fused_attention = fused_attention
        self._blocks = tuning.resolve_block_table(block_table)
        self._block_memo: Dict[Tuple, Tuple] = {}

    def _pick_blocks(self, kernel: str, R: int, D: int,
                     dtype) -> Tuple[Optional[int], int]:
        """(block_n, block_d) for one call site: tuned table entry if
        bound, else the constructor values (block_n None -> auto)."""
        key = (kernel, tuning.shape_bucket(R), tuning.shape_bucket(D),
               jnp.dtype(dtype).name)
        got = self._block_memo.get(key)
        if got is None:
            tuned = {}
            if self._blocks is not None:
                tuned = self._blocks.lookup(kernel, N=R, D=D,
                                            dtype=key[3]) or {}
            got = (tuned.get("block_n", self.block_n),
                   tuned.get("block_d", self.block_d))
            self._block_memo[key] = got
        return got

    def _row_block(self, bn: Optional[int], R: int) -> Tuple[int, int]:
        """(pad multiple, kernel row block).  An explicit/tuned block is
        both; None pads to the f32 sublane tile (8) and lets
        ``auto_block_n`` take the largest divisor of the padded count."""
        if bn is not None:
            return bn, bn
        Rp = -(-R // 8) * 8
        return 8, auto_block_n(Rp)

    def _spmm_kernel(self, H_src, w_edge, nbr, mask, table=None):
        R, F = nbr.shape
        D = H_src.shape[1]
        kernel = "gather_spmm" if table is not None else "spmm"
        bn, bd0 = self._pick_blocks(kernel, R, D, H_src.dtype)
        pad_n, block_n = self._row_block(bn, R)
        _, nbr, mask, w_edge = pad_to_blocks(pad_n, nbr, mask, w_edge)
        bd = math.gcd(D, bd0)
        Dp = D
        if bd < 8:                       # awkward width: pad columns
            Dp = -(-D // 8) * 8
            bd = math.gcd(Dp, bd0)
            H_src = jnp.pad(H_src, ((0, 0), (0, Dp - D)))
        if table is not None:
            out = kops.gather_spmm(H_src, table, w_edge, nbr, mask,
                                   use_kernel=self.use_kernel,
                                   block_n=block_n, block_d=bd)
        else:
            out = kops.spmm(H_src, w_edge, nbr, mask,
                            use_kernel=self.use_kernel,
                            block_n=block_n, block_d=bd)
        return out[:R, :D]

    def spmm(self, H_src, w_edge, io: DenseIO):
        if self.fused_gather and io.table is not None:
            return self._spmm_kernel(H_src, w_edge, io.nbr, io.mask,
                                     table=io.table)
        return self._spmm_kernel(H_src, w_edge, io.nbr_resolved, io.mask)

    def attn_scores(self, q, k, io: DenseIO, heads: int):
        """Per-head SDDMM kernel calls over head-major column slices
        (the UNFUSED score path — kept for specs that consume raw
        scores; the peephole routes GAT through
        ``attn_scores_softmax``)."""
        R = io.nbr.shape[0]
        D = q.shape[1]
        dh = D // heads
        bn, _ = self._pick_blocks("sddmm", R, dh, q.dtype)
        pad_n, block_n = self._row_block(bn, R)
        _, nbr, mask, qp = pad_to_blocks(pad_n, io.nbr_resolved, io.mask,
                                         q)
        per_head = [kops.sddmm(qp[:, h * dh:(h + 1) * dh],
                               k[:, h * dh:(h + 1) * dh], nbr, mask,
                               use_kernel=self.use_kernel,
                               block_n=block_n)
                    for h in range(heads)]
        s = jnp.stack(per_head, axis=-1)[:R]            # (R, F, h)
        return s / jnp.sqrt(jnp.float32(dh))

    @property
    def attn_scores_softmax(self):
        """Fused SDDMM + masked-softmax entry the ``run_layer`` peephole
        probes for; None (= disabled) when fusion is off."""
        if not self.fused_attention:
            return None
        return self._attn_scores_softmax

    def _attn_scores_softmax(self, q, k, io: DenseIO, heads: int):
        R = io.nbr.shape[0]
        D = q.shape[1]
        bn, _ = self._pick_blocks("gat_attention", R, D // heads,
                                  q.dtype)
        pad_n, block_n = self._row_block(bn, R)
        _, nbr, mask, qp = pad_to_blocks(pad_n, io.nbr_resolved, io.mask,
                                         q)
        alpha = kops.gat_attention(qp, k, nbr, mask, heads=heads,
                                   use_kernel=self.use_kernel,
                                   block_n=block_n)
        return alpha[:R]

    def attend(self, alpha, v, io: DenseIO, heads: int):
        D = v.shape[-1]
        dh = D // heads
        nbr = io.nbr if (self.fused_gather and io.table is not None) \
            else io.nbr_resolved
        table = io.table if (self.fused_gather and io.table is not None) \
            else None
        outs = [self._spmm_kernel(v[:, h * dh:(h + 1) * dh],
                                  alpha[..., h], nbr, io.mask,
                                  table=table)
                for h in range(heads)]
        return jnp.concatenate(outs, axis=-1)


# ----------------------------------------------------------------------
# DistExecutor — shard_map primitives + CommPlan, full or row-subset
# ----------------------------------------------------------------------

class DistExecutor:
    """Deal's distributed backend on a ("data", "model") mesh.

    Full-graph mode: ``bind`` builds the static CommPlan for a list of
    layer graphs and returns per-layer ``DistIO``s.  Row-subset mode:
    ``run_rows`` executes ONE layer for a frontier of rows, splitting
    the frontier per partition by the same 1-D ownership as the full
    plan — per-row reduction order (and hence bitwise output) matches a
    full epoch through this executor.

    GAT note: edge scores use the full-width dot (heads=1 semantics; the
    psum over `model` assembles the full-D product) — matching the
    pre-refactor distributed engine.
    """

    name = "dist"

    def __init__(self, mesh, *, spmm_variant: str = "deal",
                 gemm_variant: str = "deal", sddmm_variant: str = "deal",
                 grouped: bool = True, subset_floor: int = 64):
        self.mesh = mesh
        self.P = mesh.shape["data"]
        self.M = mesh.shape["model"]
        # pow2-bucket floor for row-subset plans: higher = fewer compiled
        # shapes across refreshes, more padded compute per refresh
        self.subset_floor = subset_floor
        self.spmm_variant = spmm_variant
        self.sddmm_variant = sddmm_variant
        self._gemm = prim.make_gemm(mesh, gemm_variant)
        self._spmm = prim.make_spmm_p(mesh, self.P, spmm_variant, grouped)
        self._sddmm_cache: Dict[int, Callable] = {}
        self._row_spec = NamedSharding(mesh, P("data", None))
        self._hd_spec = NamedSharding(mesh, P("data", "model"))
        self.plan = None

    # -- plumbing -------------------------------------------------------
    def _put(self, x, spec):
        return jax.device_put(jnp.asarray(x), spec)

    def _sddmm_fn(self, fanout: int) -> Callable:
        if fanout not in self._sddmm_cache:
            self._sddmm_cache[fanout] = prim.make_sddmm_p(
                self.mesh, self.P, fanout, self.sddmm_variant)
        return self._sddmm_cache[fanout]

    def _deal_args(self, dev: Dict[str, Any]) -> Tuple:
        return (dev["send_local"], dev["edge_dst"], dev["edge_slot"],
                dev["edge_pos"], dev["edge_mask"])

    def _plan_args(self, dev: Dict[str, Any]) -> Tuple:
        if self.spmm_variant == "graph_exchange":
            return (dev["mirror_src"], dev["edge_dst"], dev["edge_slot"],
                    dev["edge_mask"])
        return self._deal_args(dev)

    # -- full-graph binding ---------------------------------------------
    def bind(self, layer_graphs: Sequence[LayerGraph],
             need_sddmm: bool = False) -> List[DistIO]:
        with obs.span("dist.bind") as bsp:
            self.plan = build_plan(list(layer_graphs), self.P, self.M)
            ios = []
            for l, lp in enumerate(self.plan.layers):
                lg = layer_graphs[l]
                dev = prim.plan_device_arrays(lp)
                ios.append(DistIO(
                    spmm=self._spmm,
                    args=self._plan_args(dev),
                    mean_w=self._put(mean_weights(lg.mask),
                                     self._row_spec),
                    mask_f=self._put(lg.mask.astype(np.float32),
                                     self._row_spec),
                    sddmm=self._sddmm_fn(lp.fanout) if need_sddmm
                    else None,
                    sddmm_args=self._deal_args(dev) if need_sddmm
                    else ()))
            if bsp:
                bsp.set(n_layers=len(ios), P=self.P, M=self.M)
        return ios

    # -- executor primitives --------------------------------------------
    def prepare(self, X):
        return self._put(X, self._hd_spec)

    def gemm(self, H, W):
        return self._gemm(H, jnp.asarray(W))

    def spmm(self, H_src, w_edge, io: DistIO):
        return io.spmm(H_src, w_edge, *io.args)

    def attn_scores(self, q, k, io: DistIO, heads: int):
        assert self.M % heads == 0, "feature parts must align to heads"
        scores = io.sddmm(q, k, *io.sddmm_args)
        D = q.shape[1]                   # full width (global array)
        return scores / np.sqrt(D)

    def edge_softmax(self, s, io: DistIO):
        return masked_softmax(s, io.mask_f > 0)

    def attend(self, alpha, v, io: DistIO, heads: int):
        return io.spmm(v, alpha, *io.args)

    # -- row-subset mode (distributed delta refresh) --------------------
    def run_rows(self, layer: LayerSpec, lg: LayerGraph, rows: np.ndarray,
                 read_level: Callable, level: int, heads: int = 1,
                 *, n_nodes: Optional[int] = None):
        """Execute ``layer`` for the sorted row subset ``rows``, frontier
        split per partition.  ``read_level(level, ids)`` supplies input
        rows (the store's staged view during a refresh).  Returns the
        (pre-activation) global padded output plus (take, n_src): the
        real-row indices into it and the universe-row work count.

        ``n_nodes`` pins the partition geometry to the pre-growth main
        range when the layer graph has an unfolded tail appended — every
        row (and masked neighbour) passed here must stay below it."""
        assert self.spmm_variant == "deal", \
            "row-subset mode needs the unique-row exchange plan"
        assert self.M & (self.M - 1) == 0, \
            "model axis must be a power of two (pad buckets)"
        with obs.span("dist.subset_plan") as psp:
            sp = build_subset_plan_cached(lg, rows, self.P,
                                          m_align=self.M,
                                          floor=self.subset_floor,
                                          n_nodes=n_nodes)
            if psp:
                psp.set(rows=int(rows.size), src_rows=int(sp.n_src_rows),
                        level=level)
        args = (jnp.asarray(sp.send_local), jnp.asarray(sp.edge_dst),
                jnp.asarray(sp.edge_slot), jnp.asarray(sp.edge_pos),
                jnp.asarray(sp.edge_mask))
        io = DistIO(
            spmm=self._spmm,
            args=args,
            sddmm_args=args,
            mean_w=self._put(
                mean_weights(sp.row_mask.reshape(-1, sp.fanout)),
                self._row_spec),
            mask_f=self._put(
                sp.row_mask.reshape(-1, sp.fanout).astype(np.float32),
                self._row_spec),
            sddmm=self._sddmm_fn(sp.fanout))
        with obs.span("dist.exchange") as xsp:
            src_rows = read_level(level, sp.src_ids.reshape(-1))
            H_src = self._put(src_rows, self._hd_spec)
            if xsp:
                nbytes = int(np.asarray(src_rows).nbytes)
                xsp.set(bytes=nbytes, rows=int(sp.n_src_rows),
                        level=level)
                obs.add("dist.exchanged_bytes", nbytes)
                obs.add("dist.src_rows", int(sp.n_src_rows))
        h_tgt = lambda: self._put(                       # noqa: E731
            read_level(level, sp.row_ids.reshape(-1)), self._hd_spec)
        H = run_layer(self, layer, io, h_tgt, H_src, heads)
        return H, sp.take, sp.n_src_rows


# ----------------------------------------------------------------------
# factory — backends resolve through the executor registry
# ----------------------------------------------------------------------

def _make_ref(mesh=None, **kw):
    return RefExecutor()


def _make_pallas(mesh=None, **kw):
    return PallasExecutor(**kw)


def _make_dist(mesh=None, **kw):
    if mesh is None:
        raise ValueError("dist executor needs a mesh= argument")
    return DistExecutor(mesh, **kw)


register_executor("ref", _make_ref)
register_executor("pallas", _make_pallas)
register_executor("dist", _make_dist)


def get_executor(executor="ref", *, mesh=None, **kw):
    """Resolve a REGISTERED executor name ("ref" | "pallas" | "dist" |
    anything added via ``api.registry.register_executor``) or pass an
    instance through.  "dist" needs a mesh.  Unknown names raise with
    every registered name listed."""
    if not isinstance(executor, str):
        return executor
    try:
        factory = EXECUTORS.get(executor)
    except KeyError as exc:
        raise ValueError(str(exc)) from None
    return factory(mesh=mesh, **kw)
