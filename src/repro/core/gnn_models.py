"""GNN models: parameter initializers and the DECLARATIVE layer specs.

The paper evaluates 3-layer GCN and GAT (4 heads).  Our GAT uses dot-product
attention (q.k per sampled edge) so that edge scoring exercises the SDDMM
primitive exactly as §3.4 describes; classic additive GAT decomposes into
node terms and would never need SDDMM.  Heads are laid out head-major in the
feature dim so each `model` shard belongs to one head (requires M % heads
== 0 in the distributed engine).

Each model's per-layer math is defined ONCE, as a sequence of declarative
layer ops (gemm / spmm / attn_scores / edge_softmax / attend / add) over
two input slots — ``h_tgt`` (rows being produced) and ``h_src`` (rows
being aggregated from; identical to ``h_tgt`` in full-graph inference,
the gathered universe in row-subset delta refresh).  ``core.ops``
interprets the spec against one of the interchangeable executors
(ref / pallas / dist), so no engine reimplements the layer math.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import MODELS, register_model


def init_gcn(rng, dims: List[int]) -> Dict[str, Any]:
    ks = jax.random.split(rng, len(dims) - 1)
    return {"w": [jax.random.normal(k, (dims[i], dims[i + 1]),
                                    jnp.float32) * (dims[i] ** -0.5)
                  for i, k in enumerate(ks)]}


def init_gat(rng, dims: List[int], heads: int = 4) -> Dict[str, Any]:
    layers = []
    for i in range(len(dims) - 1):
        k = jax.random.fold_in(rng, i)
        kq, kk, kv = jax.random.split(k, 3)
        s = dims[i] ** -0.5
        layers.append({
            "wq": jax.random.normal(kq, (dims[i], dims[i + 1]), jnp.float32) * s,
            "wk": jax.random.normal(kk, (dims[i], dims[i + 1]), jnp.float32) * s,
            "wv": jax.random.normal(kv, (dims[i], dims[i + 1]), jnp.float32) * s,
        })
    return {"layers": layers, "heads": heads}


def init_sage(rng, dims: List[int]) -> Dict[str, Any]:
    layers = []
    for i in range(len(dims) - 1):
        k = jax.random.fold_in(rng, i)
        k1, k2 = jax.random.split(k)
        s = dims[i] ** -0.5
        layers.append({
            "w_self": jax.random.normal(k1, (dims[i], dims[i + 1]),
                                        jnp.float32) * s,
            "w_nbr": jax.random.normal(k2, (dims[i], dims[i + 1]),
                                       jnp.float32) * s,
        })
    return {"layers": layers}


def mean_weights(mask: np.ndarray) -> np.ndarray:
    """Mean-aggregation edge weights from a fanout mask."""
    deg = np.maximum(mask.sum(axis=1, keepdims=True), 1)
    return (mask / deg).astype(np.float32)


def masked_softmax(scores: jax.Array, mask: jax.Array) -> jax.Array:
    s = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return p * mask


def gat_head_scores(q, kf, nbr, mask, heads: int):
    """Per-head dot scores (N, F, h) from full-width q/k (single host).
    kf rows may outnumber q rows (row-subset universe gather)."""
    N, D = q.shape
    dh = D // heads
    qh = q.reshape(N, heads, dh)
    kh = kf.reshape(-1, heads, dh)
    kn = jnp.take(kh, nbr.reshape(-1), axis=0).reshape(
        nbr.shape + (heads, dh))
    s = jnp.einsum("nhd,nfhd->nfh", qh, kn) / jnp.sqrt(jnp.float32(dh))
    return s


# ----------------------------------------------------------------------
# declarative layer specs (executed by core.ops — see module docstring)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerOp:
    """One declarative op inside a layer program.

    kind     gemm | spmm | add | attn_scores | edge_softmax | attend
    out      env slot written
    src      env slots read ("h_tgt"/"h_src" are the layer inputs)
    param    weight matrix (gemm only)
    """
    kind: str
    out: str
    src: Tuple[str, ...] = ()
    param: Any = None


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    ops: Tuple[LayerOp, ...]
    out: str = "h"


@dataclasses.dataclass
class ModelSpec:
    """One model == a sequence of LayerSpecs + head count + activation
    (applied between layers, not after the last)."""
    model: str
    layers: List[LayerSpec]
    heads: int
    activation: Callable


@dataclasses.dataclass(frozen=True)
class ModelPlugin:
    """A registered GNN model: ``init(key, dims, heads) -> params`` and
    ``spec(params) -> ModelSpec`` (the declarative layer program every
    executor interprets).  Third-party models register one of these
    under a new name (``api.registry.register_model``) and become legal
    ``DealConfig.model.name`` values everywhere — engines, delta
    refresh, serving — with zero core edits."""
    init: Callable
    spec: Callable


def _gcn_spec(params: Dict[str, Any]) -> ModelSpec:
    layers = [LayerSpec(ops=(
        LayerOp("gemm", "hw", ("h_src",), w),
        LayerOp("spmm", "h", ("hw",)),
    )) for w in params["w"]]
    return ModelSpec("gcn", layers, heads=1, activation=jax.nn.relu)


def _sage_spec(params: Dict[str, Any]) -> ModelSpec:
    layers = [LayerSpec(ops=(
        LayerOp("spmm", "agg", ("h_src",)),
        LayerOp("gemm", "own", ("h_tgt",), p["w_self"]),
        LayerOp("gemm", "nb", ("agg",), p["w_nbr"]),
        LayerOp("add", "h", ("own", "nb")),
    )) for p in params["layers"]]
    return ModelSpec("sage", layers, heads=1, activation=jax.nn.relu)


def _gat_spec(params: Dict[str, Any]) -> ModelSpec:
    layers = [LayerSpec(ops=(
        LayerOp("gemm", "q", ("h_tgt",), p["wq"]),
        LayerOp("gemm", "k", ("h_src",), p["wk"]),
        LayerOp("gemm", "v", ("h_src",), p["wv"]),
        LayerOp("attn_scores", "s", ("q", "k")),
        LayerOp("edge_softmax", "alpha", ("s",)),
        LayerOp("attend", "h", ("alpha", "v")),
    )) for p in params["layers"]]
    return ModelSpec("gat", layers, heads=int(params.get("heads", 1)),
                     activation=jax.nn.elu)


register_model("gcn", ModelPlugin(
    init=lambda key, dims, heads=1: init_gcn(key, dims), spec=_gcn_spec))
register_model("sage", ModelPlugin(
    init=lambda key, dims, heads=1: init_sage(key, dims), spec=_sage_spec))
register_model("gat", ModelPlugin(
    init=lambda key, dims, heads=1: init_gat(key, dims, heads=heads),
    spec=_gat_spec))


def model_spec(model: str, params: Dict[str, Any]) -> ModelSpec:
    """The single definition of each model's layer math, as data —
    resolved through the model registry so registered third-party
    models work everywhere the built-ins do."""
    try:
        plugin = MODELS.get(model)
    except KeyError as exc:
        raise ValueError(str(exc)) from None
    return plugin.spec(params)
