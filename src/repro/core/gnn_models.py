"""GNN models on top of the DEAL primitives: GCN, dot-GAT, GraphSAGE.

The paper evaluates 3-layer GCN and GAT (4 heads).  Our GAT uses dot-product
attention (q.k per sampled edge) so that edge scoring exercises the SDDMM
primitive exactly as §3.4 describes; classic additive GAT decomposes into
node terms and would never need SDDMM.  Heads are laid out head-major in the
feature dim so each `model` shard belongs to one head (requires M % heads
== 0 in the distributed engine).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def init_gcn(rng, dims: List[int]) -> Dict[str, Any]:
    ks = jax.random.split(rng, len(dims) - 1)
    return {"w": [jax.random.normal(k, (dims[i], dims[i + 1]),
                                    jnp.float32) * (dims[i] ** -0.5)
                  for i, k in enumerate(ks)]}


def init_gat(rng, dims: List[int], heads: int = 4) -> Dict[str, Any]:
    layers = []
    for i in range(len(dims) - 1):
        k = jax.random.fold_in(rng, i)
        kq, kk, kv = jax.random.split(k, 3)
        s = dims[i] ** -0.5
        layers.append({
            "wq": jax.random.normal(kq, (dims[i], dims[i + 1]), jnp.float32) * s,
            "wk": jax.random.normal(kk, (dims[i], dims[i + 1]), jnp.float32) * s,
            "wv": jax.random.normal(kv, (dims[i], dims[i + 1]), jnp.float32) * s,
        })
    return {"layers": layers, "heads": heads}


def init_sage(rng, dims: List[int]) -> Dict[str, Any]:
    layers = []
    for i in range(len(dims) - 1):
        k = jax.random.fold_in(rng, i)
        k1, k2 = jax.random.split(k)
        s = dims[i] ** -0.5
        layers.append({
            "w_self": jax.random.normal(k1, (dims[i], dims[i + 1]),
                                        jnp.float32) * s,
            "w_nbr": jax.random.normal(k2, (dims[i], dims[i + 1]),
                                       jnp.float32) * s,
        })
    return {"layers": layers}


def mean_weights(mask: np.ndarray) -> np.ndarray:
    """Mean-aggregation edge weights from a fanout mask."""
    deg = np.maximum(mask.sum(axis=1, keepdims=True), 1)
    return (mask / deg).astype(np.float32)


def masked_softmax(scores: jax.Array, mask: jax.Array) -> jax.Array:
    s = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return p * mask


def gat_head_scores(q, kf, nbr, mask, heads: int):
    """Per-head dot scores (N, F, h) from full-width q/k (single host)."""
    N, D = q.shape
    dh = D // heads
    qh = q.reshape(N, heads, dh)
    kh = kf.reshape(N, heads, dh)
    kn = jnp.take(kh, nbr.reshape(-1), axis=0).reshape(
        nbr.shape + (heads, dh))
    s = jnp.einsum("nhd,nfhd->nfh", qh, kn) / jnp.sqrt(jnp.float32(dh))
    return s
