"""1-D graph + feature collaborative partition and the static CommPlan.

DEAL's protocol ("send the non-zero column IDs, receive those H' rows") is
runtime-negotiated on CPUs; on TPU every message must be static-shaped, so
the partitioner resolves the negotiation AT PARTITION TIME: for every
(dst-partition p, ring step k) it precomputes the padded unique-row request
set and the edge-entry lists that consume the received buffer.  The graph is
a static input of all-node inference, so this loses no generality — it IS
the paper's ID exchange, hoisted to the plan.

Group structure == the paper's partitioned communication (§3.5): group 0 is
the local tile (Fig 11 "local first"), group k>0 holds the edges whose
source lives k hops around the data-axis ring.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro import obs
from repro.core.sampler import LayerGraph


@dataclasses.dataclass
class LayerPlan:
    """Static comm plan for one layer graph on a P x M grid."""
    P: int
    n_local: int                 # nodes per partition
    fanout: int
    # ring step k: device p sends rows send_local[p, k] to peer (p-k)%P and
    # receives the rows it requested from peer (p+k)%P.
    send_local: np.ndarray       # (P, P, R) int32, row ids local to sender
    send_count: np.ndarray       # (P, P)   int32 (valid prefix of R)
    # consuming the received buffer (k=0 consumes H_local directly):
    edge_dst: np.ndarray         # (P, P, E) int32 — local dst row
    edge_slot: np.ndarray        # (P, P, E) int32 — fanout slot of the edge
    edge_pos: np.ndarray         # (P, P, E) int32 — row in the recv buffer
    edge_mask: np.ndarray        # (P, P, E) bool
    # mirror for the graph-exchange baseline: at step k device q gathers the
    # per-edge source rows for peer (q-k)%P (duplicates included).
    mirror_src: np.ndarray       # (P, P, E) int32 — row local to the sender

    @property
    def max_request(self) -> int:
        return self.send_local.shape[-1]

    @property
    def max_entries(self) -> int:
        return self.edge_dst.shape[-1]


@dataclasses.dataclass
class PartitionPlan:
    n_nodes: int
    P: int
    M: int
    bounds: np.ndarray           # (P+1,)
    layers: List[LayerPlan]
    nbr_local: List[np.ndarray]  # per layer (P, n_local, F) partition-local view
    mask_local: List[np.ndarray]


def partition_nodes(n_nodes: int, P: int) -> np.ndarray:
    """1-D contiguous equal ranges (paper §3.3). n_nodes must divide by P
    for the static per-device shapes; callers pad the graph if needed."""
    assert n_nodes % P == 0, (n_nodes, P)
    return (np.arange(P + 1) * (n_nodes // P)).astype(np.int64)


def build_plan(layer_graphs: List[LayerGraph], P: int, M: int
               ) -> PartitionPlan:
    n = layer_graphs[0].n_nodes
    bounds = partition_nodes(n, P)
    n_local = n // P
    layers, nbrs, masks = [], [], []
    for lg in layer_graphs:
        layers.append(_layer_plan(lg, bounds, P))
        nbrs.append(lg.nbr.reshape(P, n_local, lg.fanout))
        masks.append(lg.mask.reshape(P, n_local, lg.fanout))
    return PartitionPlan(n_nodes=n, P=P, M=M, bounds=bounds, layers=layers,
                         nbr_local=nbrs, mask_local=masks)


def _layer_plan(lg: LayerGraph, bounds: np.ndarray, P: int) -> LayerPlan:
    n = lg.n_nodes
    n_local = n // P
    F = lg.fanout
    owner = np.searchsorted(bounds, lg.nbr, side="right") - 1

    req: List[List[np.ndarray]] = [[None] * P for _ in range(P)]
    entries = [[None] * P for _ in range(P)]
    for p in range(P):
        rows = slice(p * n_local, (p + 1) * n_local)
        nbr_p, mask_p, own_p = lg.nbr[rows], lg.mask[rows], owner[rows]
        for k in range(P):
            q = (p + k) % P
            sel = mask_p & (own_p == q)
            dst_loc, slot = np.nonzero(sel)
            ids = nbr_p[sel]
            if k == 0:
                # local group: positions index H_local directly
                uniq = np.empty(0, np.int64)
                pos = (ids - bounds[q]).astype(np.int64)
            else:
                uniq, pos = np.unique(ids, return_inverse=True)
                uniq = uniq - bounds[q]       # local to the source partition
            req[p][k] = uniq
            entries[p][k] = (dst_loc.astype(np.int32),
                             slot.astype(np.int32), pos.astype(np.int32),
                             (ids - bounds[q]).astype(np.int32))
    R = max(1, max(r.size for row in req for r in row))
    E = max(1, max(e[0].size for row in entries for e in row))

    send_local = np.zeros((P, P, R), np.int32)
    send_count = np.zeros((P, P), np.int32)
    edge_dst = np.zeros((P, P, E), np.int32)
    edge_slot = np.zeros((P, P, E), np.int32)
    edge_pos = np.zeros((P, P, E), np.int32)
    edge_mask = np.zeros((P, P, E), bool)
    mirror_src = np.zeros((P, P, E), np.int32)
    for p in range(P):
        for k in range(P):
            d, s, pos, src_loc = entries[p][k]
            m = d.size
            edge_dst[p, k, :m] = d
            edge_slot[p, k, :m] = s
            edge_pos[p, k, :m] = pos
            edge_mask[p, k, :m] = True
            # sender (p+k)%P ships these rows to p at ring step k:
            sender = (p + k) % P
            r = req[p][k]
            send_local[sender, k, :r.size] = r
            send_count[sender, k] = r.size
            mirror_src[sender, k, :m] = src_loc
    return LayerPlan(P=P, n_local=n_local, fanout=F, send_local=send_local,
                     send_count=send_count, edge_dst=edge_dst,
                     edge_slot=edge_slot, edge_pos=edge_pos,
                     edge_mask=edge_mask, mirror_src=mirror_src)


# ----------------------------------------------------------------------
# row-subset (frontier) plans — the distributed-delta-refresh machinery
# ----------------------------------------------------------------------

def pad_bucket(n: int, floor: int = 8) -> int:
    """Pad bucket: next power of two, floored, so varying frontier sizes
    share a small set of compiled shapes instead of minting one each."""
    return max(floor, 1 << max(0, int(n - 1).bit_length()))


@dataclasses.dataclass
class SubsetPlan:
    """Static comm plan for ONE layer restricted to a row subset, with the
    frontier split per partition by the SAME 1-D ownership as the full
    plan (so per-row reduction order — and therefore bitwise output —
    matches a full epoch through the same primitives).

    Row space: each partition p computes its own frontier rows, padded to
    a common pow2 bucket ``Rmax``; source rows are each partition's
    universe of requested ids, padded to ``Umax``.  ``edge_pos[p, 0]``
    indexes the LOCAL source tile (k == 0 consumes it directly);
    ``edge_pos[p, k>0]`` indexes the ring-step recv buffer, exactly like
    ``LayerPlan``.
    """
    P: int
    fanout: int
    row_ids: np.ndarray       # (P, Rmax) int64 global target ids (pads = 0)
    row_mask: np.ndarray      # (P, Rmax, F) bool fanout masks (False on pads)
    src_ids: np.ndarray       # (P, Umax) int64 global source ids per owner
    send_local: np.ndarray    # (P, P, R) int32 positions in sender src tile
    edge_dst: np.ndarray      # (P, P, E) int32 local target row
    edge_slot: np.ndarray     # (P, P, E) int32
    edge_pos: np.ndarray      # (P, P, E) int32
    edge_mask: np.ndarray     # (P, P, E) bool
    take: np.ndarray          # indices of real rows in the flat (P*Rmax) out
    n_src_rows: int           # unpadded universe total (work accounting)


def build_subset_plan(lg: LayerGraph, rows: np.ndarray, P: int,
                      *, m_align: int = 1, floor: int = 8,
                      n_nodes: Optional[int] = None) -> SubsetPlan:
    """Comm plan for recomputing ``rows`` of one layer on a P-way data
    axis.  ``rows`` must be sorted unique global ids; ``m_align`` forces
    the row buckets to a multiple of the model-axis size (the tiled
    all-to-all GEMM splits rows M ways).

    ``n_nodes`` overrides the partitioned node count: a tail-grown layer
    graph (incremental onboarding) keeps the ORIGINAL main-partition
    geometry — callers route rows that touch the tail elsewhere, and the
    plan here must keep deriving the same 1-D ownership (and therefore
    the same per-row reduction order) as before the growth."""
    rows = np.asarray(rows, np.int64)
    n, F = int(n_nodes or lg.n_nodes), lg.fanout
    assert rows.size == 0 or int(rows[-1]) < n, \
        "subset rows outside the partitioned range (route tail rows " \
        "to a local executor)"
    bounds = partition_nodes(n, P)
    floor = pad_bucket(max(floor, m_align))
    split = np.searchsorted(rows, bounds)
    counts = np.diff(split)
    Rmax = pad_bucket(int(counts.max()), floor)

    nbr_r, mask_r = lg.nbr[rows], lg.mask[rows]
    owner = np.searchsorted(bounds, nbr_r, side="right") - 1

    # per-owner source universes (union over all requesting partitions)
    uni: List[np.ndarray] = []
    for q in range(P):
        ids = nbr_r[mask_r & (owner == q)]
        uni.append(np.unique(ids.astype(np.int64)))
    Umax = pad_bucket(max(1, max(u.size for u in uni)), floor)
    src_ids = np.zeros((P, Umax), np.int64)
    for q in range(P):
        src_ids[q, :uni[q].size] = uni[q]
        # pad with ids already being read: pad values never reach real
        # outputs, but on a budgeted store a pad pointing at an evicted
        # row would trigger a spurious recompute (see gnnserve.delta)
        src_ids[q, uni[q].size:] = uni[q][0] if uni[q].size else rows[0]

    req: List[List[np.ndarray]] = [[None] * P for _ in range(P)]
    entries = [[None] * P for _ in range(P)]
    for p in range(P):
        sl = slice(split[p], split[p + 1])
        nbr_p, mask_p, own_p = nbr_r[sl], mask_r[sl], owner[sl]
        for k in range(P):
            q = (p + k) % P
            sel = mask_p & (own_p == q)
            dst_loc, slot = np.nonzero(sel)
            ids = nbr_p[sel].astype(np.int64)
            if k == 0:
                # local group: positions index the local source tile
                uniq = np.empty(0, np.int64)
                pos = np.searchsorted(uni[q], ids)
            else:
                uniq_ids, pos = np.unique(ids, return_inverse=True)
                uniq = np.searchsorted(uni[q], uniq_ids)
            req[p][k] = uniq
            entries[p][k] = (dst_loc.astype(np.int32),
                             slot.astype(np.int32), pos.astype(np.int32))
    R = pad_bucket(max(1, max(r.size for row in req for r in row)), floor)
    E = pad_bucket(max(1, max(e[0].size for row in entries for e in row)),
                   floor)

    send_local = np.zeros((P, P, R), np.int32)
    edge_dst = np.zeros((P, P, E), np.int32)
    edge_slot = np.zeros((P, P, E), np.int32)
    edge_pos = np.zeros((P, P, E), np.int32)
    edge_mask = np.zeros((P, P, E), bool)
    row_ids = np.zeros((P, Rmax), np.int64)
    row_mask = np.zeros((P, Rmax, F), bool)
    take = []
    for p in range(P):
        c = int(counts[p])
        row_ids[p, :c] = rows[split[p]:split[p + 1]]
        row_ids[p, c:] = rows[split[p]] if c else rows[0]   # see src_ids
        row_mask[p, :c] = mask_r[split[p]:split[p + 1]]
        take.append(p * Rmax + np.arange(c))
        for k in range(P):
            d, s, pos = entries[p][k]
            m = d.size
            edge_dst[p, k, :m] = d
            edge_slot[p, k, :m] = s
            edge_pos[p, k, :m] = pos
            edge_mask[p, k, :m] = True
            r = req[p][k]
            send_local[(p + k) % P, k, :r.size] = r
    return SubsetPlan(P=P, fanout=F, row_ids=row_ids, row_mask=row_mask,
                      src_ids=src_ids, send_local=send_local,
                      edge_dst=edge_dst, edge_slot=edge_slot,
                      edge_pos=edge_pos, edge_mask=edge_mask,
                      take=np.concatenate(take) if take else
                      np.empty(0, np.int64),
                      n_src_rows=int(sum(u.size for u in uni)))


# -- frontier-signature plan cache -------------------------------------
#
# ``build_subset_plan`` is pure numpy and runs per refreshed layer; a hot
# frontier hit repeatedly by recompute-on-miss (the budgeted store's
# eviction escape hatch) would otherwise rebuild the identical plan every
# time (ROADMAP: subset-plan build off the hot path).  Plans are cached
# ON the layer graph keyed by the frontier signature — a hash of the
# sorted row ids plus everything the partition bounds derive from
# (P / n_nodes / m_align / floor).  ``resample_rows`` mutates the layer
# graph in place, so it must call ``invalidate_subset_plans``.

SUBSET_PLAN_CACHE = {"hits": 0, "misses": 0}   # process-global aggregate
_COUNTER_SCOPES: List[dict] = []
_SUBSET_CACHE_ATTR = "_subset_plan_cache"
_SUBSET_CACHE_CAP = 64          # plans are small; bound pathological churn


def install_plan_cache_counters() -> dict:
    """Open a fresh hit/miss counter scope and return it.

    Counts are mirrored into every installed scope AND the process-global
    aggregate, so a `Session` can report its own cache behaviour without
    seeing traffic from other sessions in the same process (config
    sweeps, the test suite).  Pair with ``uninstall_plan_cache_counters``."""
    c = {"hits": 0, "misses": 0}
    _COUNTER_SCOPES.append(c)
    return c


def uninstall_plan_cache_counters(counters: dict) -> None:
    try:
        _COUNTER_SCOPES.remove(counters)
    except ValueError:
        pass                     # idempotent: double-close is fine


def subset_plan_cache_stats() -> dict:
    """Compat alias: innermost installed scope, else the global aggregate."""
    return dict(_COUNTER_SCOPES[-1] if _COUNTER_SCOPES else SUBSET_PLAN_CACHE)


def _count_plan_cache(key: str) -> None:
    SUBSET_PLAN_CACHE[key] += 1
    for c in _COUNTER_SCOPES:
        c[key] += 1
    obs.add(f"plan_cache.{key}")


def invalidate_subset_plans(lg: LayerGraph) -> None:
    """Drop cached frontier plans after an in-place layer-graph mutation."""
    getattr(lg, _SUBSET_CACHE_ATTR, {}).clear()


def build_subset_plan_cached(lg: LayerGraph, rows: np.ndarray, P: int,
                             *, m_align: int = 1, floor: int = 8,
                             n_nodes: Optional[int] = None) -> SubsetPlan:
    """``build_subset_plan`` memoized per (layer graph, frontier
    signature).  Safe because plans depend only on (lg.nbr, lg.mask,
    rows, P, n_nodes, m_align, floor) and every nbr/mask mutation goes
    through ``resample_rows`` -> ``invalidate_subset_plans``."""
    rows = np.asarray(rows, np.int64)
    n = int(n_nodes or lg.n_nodes)
    cache = getattr(lg, _SUBSET_CACHE_ATTR, None)
    if cache is None:
        cache = {}
        setattr(lg, _SUBSET_CACHE_ATTR, cache)
    # the row bytes themselves, not their hash: a 64-bit hash collision
    # would silently return another frontier's exchange plan, and the
    # key bytes are tiny next to the cached plan arrays
    key = (P, m_align, floor, n, rows.tobytes())
    plan = cache.get(key)
    if plan is not None:
        _count_plan_cache("hits")
        return plan
    _count_plan_cache("misses")
    if len(cache) >= _SUBSET_CACHE_CAP:
        cache.pop(next(iter(cache)))    # FIFO drop-one: clearing all
        # would also evict the hot frontier the cache exists to keep
    with obs.span("dist.subset_plan_build") as sp:
        plan = build_subset_plan(lg, rows, P, m_align=m_align,
                                 floor=floor, n_nodes=n)
        if sp:
            sp.set(rows=int(rows.size), P=P)
    cache[key] = plan
    return plan


def comm_volume(plan: PartitionPlan, d_feature: int, bytes_per: int = 4
                ) -> dict:
    """Analytic per-layer communication volumes (Tables 1-3 checks)."""
    out = {}
    for i, lp in enumerate(plan.layers):
        deal = int(lp.send_count[:, 1:].sum()) * (d_feature // plan.M)
        dup_edges = int(lp.edge_mask[:, 1:].sum())
        graph_exch = dup_edges * (d_feature // plan.M)
        out[f"layer{i}"] = {
            "deal_feature_exchange_B": deal * bytes_per,
            "graph_exchange_B": graph_exch * bytes_per,
            "unique_rows": int(lp.send_count[:, 1:].sum()),
            "duplicated_edge_rows": dup_edges,
        }
    return out
