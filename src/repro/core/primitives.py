"""DEAL's distributed GNN primitives (§3.4) in shard_map, plus the paper's
baselines (CAGNET-style GEMM, graph-exchange SPMM, SDDMM approach (i),
monolithic all-gather SPMM) for the benchmark comparisons.

Mesh geometry: ("data", "model") == DEAL's (P, M) grid.  All collectives
are explicit jax.lax calls so the communication schedule is exactly the
paper's: ring ppermute of requested feature rows (SPMM), two tiled
all-to-alls (GEMM), edge-scalar psum (SDDMM approach (ii)).

These primitives are consumed through ``core.ops.DistExecutor`` (the
distributed backend of the pluggable executor layer); the ``make_*_p``
factories build jitted shard_map calls keyed only on static geometry
(P, fanout, variant) so one compiled function serves every layer — and
every row-subset refresh — with the same shapes.  The edge plans are
runtime arguments, so full-graph plans (``core.partition.build_plan``)
and frontier-subset plans (``build_subset_plan``) flow through the same
compiled collectives.

The single-host ``ref_*`` oracles are re-exported from ``kernels.ref``
— one canonical definition shared with the Pallas kernel tests, so the
two copies can never drift.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.partition import LayerPlan
from repro.kernels.ref import gemm_ref as ref_gemm
from repro.kernels.ref import sddmm_ref as ref_sddmm
from repro.kernels.ref import spmm_ref as ref_spmm
from repro.sharding.compat import shard_map

__all__ = [
    "make_gemm", "make_spmm", "make_spmm_p", "make_sddmm", "make_sddmm_p",
    "plan_device_arrays", "ref_gemm", "ref_spmm", "ref_sddmm",
]


# ----------------------------------------------------------------------
# GEMM
# ----------------------------------------------------------------------

def _gemm_deal_local(H, W):
    """DEAL GEMM (Fig 7b): reshard rows over `model` with a tiled
    all-to-all, multiply with the replicated W, reshard back."""
    full = jax.lax.all_to_all(H, "model", split_axis=0, concat_axis=1,
                              tiled=True)              # (n/M, D)
    out = jnp.dot(full, W, preferred_element_type=jnp.float32)
    out = out.astype(H.dtype)
    return jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                              tiled=True)              # (n, D_out/M)


def _gemm_cagnet_local(H, W):
    """CAGNET-style allreduce GEMM (Fig 7a): full-width partials + column
    reduce-scatter.  (M-1)/M * n * D_out comm vs DEAL's 2(M-1)/M * n*D/M."""
    m = jax.lax.axis_index("model")
    d_loc = H.shape[1]
    w_slice = jax.lax.dynamic_slice_in_dim(W, m * d_loc, d_loc, 0)
    partial = jnp.dot(H, w_slice, preferred_element_type=jnp.float32)
    out = jax.lax.psum_scatter(partial, "model", scatter_dimension=1,
                               tiled=True)
    return out.astype(H.dtype)


def _gemm_deal_ring_local(H, W, *, M: int):
    """DEAL GEMM with the explicit M-1-stage ring of Fig 7(b): at stage k
    each device ships the row-block addressed k hops away and ACCUMULATES
    the arriving chunk against the matching W row-slice, so stage k's
    matmul overlaps stage k+1's ppermute (the paper's pipelining)."""
    m = jax.lax.axis_index("model")
    n_loc, d_loc = H.shape
    blocks = H.reshape(M, n_loc // M, d_loc)

    def w_slice(j):
        return jax.lax.dynamic_slice_in_dim(W, j * d_loc, d_loc, 0)

    acc = jnp.dot(jnp.take(blocks, m, axis=0), w_slice(m),
                  preferred_element_type=jnp.float32)
    for k in range(1, M):
        send = jnp.take(blocks, (m + k) % M, axis=0)
        perm = [(i, (i + k) % M) for i in range(M)]
        recv = jax.lax.ppermute(send, "model", perm)
        acc = acc + jnp.dot(recv, w_slice((m - k) % M),
                            preferred_element_type=jnp.float32)
    out = acc.astype(H.dtype)                       # (n/M, D_out)
    return jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                              tiled=True)           # (n, D_out/M)


def make_gemm(mesh, variant: str = "deal"):
    if variant == "deal_ring":
        fn = functools.partial(_gemm_deal_ring_local,
                               M=mesh.shape["model"])
    else:
        fn = (_gemm_deal_local if variant == "deal"
              else _gemm_cagnet_local)
    return jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(P("data", "model"), P(None, None)),
        out_specs=P("data", "model")))


# ----------------------------------------------------------------------
# SPMM
# ----------------------------------------------------------------------

def _ring_bufs(H, send_local, P_: int):
    """Return the list of recv buffers for ring steps k = 1..P-1; buffer
    k-1 holds the rows this device requested from peer (p+k)%P.  All
    ppermutes are issued before any consumer runs — the monolithic
    (ungrouped) communication schedule."""
    bufs = []
    for k in range(1, P_):
        rows = jnp.take(H, send_local[k], axis=0)
        perm = [(i, (i - k) % P_) for i in range(P_)]
        bufs.append(jax.lax.ppermute(rows, "data", perm))
    return bufs


def _accumulate(out, w, buf, dst, slot, pos, mask):
    vals = jnp.take(buf, pos, axis=0).astype(jnp.float32)
    vals = vals * (w[dst, slot] * mask).astype(jnp.float32)[:, None]
    return out.at[dst].add(vals)


def _spmm_deal_local(H, w, send_local, edge_dst, edge_slot, edge_pos,
                     edge_mask, *, P_: int, grouped: bool = True):
    """DEAL SPMM: ship only requested unique rows; grouped accumulation.

    H (u_loc, d_loc) source rows; w (r_loc, F) edge weights — output rows
    follow w, so a frontier subset (r_loc < u_loc) runs through the same
    compiled collective as the full graph (r_loc == u_loc).  Plan arrays
    squeezed to this device: send_local (P, R), edge_* (P, E).
    """
    d_loc = H.shape[1]
    out = jnp.zeros((w.shape[0], d_loc), jnp.float32)
    # group 0: local tile first (Fig 12c — covers pipeline fill)
    out = _accumulate(out, w, H, edge_dst[0], edge_slot[0], edge_pos[0],
                      edge_mask[0])
    if grouped:
        for k in range(1, P_):
            rows = jnp.take(H, send_local[k], axis=0)
            perm = [(i, (i - k) % P_) for i in range(P_)]
            buf = jax.lax.ppermute(rows, "data", perm)
            out = _accumulate(out, w, buf, edge_dst[k], edge_slot[k],
                              edge_pos[k], edge_mask[k])
    else:
        # monolithic: all communication completes before any compute
        bufs = _ring_bufs(H, send_local, P_)
        for k in range(1, P_):
            out = _accumulate(out, w, bufs[k - 1], edge_dst[k],
                              edge_slot[k], edge_pos[k], edge_mask[k])
    return out.astype(H.dtype)


def _spmm_allgather_local(H, w, nbr, mask, *, P_: int):
    """Graph-partition-only baseline (Fig 3b): all-gather the FULL feature
    tile over `data` then gather locally — the memory blowup DEAL avoids."""
    full = jax.lax.all_gather(H, "data", axis=0, tiled=True)  # (N, d_loc)
    vals = jnp.take(full, nbr.reshape(-1), axis=0).astype(jnp.float32)
    vals = vals.reshape(nbr.shape + (H.shape[1],))
    out = (vals * (w * mask).astype(jnp.float32)[..., None]).sum(axis=1)
    return out.astype(H.dtype)


def _spmm_graph_exchange_local(H, w, mirror_src, edge_dst, edge_slot,
                               edge_mask, *, P_: int):
    """'Exchange G0' baseline (§3.4): the SOURCE owner gathers per-edge rows
    (duplicates included) and ships them to the destination — Z x more
    traffic than DEAL's unique-row exchange."""
    d_loc = H.shape[1]
    out = jnp.zeros((w.shape[0], d_loc), jnp.float32)
    # k=0: mirror_src == local row ids for the local group
    out = _accumulate(out, w, H, edge_dst[0], edge_slot[0], mirror_src[0],
                      edge_mask[0])
    for k in range(1, P_):
        contrib = jnp.take(H, mirror_src[k], axis=0)       # (E, d_loc) dup!
        perm = [(i, (i - k) % P_) for i in range(P_)]
        buf = jax.lax.ppermute(contrib, "data", perm)
        vals = buf.astype(jnp.float32) * \
            (w[edge_dst[k], edge_slot[k]] * edge_mask[k]).astype(
                jnp.float32)[:, None]
        out = out.at[edge_dst[k]].add(vals)
    return out.astype(H.dtype)


def _squeeze0(x):
    return x[0]


def make_spmm_p(mesh, P_: int, variant: str = "deal",
                grouped: bool = True):
    """Jitted SPMM keyed on static geometry only (P, variant, grouped);
    the per-layer plan tensors are runtime arguments, so one compiled
    function serves every layer and every frontier-subset plan."""
    plan_spec = P("data", None, None)

    if variant == "allgather":
        def fn(H, w, nbr, mask):
            return _spmm_allgather_local(H, w, nbr[0], mask[0], P_=P_)
        return jax.jit(shard_map(
            fn, mesh=mesh,
            in_specs=(P("data", "model"), P("data", None),
                      P("data", None, None), P("data", None, None)),
            out_specs=P("data", "model")))

    if variant == "graph_exchange":
        def fn(H, w, mirror_src, edge_dst, edge_slot, edge_mask):
            return _spmm_graph_exchange_local(
                H, w, mirror_src[0], edge_dst[0], edge_slot[0],
                edge_mask[0], P_=P_)
        return jax.jit(shard_map(
            fn, mesh=mesh,
            in_specs=(P("data", "model"), P("data", None)) +
            (plan_spec,) * 4,
            out_specs=P("data", "model")))

    def fn(H, w, send_local, edge_dst, edge_slot, edge_pos, edge_mask):
        return _spmm_deal_local(
            H, w, send_local[0], edge_dst[0], edge_slot[0], edge_pos[0],
            edge_mask[0], P_=P_, grouped=grouped)
    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(P("data", "model"), P("data", None)) + (plan_spec,) * 5,
        out_specs=P("data", "model")))


def make_spmm(mesh, lp: LayerPlan, variant: str = "deal",
              grouped: bool = True):
    return make_spmm_p(mesh, lp.P, variant, grouped)


# ----------------------------------------------------------------------
# SDDMM
# ----------------------------------------------------------------------

def _sddmm_deal_local(q, kf, send_local, edge_dst, edge_slot, edge_pos,
                      edge_mask, *, P_: int, fanout: int):
    """Approach (ii): partial dots over this device's D/M slice, then psum
    the edge SCALARS over `model` (exchange results, not features)."""
    n_loc = q.shape[0]
    attn = jnp.zeros((n_loc, fanout), jnp.float32)

    def acc(attn, buf, k):
        part = (jnp.take(q, edge_dst[k], axis=0).astype(jnp.float32)
                * jnp.take(buf, edge_pos[k], axis=0).astype(jnp.float32)
                ).sum(-1)
        part = part * edge_mask[k]
        return attn.at[edge_dst[k], edge_slot[k]].add(part)

    attn = acc(attn, kf, 0)
    for k in range(1, P_):
        rows = jnp.take(kf, send_local[k], axis=0)
        perm = [(i, (i - k) % P_) for i in range(P_)]
        buf = jax.lax.ppermute(rows, "data", perm)
        attn = acc(attn, buf, k)
    return jax.lax.psum(attn, "model")


def _sddmm_dup_local(q, kf, send_local, edge_dst, edge_slot, edge_pos,
                     edge_mask, *, P_: int, fanout: int):
    """Approach (i): all-gather the FULL feature columns over `model`
    (duplicate the computation), no result exchange."""
    qf = jax.lax.all_gather(q, "model", axis=1, tiled=True)   # (n_loc, D)
    kff = jax.lax.all_gather(kf, "model", axis=1, tiled=True)
    n_loc = q.shape[0]
    attn = jnp.zeros((n_loc, fanout), jnp.float32)

    def acc(attn, buf, k):
        part = (jnp.take(qf, edge_dst[k], axis=0).astype(jnp.float32)
                * jnp.take(buf, edge_pos[k], axis=0).astype(jnp.float32)
                ).sum(-1)
        return attn.at[edge_dst[k], edge_slot[k]].add(part * edge_mask[k])

    attn = acc(attn, kff, 0)
    for k in range(1, P_):
        rows = jnp.take(kff, send_local[k], axis=0)
        perm = [(i, (i - k) % P_) for i in range(P_)]
        buf = jax.lax.ppermute(rows, "data", perm)
        attn = acc(attn, buf, k)
    return attn


def make_sddmm_p(mesh, P_: int, fanout: int, variant: str = "deal"):
    """Jitted SDDMM keyed on static geometry only (P, fanout, variant) —
    see ``make_spmm_p``."""
    local = _sddmm_deal_local if variant == "deal" else _sddmm_dup_local
    plan_spec = P("data", None, None)

    def fn(q, kf, send_local, edge_dst, edge_slot, edge_pos, edge_mask):
        return local(q, kf, send_local[0], edge_dst[0], edge_slot[0],
                     edge_pos[0], edge_mask[0], P_=P_, fanout=fanout)
    # approach (i) duplicates the computation, so its output is replicated
    # over `model` by construction — not statically inferable (check_vma).
    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(P("data", "model"), P("data", "model")) + (plan_spec,) * 5,
        out_specs=P("data", None), check_vma=(variant == "deal")))


def make_sddmm(mesh, lp: LayerPlan, variant: str = "deal"):
    return make_sddmm_p(mesh, lp.P, lp.fanout, variant)


# ----------------------------------------------------------------------
# single-host references: re-exported from kernels.ref (see module
# docstring) — ref_gemm / ref_spmm / ref_sddmm are bound in the imports.
# ----------------------------------------------------------------------

def plan_device_arrays(lp: LayerPlan) -> Dict[str, Any]:
    """The per-layer plan tensors shipped to devices (leading dim = P,
    sharded over `data`)."""
    return {
        "send_local": jnp.asarray(lp.send_local),
        "edge_dst": jnp.asarray(lp.edge_dst),
        "edge_slot": jnp.asarray(lp.edge_slot),
        "edge_pos": jnp.asarray(lp.edge_pos),
        "edge_mask": jnp.asarray(lp.edge_mask),
        "mirror_src": jnp.asarray(lp.mirror_src),
    }
