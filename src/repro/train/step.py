"""train_step: fwd (chunked CE) + bwd + AdamW, one jittable function."""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.train.loss import chunked_softmax_xent
from repro.train.optimizer import AdamWConfig, OptState, adamw_update

AUX_LOSS_WEIGHT = 0.01


def _head(cfg, params):
    return (params["embed"].T.astype(jnp.dtype(cfg.dtype))
            if cfg.tie_embeddings else params["lm_head"])


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, Any]):
    hidden, aux = transformer.forward(cfg, params, batch, mode="train",
                                      return_hidden=True)
    labels = batch["labels"]
    # vlm: hidden includes image positions; score text positions only
    if cfg.family == "vlm":
        hidden = hidden[:, cfg.n_frontend_tokens:]
    ce = chunked_softmax_xent(hidden, _head(cfg, params), labels)
    return ce + AUX_LOSS_WEIGHT * aux, (ce, aux)


def train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, params,
               opt_state: OptState, batch):
    (total, (ce, aux)), grads = jax.value_and_grad(
        functools.partial(loss_fn, cfg), has_aux=True)(params, batch)
    params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                              opt_cfg)
    metrics.update({"loss": ce, "aux_loss": aux, "total_loss": total})
    return params, opt_state, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    return functools.partial(train_step, cfg, opt_cfg)
