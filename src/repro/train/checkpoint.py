"""Checkpointing: flatten a pytree to a .npz with path-encoded keys.

Sharding-aware: arrays are fetched with jax.device_get (gathering shards),
and restore re-places them under the sharding of a reference tree when one
is given.  Deliberately dependency-free (no orbax offline).
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Optional

import jax
import numpy as np


_SEP = "::"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":   # npz has no bf16: store f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(path, params, opt_state=None, step: int = 0,
                    metadata: Optional[dict] = None):
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    blobs = {f"params{_SEP}{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        blobs.update({f"opt{_SEP}{k}": v
                      for k, v in _flatten(opt_state).items()})
    np.savez(path, __step__=np.int64(step),
             __meta__=json.dumps(metadata or {}), **blobs)


def restore_checkpoint(path, params_like, opt_like=None, sharding=None):
    """Restore into the structure of `params_like` (and `opt_like`)."""
    z = np.load(path, allow_pickle=False)
    step = int(z["__step__"])

    def fill(prefix, like):
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        import jax.numpy as jnp
        for path, leaf in leaves_p:
            key = prefix + _SEP + _SEP.join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = z[key]
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = jnp.asarray(arr).astype(leaf.dtype)  # handles bf16
            out.append(arr)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out)

    params = fill("params", params_like)
    if sharding is not None:
        params = jax.device_put(params, sharding)
    if opt_like is None:
        return params, step
    opt = fill("opt", opt_like)
    return params, opt, step
