"""Chunked cross-entropy: never materializes (B, S, V) logits.

The unembedding + CE is computed per sequence chunk under lax.scan with a
checkpoint on the chunk body, so both fwd and bwd peak at (B, chunk, V).
This is what lets 200k-vocab models train at 4k x 256 on 16 GB chips.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.context import constrain


def chunked_softmax_xent(hidden, head, labels, *, chunk: int = 512,
                         mask=None):
    """hidden: (B, S, D); head: (D, V); labels: (B, S) int32.

    Returns mean NLL over unmasked positions.
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    pad = (-S) % chunk
    if pad:                       # ragged (e.g. vlm text span): mask the pad
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    nc = S // chunk
    hid = hidden.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    lab = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    msk = mask.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h, l, m = xs
        logits = constrain(
            jnp.einsum("bsd,dv->bsv", h, head).astype(jnp.float32),
            "dp", None, "tp")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hid, lab, msk))
    return tot / jnp.maximum(cnt, 1.0)
