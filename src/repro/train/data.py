"""Data pipeline: deterministic synthetic LM stream + file-backed corpus.

The synthetic stream generates structured (learnable) token sequences — a
noisy order-2 Markov chain — so train_lm.py shows a real loss curve, not
noise memorization.  The file pipeline memory-maps a token .npy and yields
sharded batches with host prefetch.
"""
from __future__ import annotations

import dataclasses
import threading
import queue
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    corpus_path: Optional[str] = None


def _markov_tables(vocab: int, seed: int):
    rng = np.random.default_rng(seed)
    # sparse order-2 structure: each (a, b) strongly prefers 4 successors
    prefer = rng.integers(0, vocab, size=(vocab, 4))
    return prefer


def synthetic_batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(cfg.seed)
    prefer = _markov_tables(cfg.vocab_size, cfg.seed + 1)
    B, S = cfg.batch_size, cfg.seq_len
    while True:
        tok = np.empty((B, S + 1), np.int32)
        tok[:, 0] = rng.integers(0, cfg.vocab_size, B)
        for t in range(S):
            choice = prefer[tok[:, t], rng.integers(0, 4, B)]
            noise = rng.integers(0, cfg.vocab_size, B)
            use_noise = rng.random(B) < 0.1
            tok[:, t + 1] = np.where(use_noise, noise, choice)
        yield {"tokens": tok[:, :S], "labels": tok[:, 1:]}


def file_batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    data = np.load(cfg.corpus_path, mmap_mode="r")
    B, S = cfg.batch_size, cfg.seq_len
    n = (data.shape[0] - 1) // S
    rng = np.random.default_rng(cfg.seed)
    while True:
        idx = rng.integers(0, n, B)
        tok = np.stack([data[i * S:i * S + S + 1] for i in idx])
        yield {"tokens": tok[:, :S].astype(np.int32),
               "labels": tok[:, 1:].astype(np.int32)}


def make_pipeline(cfg: DataConfig, prefetch: int = 2
                  ) -> Iterator[Dict[str, np.ndarray]]:
    """Host-thread prefetching wrapper."""
    src = (file_batches(cfg) if cfg.corpus_path else synthetic_batches(cfg))
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        for b in src:
            if stop.is_set():
                return
            q.put(b)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
