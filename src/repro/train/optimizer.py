"""In-house AdamW + LR schedules (optax is not available offline)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"   # bf16 for the >200B MoE configs


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def abstract_opt_state(abstract_params, cfg: AdamWConfig) -> OptState:
    return jax.eval_shape(lambda p: init_opt_state(p, cfg), abstract_params)


def lr_schedule(step, cfg: AdamWConfig):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * cfg.lr * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    dt = jnp.dtype(cfg.state_dtype)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    # bf16 state -> bf16 math: avoids materializing f32 twins of the whole
    # (stacked-expert) parameter tree during the update — the dominant temp
    # buffer for the >200B MoE configs (EXPERIMENTS.md §Perf H2/iter-3).
    mdt = jnp.float32 if dt == jnp.float32 else jnp.bfloat16

    def upd(p, g, m, v):
        g = g.astype(mdt) * scale.astype(mdt)
        m_new = (cfg.b1 * m.astype(mdt) + (1 - cfg.b1) * g).astype(mdt)
        v_new = (cfg.b2 * v.astype(mdt)
                 + (1 - cfg.b2) * jnp.square(g)).astype(mdt)
        mhat = m_new / b1c.astype(mdt)
        vhat = v_new.astype(jnp.float32) / b2c
        delta = mhat.astype(jnp.float32) / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(dt), v_new.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
