"""whisper-base [audio] — encoder-decoder; conv/mel frontend is a STUB.

Source: [arXiv:2212.04356]: 6L (enc) + 6L (dec) d_model=512 8H d_ff=2048
vocab=51865.  input_specs() supplies precomputed frame embeddings (the mel +
conv feature extractor is the allowed stub).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=6,                  # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    qkv_bias=True,
    is_encoder_decoder=True,
    n_encoder_layers=6,
    frontend="audio",
    frontend_dim=512,            # post-conv frame embedding dim
    n_frontend_tokens=1500,      # 30s audio -> 1500 frames
)
