"""qwen2.5-14b [dense] — GQA with QKV bias.

Source: [hf:Qwen/Qwen2.5-0.5B] family card at the assigned 14B shape:
48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-14b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152_064,
    rope_theta=1_000_000.0,
    qkv_bias=True,
)
