"""llava-next-34b [vlm] — anyres tiling; ViT encoder + projector are STUBS.

Source: [hf:llava-hf/llava-v1.6-mistral-7b-hf] family card at the assigned
34B backbone shape: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
input_specs() supplies precomputed patch embeddings (anyres: base 576 patches
+ up to 4 tiles -> 2880 image tokens).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
    frontend="vision",
    frontend_dim=1024,           # CLIP/SigLIP patch embedding dim
    n_frontend_tokens=2880,      # anyres: 576 base + 4x576 tiles
)
