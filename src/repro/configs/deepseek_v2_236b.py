"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

Source: [arXiv:2405.04434]: 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400; MLA q_lora=1536 kv_lora=512 rope_dim=64 nope_dim=128 v_dim=128;
first layer dense with d_ff=12288.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,              # MLA: all heads share the latent kv
    head_dim=192,                # nope 128 + rope 64
    d_ff=12288,                  # dense first-layer hidden size
    vocab_size=102_400,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        n_shared_experts=2,
        d_ff_expert=1536,
        period=1,
        first_dense_layers=1,
        d_ff_dense=12288,
    ),
)
