"""llama4-maverick-400b-a17b [moe] — interleaved MoE, early fusion.

Source: [hf:meta-llama/Llama-4-Scout-17B-16E] family card, assigned Maverick
shape: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 128 routed
experts top-1 + 1 shared expert, MoE every other layer (interleave step 2,
matching the ~400B-total / 17B-active budget).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,                   # dense-layer hidden size
    vocab_size=202_048,
    rope_theta=500_000.0,
    moe=MoEConfig(
        n_experts=128,
        top_k=1,
        n_shared_experts=1,
        d_ff_expert=8192,
        period=2,                # MoE every other layer
        d_ff_dense=16384,        # interleaved dense MLPs
    ),
)
