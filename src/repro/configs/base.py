"""Config dataclasses for the architecture zoo and the input-shape suite.

Every assigned architecture gets one file in this package instantiating
:class:`ModelConfig` with the exact assigned numbers (source cited in the
file header).  ``reduced()`` derives the CPU smoke-test variant (<=2 layers,
d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 1
    n_shared_experts: int = 0
    d_ff_expert: int = 0          # per-expert hidden size
    period: int = 1               # MoE every `period` layers (1 = every layer)
    first_dense_layers: int = 0   # leading dense layers (deepseek-v2)
    capacity_factor: float = 1.25
    d_ff_dense: int = 0           # hidden size of the interleaved dense MLPs


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims [arXiv:2405.04434]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD dims [arXiv:2405.21060]."""
    d_state: int = 128
    d_inner: int = 0              # = expand * d_model
    n_heads: int = 0              # d_inner // head_dim
    head_dim: int = 64
    d_conv: int = 4
    chunk_size: int = 256
    n_groups: int = 1             # B/C groups


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    source: str                   # citation for the numbers
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- sliding-window / local-global pattern (gemma3) ---
    sliding_window: Optional[int] = None
    global_interval: int = 0      # every Nth layer is global (0 = all global)
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- MLA (replaces GQA when set) ---
    mla: Optional[MLAConfig] = None
    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    attn_interval: int = 0        # hybrid: shared attn block every N ssm layers
    shared_attn_lora_rank: int = 0
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # --- modality frontend stub ---
    frontend: Optional[str] = None   # 'audio' | 'vision'
    frontend_dim: int = 0            # raw embedding dim fed to the projector
    n_frontend_tokens: int = 0       # image/audio token budget inside the sequence
    # --- numerics ---
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM, hybrid, or sliding-window dense)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have no decode step; all assigned archs decode."""
        return True

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for layer in range(self.n_layers):
            n += self._layer_params(layer)
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                n += self._attn_params() + 2 * self.d_ff * d + d * self.d_ff
        if self.frontend:
            n += self.frontend_dim * d  # projector stub
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        m = self.moe
        for layer in range(self.n_layers):
            n += self._attn_params()
            if self._is_moe_layer(layer):
                active = m.top_k + m.n_shared_experts
                n += active * 3 * d * m.d_ff_expert + d * m.n_experts  # + router
            else:
                n += 3 * d * (m.d_ff_dense or self.d_ff)
        return n

    def _is_moe_layer(self, layer: int) -> bool:
        if self.moe is None:
            return False
        if layer < self.moe.first_dense_layers:
            return False
        return (layer - self.moe.first_dense_layers) % self.moe.period == 0

    def _attn_params(self) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        if self.mla is not None:
            a = self.mla
            qh = a.nope_head_dim + a.rope_head_dim
            n = d * a.q_lora_rank + a.q_lora_rank * self.n_heads * qh
            n += d * (a.kv_lora_rank + a.rope_head_dim)
            n += a.kv_lora_rank * self.n_heads * (a.nope_head_dim + a.v_head_dim)
            n += self.n_heads * a.v_head_dim * d
            return n
        return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

    def _layer_params(self, layer: int) -> int:
        d = self.d_model
        if self.family == "ssm":
            s = self.ssm
            return 2 * d * s.d_inner + s.d_inner * d + s.d_inner * (2 * s.d_state)
        n = 0
        is_ssm_layer = self.family == "hybrid" and not self._is_hybrid_attn(layer)
        if is_ssm_layer:
            s = self.ssm
            n += 2 * d * s.d_inner + s.d_inner * d + s.d_inner * (2 * s.d_state)
        else:
            n += self._attn_params()
        if self.moe is not None and self._is_moe_layer(layer):
            m = self.moe
            n += (m.n_experts + m.n_shared_experts) * 3 * d * m.d_ff_expert
            n += d * m.n_experts
        elif not is_ssm_layer and self.d_ff:
            n += 3 * d * self.d_ff
        return n

    def _is_hybrid_attn(self, layer: int) -> bool:
        return self.attn_interval > 0 and (layer + 1) % self.attn_interval == 0

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        changes = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            frontend_dim=min(self.frontend_dim, 128) if self.frontend_dim else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else None,
            global_interval=min(self.global_interval, 2) if self.global_interval else 0,
            attn_interval=min(self.attn_interval, 2) if self.attn_interval else 0,
            shared_attn_lora_rank=min(self.shared_attn_lora_rank, 8)
            if self.shared_attn_lora_rank else 0,
        )
        if self.n_kv_heads == self.n_heads:     # MHA stays MHA
            changes["n_kv_heads"] = changes["n_heads"]
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                d_ff_expert=128, first_dense_layers=min(self.moe.first_dense_layers, 1),
                period=min(self.moe.period, 2) if self.moe.period > 1 else 1,
                d_ff_dense=min(self.moe.d_ff_dense, 256) if self.moe.d_ff_dense else 0,
            )
        if self.mla is not None:
            changes["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                       rope_head_dim=16, nope_head_dim=32,
                                       v_head_dim=32)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, d_inner=2 * changes["d_model"],
                n_heads=(2 * changes["d_model"]) // 32, head_dim=32,
                chunk_size=16)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPES = {s.name: s for s in INPUT_SHAPES}
