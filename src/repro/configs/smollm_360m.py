"""smollm-360m [dense] — llama-arch small model.

Source: [hf:HuggingFaceTB/SmolLM-135M] family card, assigned 360M shape:
32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49_152,
    tie_embeddings=True,
)
