"""mamba2-1.3b [ssm] — attention-free, SSD (state-space duality).

Source: [arXiv:2405.21060]: 48L d_model=2048 d_ff=0 vocab=50280
ssm_state=128, expand=2 (d_inner=4096), head_dim=64 (64 heads).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=2048,
    n_heads=1,                   # attn-free; unused
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, d_inner=4096, n_heads=64, head_dim=64,
                  d_conv=4, chunk_size=256),
    tie_embeddings=True,
)
