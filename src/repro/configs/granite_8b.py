"""granite-8b [dense] — llama-arch, code model.

Source: [arXiv:2405.04324]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49_152,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
)
