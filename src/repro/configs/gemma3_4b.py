"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.

Source: [hf:google/gemma-3-1b-pt] family card, scaled to the assigned 4B shape:
34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, head_dim=256,
sliding_window=1024, every 6th layer global.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-4b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    global_interval=6,       # 5 local : 1 global
    tie_embeddings=True,
)
