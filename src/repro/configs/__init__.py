"""Architecture registry: ``get_config("<arch-id>")`` and the input shapes."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, SHAPES, InputShape, ModelConfig

_ARCH_MODULES = {
    "gemma3-4b": "repro.configs.gemma3_4b",
    "smollm-360m": "repro.configs.smollm_360m",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "whisper-base": "repro.configs.whisper_base",
    "granite-8b": "repro.configs.granite_8b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _cache:
        if arch_id not in _ARCH_MODULES:
            raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
        _cache[arch_id] = importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG
    return _cache[arch_id]


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def applicable_shapes(cfg: ModelConfig) -> List[InputShape]:
    """The input shapes this arch runs (long_500k only when sub-quadratic)."""
    out = []
    for s in INPUT_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # skip noted in DESIGN.md §Arch-applicability
        out.append(s)
    return out


__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "SHAPES", "InputShape", "ModelConfig",
    "get_config", "get_shape", "applicable_shapes",
]
