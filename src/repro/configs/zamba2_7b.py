"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

Source: [arXiv:2411.15242]: 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000 ssm_state=64.  A single shared attention block (with
per-invocation LoRA deltas) is applied every 6 Mamba2 layers.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    ssm=SSMConfig(d_state=64, d_inner=7168, n_heads=112, head_dim=64,
                  d_conv=4, chunk_size=256),
    attn_interval=6,             # shared attn block every 6 ssm layers
    shared_attn_lora_rank=128,
)
