"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline table.

  PYTHONPATH=src python -m repro.roofline.report [--mesh single] [--md]

cost_analysis caveat (XLA CPU backend): while-loop bodies (lax.scan) are
costed ONCE, not x trip-count.  We therefore report BOTH the raw HLO
numbers and scan-corrected estimates: flops/bytes multiplied by the known
static trip counts (layer stacks, attention kv blocks, loss chunks) that
wrap essentially all compute.  The correction factor per record is the
product of scan lengths along the dominant path, computed from the config.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

from repro.configs import get_config, get_shape
from repro.roofline.analysis import HW, model_flops

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def scan_correction(arch: str, shape_name: str) -> float:
    """Static trip-count product along the dominant compute path: the
    layer-stack scan(s).  Inner attention/loss scans are *nested* in the
    costed-once body, so the body cost already reflects one (layer x
    q-block x kv-block) tile — we conservatively correct by the layer
    count only (a LOWER bound on true FLOPs; see EXPERIMENTS.md)."""
    cfg = get_config(arch)
    if cfg.family == "moe" and cfg.moe.first_dense_layers:
        return cfg.n_layers - cfg.moe.first_dense_layers  # dominant stack
    if cfg.family == "moe":
        return cfg.n_layers // 2              # super-blocks of 2 layers
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_interval
    if cfg.family == "audio":
        return cfg.n_layers
    return cfg.n_layers


def load(mesh: str) -> List[Dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        if d.get("status") == "ok":
            recs.append(d)
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def build_rows(mesh: str):
    rows = []
    for d in load(mesh):
        arch, shape_name = d["arch"], d["shape"]
        cfg, shape = get_config(arch), get_shape(shape_name)
        n = d["n_chips"]
        corr = scan_correction(arch, shape_name)
        fl = d["cost_analysis"]["flops"] * corr
        by = d["cost_analysis"]["bytes_accessed"] * corr
        coll = d["collectives"]["total"] * corr
        compute_s = fl / HW["peak_flops_bf16"]
        memory_s = by / HW["hbm_bw"]
        coll_s = coll / HW["ici_bw"]
        dom = max((compute_s, "compute"), (memory_s, "memory"),
                  (coll_s, "collective"))[1]
        mf = model_flops(cfg, shape)
        ratio = mf / (fl * n) if fl else float("nan")
        temp = d["memory_analysis"].get("temp_size_in_bytes", 0)
        args = d["memory_analysis"].get("argument_size_in_bytes", 0)
        rows.append({
            "arch": arch, "shape": shape_name, "mesh": mesh,
            "chips": n, "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dom,
            "model_flops": mf, "hlo_flops_global": fl * n,
            "useful_ratio": ratio,
            "temp_gb": temp / 1e9, "args_gb": args / 1e9,
            "coll_by_kind": {k: v * corr for k, v in
                             d["collectives"].items()
                             if k not in ("count", "total")},
            "scan_corr": corr,
        })
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | chips | compute | memory | collective | "
           "dominant | useful-FLOP ratio | temp/chip | args/chip |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['temp_gb']:.1f} GB | "
            f"{r['args_gb']:.2f} GB |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = build_rows(args.mesh)
    if args.md:
        print(markdown(rows))
        return
    for r in rows:
        print(f"{r['arch']:28s} {r['shape']:12s} {r['chips']:4d} "
              f"c={fmt_s(r['compute_s']):>8s} m={fmt_s(r['memory_s']):>8s} "
              f"x={fmt_s(r['collective_s']):>8s} dom={r['dominant']:10s} "
              f"useful={r['useful_ratio']:.2f} temp={r['temp_gb']:.1f}GB")


if __name__ == "__main__":
    main()
