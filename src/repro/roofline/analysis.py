"""Three-term roofline from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed out of the post-SPMD optimized HLO text (operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e, per chip
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,2560]{1,0}   or  f32[]  — shape literal
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# an HLO instruction line:  %name = <shape-or-tuple> opcode(...operands...)
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9_]+\[[^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum *result* shape bytes of every collective op, by op kind.

    The result shape of -start ops is used (we skip -done which would double
    count).  This is the per-replica payload as seen by one device — we
    report per-chip traffic.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_txt)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    def as_dict(self):
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        return d


def roofline_terms(*, total_flops: float, total_bytes: float,
                   collective_bytes_per_chip: float, n_chips: int,
                   flops_are_global: bool = True) -> RooflineTerms:
    """cost_analysis totals are for one partition's program (per-chip)."""
    f = total_flops / n_chips if flops_are_global else total_flops
    b = total_bytes / n_chips if flops_are_global else total_bytes
    return RooflineTerms(
        compute_s=f / HW["peak_flops_bf16"],
        memory_s=b / HW["hbm_bw"],
        collective_s=collective_bytes_per_chip / HW["ici_bw"],
        flops_per_chip=f,
        bytes_per_chip=b,
        collective_bytes_per_chip=collective_bytes_per_chip,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) with N = active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens
