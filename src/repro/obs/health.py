"""Serving-tier health: per-query critical-path attribution and SLO
burn-rate monitoring.

Two cooperating pieces, both fed by ``gnnserve.engine`` only when
telemetry is enabled (the engine's hooks all guard on a per-query
``attrib`` dict / a lazily-built monitor, so the disabled cost stays
zero):

``AttributionCollector``
    Every served query's wall time, partitioned into the causal
    segments of its critical path —

        queue_wait      submit -> slot admission (+ re-queues after a
                        preemption or a mid-job park)
        pin             snapshot pinning (admit-then-capture) minus the
                        recompute share
        recompute       recompute-on-miss time triggered by the pin
        gather          this query's row-proportional share of the
                        fused sharded gathers it rode
        refresh_wait    refresh interference: inline refreshes and
                        chunked-refresh chunk advances that ran during
                        steps the query sat in a slot
        sched_wait      the rest of the in-slot time — waiting for DRR
                        grants / other tenants' rows

    The segments partition ``[submit, done]``: queue_wait + in-slot
    time are measured from the same clock reads that bound the query's
    end-to-end wall time, so the per-tenant sums reconcile against
    measured e2e (the acceptance bound is 5%; ``summary()`` reports the
    ``attributed_frac`` per tenant).  The engine also records one
    ``serve.query`` trace event per completed query (own Perfetto
    track, segment attrs) — the report CLI's top-k critical paths.

``HealthMonitor``
    Rolling-window detectors emitting structured ``health.alert``
    events into the trace plus ``health.alerts[.<kind>]`` counters and
    ``health.burn_rate.<tenant>`` gauges (so alerts surface on the
    Prometheus endpoint too).  Detectors:

    * ``slo_burn`` — per-tenant burn rate over the staleness SLO:
      ``burn = violating_fraction_of_window / error_budget``; fires at
      ``burn >= burn_threshold``, re-arms below half the threshold
      (hysteresis, so a sustained burn alerts once, not per step).
    * ``wait_burn`` — same machinery over queue wait vs an optional
      wall-clock wait SLO (``wait_slo_ms``; 0 disables).
    * ``evict_thrash`` — eviction events over the last window exceed
      ``thrash_evictions`` (the budgeted store is churning rows it is
      about to need again).
    * ``refresh_backlog`` — pending mutations grew across the window
      AND exceed ``backlog_factor`` x the tightest tenant SLO: refresh
      is not keeping up with the mutation stream.
    * ``route_flap`` — the dist-vs-local refresh route (PR 7 cutover)
      flipped direction >= ``flap_threshold`` times within the window:
      frontier sizes are hovering at the cutover and every flip pays a
      cold plan or a cold mesh dispatch.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

from repro import obs

# the canonical segment order (reports render in this order)
SEGMENTS = ("queue_wait", "pin", "recompute", "gather", "refresh_wait",
            "sched_wait")

MAX_SAMPLES = 4096


def _pct(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(int(round(q / 100.0 * (len(sorted_vals) - 1))),
              len(sorted_vals) - 1)
    return sorted_vals[idx]


class _TenantAttrib:
    __slots__ = ("n", "e2e_sum", "seg_sum", "e2e_samples")

    def __init__(self):
        self.n = 0
        self.e2e_sum = 0
        self.seg_sum = {s: 0 for s in SEGMENTS}
        self.e2e_samples: List[int] = []


class AttributionCollector:
    """Per-tenant aggregation of per-query critical-path segments,
    plus a bounded top-k of the slowest individual queries."""

    def __init__(self, top_k: int = 16):
        self.top_k = int(top_k)
        self._t: Dict[str, _TenantAttrib] = {}
        self._top: List[dict] = []      # sorted by e2e_ns desc

    def record(self, *, uid: int, tenant: str, e2e_ns: int,
               segments_ns: Dict[str, int],
               served_version: int = -1) -> None:
        t = self._t.get(tenant)
        if t is None:
            t = self._t[tenant] = _TenantAttrib()
        t.n += 1
        t.e2e_sum += int(e2e_ns)
        for s in SEGMENTS:
            t.seg_sum[s] += int(segments_ns.get(s, 0))
        t.e2e_samples.append(int(e2e_ns))
        if len(t.e2e_samples) > MAX_SAMPLES:
            del t.e2e_samples[:len(t.e2e_samples) - MAX_SAMPLES]
        if (len(self._top) < self.top_k
                or e2e_ns > self._top[-1]["e2e_ns"]):
            self._top.append({"uid": int(uid), "tenant": tenant,
                              "e2e_ns": int(e2e_ns),
                              "served_version": int(served_version),
                              "segments_ns": {s: int(segments_ns.get(s, 0))
                                              for s in SEGMENTS}})
            self._top.sort(key=lambda r: -r["e2e_ns"])
            del self._top[self.top_k:]

    @property
    def n_queries(self) -> int:
        return sum(t.n for t in self._t.values())

    def summary(self) -> Dict[str, dict]:
        """Per tenant: query count, e2e latency stats, per-segment
        totals + fractions, and the attribution closure
        (``attributed_frac`` = segment sum / measured e2e sum — the 5%
        reconciliation bound means this stays within [0.95, 1.05])."""
        out: Dict[str, dict] = {}
        for name, t in sorted(self._t.items()):
            samples = sorted(t.e2e_samples)
            seg_total = sum(t.seg_sum.values())
            e2e = max(t.e2e_sum, 1)
            out[name] = {
                "n_queries": t.n,
                "e2e_ms": {
                    "sum": t.e2e_sum / 1e6,
                    "mean": t.e2e_sum / max(t.n, 1) / 1e6,
                    "p50": _pct(samples, 50) / 1e6,
                    "p95": _pct(samples, 95) / 1e6,
                    "max": (samples[-1] if samples else 0) / 1e6,
                },
                "segments_ms": {s: t.seg_sum[s] / 1e6 for s in SEGMENTS},
                "segments_frac": {s: t.seg_sum[s] / e2e for s in SEGMENTS},
                "attributed_frac": seg_total / e2e,
            }
        return out

    def top_paths(self) -> List[dict]:
        """The slowest queries, worst first, with segment breakdowns in
        ms (the report CLI's top-k critical-path table)."""
        return [{"uid": r["uid"], "tenant": r["tenant"],
                 "served_version": r["served_version"],
                 "e2e_ms": r["e2e_ns"] / 1e6,
                 "segments_ms": {s: v / 1e6
                                 for s, v in r["segments_ns"].items()}}
                for r in self._top]


class HealthMonitor:
    """Rolling-window SLO burn-rate + serving-health detectors (see the
    module docstring).  ``slos`` maps tenant name -> staleness SLO (the
    engine passes its QoS registry, or ``{"default": staleness_bound}``
    on the FIFO path)."""

    def __init__(self, slos: Dict[str, int], *, window: int = 128,
                 error_budget: float = 0.01, burn_threshold: float = 4.0,
                 wait_slo_ms: float = 0.0, thrash_evictions: int = 32,
                 backlog_factor: float = 4.0, flap_threshold: int = 8):
        assert slos, "at least one tenant SLO required"
        assert window >= 2 and 0 < error_budget <= 1 and burn_threshold > 0
        self.slos = {k: int(v) for k, v in slos.items()}
        self.window = int(window)
        self.error_budget = float(error_budget)
        self.burn_threshold = float(burn_threshold)
        self.wait_slo_ms = float(wait_slo_ms)
        self.thrash_evictions = int(thrash_evictions)
        self.backlog_factor = float(backlog_factor)
        self.flap_threshold = int(flap_threshold)
        self.alerts: List[dict] = []
        self.burn_rate: Dict[str, float] = {}
        self.wait_burn_rate: Dict[str, float] = {}
        self.step_no = 0
        self._stale: Dict[str, deque] = {}
        self._wait: Dict[str, deque] = {}
        self._firing: set = set()       # (kind, subject) with hysteresis
        self._pending: deque = deque(maxlen=self.window)
        self._evict: deque = deque(maxlen=self.window)
        # counter baselines prime on the FIRST on_step: the monitor can
        # attach to a warm engine without reading its whole history as
        # one burst
        self._last: Optional[Dict[str, int]] = None
        self._route_dir = 0
        self._flips: deque = deque(maxlen=self.window)

    # -- alert plumbing -------------------------------------------------
    def _fire(self, kind: str, subject: str, details: dict) -> None:
        key = (kind, subject)
        if key in self._firing:
            return
        self._firing.add(key)
        alert = {"kind": kind, "subject": subject, "step": self.step_no,
                 **details}
        self.alerts.append(alert)
        obs.add("health.alerts")
        obs.add(f"health.alerts.{kind}")
        tel = obs.current()
        if tel.enabled:
            # a zero-duration structured event in the span stream: the
            # report CLI and Perfetto both see WHEN the alert fired
            tel.tracer.record("health.alert", tel.now_ns(), 0, 0,
                              dict(alert))

    def _clear(self, kind: str, subject: str) -> None:
        self._firing.discard((kind, subject))

    # -- per-observation feeds ------------------------------------------
    def _burn(self, dq: deque, violated: bool, budget: float) -> float:
        dq.append(1 if violated else 0)
        return (sum(dq) / len(dq)) / budget

    def on_staleness(self, tenant: str, staleness: int) -> None:
        """One pinned read's observed staleness vs the tenant's SLO."""
        slo = self.slos.get(tenant)
        if slo is None:
            return
        dq = self._stale.get(tenant)
        if dq is None:
            dq = self._stale[tenant] = deque(maxlen=self.window)
        burn = self._burn(dq, staleness > slo, self.error_budget)
        self.burn_rate[tenant] = burn
        obs.gauge(f"health.burn_rate.{tenant}", burn)
        if burn >= self.burn_threshold:
            self._fire("slo_burn", tenant,
                       {"burn_rate": round(burn, 3), "slo": slo,
                        "window": len(dq), "violations": int(sum(dq))})
        elif burn < self.burn_threshold / 2:
            self._clear("slo_burn", tenant)

    def on_wait(self, tenant: str, wait_ms: float) -> None:
        """One query's queue wait vs the (optional) wall-clock wait
        SLO."""
        if self.wait_slo_ms <= 0:
            return
        dq = self._wait.get(tenant)
        if dq is None:
            dq = self._wait[tenant] = deque(maxlen=self.window)
        burn = self._burn(dq, wait_ms > self.wait_slo_ms,
                          self.error_budget)
        self.wait_burn_rate[tenant] = burn
        obs.gauge(f"health.wait_burn_rate.{tenant}", burn)
        if burn >= self.burn_threshold:
            self._fire("wait_burn", tenant,
                       {"burn_rate": round(burn, 3),
                        "wait_slo_ms": self.wait_slo_ms,
                        "window": len(dq), "violations": int(sum(dq))})
        elif burn < self.burn_threshold / 2:
            self._clear("wait_burn", tenant)

    def on_step(self, *, pending: int, evictions: int,
                route_local: int = 0, route_dist: int = 0) -> None:
        """One engine step's cumulative counters (the monitor diffs
        them; a counter moving backwards — e.g. a ``full_epoch`` store
        swap — resets that detector's baseline)."""
        self.step_no += 1
        if self._last is None:           # prime the diff baselines
            self._last = {"evictions": int(evictions),
                          "route_local": int(route_local),
                          "route_dist": int(route_dist)}

        # refresh-backlog growth: pending grew across the window AND
        # exceeds what the tightest SLO should ever let accumulate
        self._pending.append(int(pending))
        tight = min(self.slos.values())
        cap = self.backlog_factor * max(tight, 1)
        if (len(self._pending) == self._pending.maxlen
                and pending > self._pending[0] and pending >= cap):
            self._fire("refresh_backlog", "engine",
                       {"pending": int(pending),
                        "window_ago": int(self._pending[0]),
                        "cap": cap})
        elif pending <= max(tight, 1):
            self._clear("refresh_backlog", "engine")

        # eviction thrash: eviction events per rolling window
        d_ev = max(int(evictions) - self._last["evictions"], 0)
        self._last["evictions"] = int(evictions)
        self._evict.append(d_ev)
        ev_window = sum(self._evict)
        if ev_window >= self.thrash_evictions:
            self._fire("evict_thrash", "store",
                       {"evictions_in_window": int(ev_window),
                        "window": len(self._evict)})
        elif ev_window < self.thrash_evictions / 2:
            self._clear("evict_thrash", "store")

        # route flapping: dist-vs-local refresh routing changed
        # direction repeatedly within the window
        d_l = max(int(route_local) - self._last["route_local"], 0)
        d_d = max(int(route_dist) - self._last["route_dist"], 0)
        self._last["route_local"] = int(route_local)
        self._last["route_dist"] = int(route_dist)
        direction = 1 if (d_l and not d_d) else (-1 if (d_d and not d_l)
                                                 else 0)
        if direction and self._route_dir and direction != self._route_dir:
            self._flips.append(self.step_no)
        if direction:
            self._route_dir = direction
        flips = sum(1 for s in self._flips
                    if s > self.step_no - self.window)
        if flips >= self.flap_threshold:
            self._fire("route_flap", "refresh",
                       {"flips_in_window": int(flips),
                        "window": self.window})
        elif flips < self.flap_threshold / 2:
            self._clear("route_flap", "refresh")

    def summary(self) -> dict:
        return {"n_alerts": len(self.alerts),
                "alerts": list(self.alerts),
                "burn_rate": dict(self.burn_rate),
                "wait_burn_rate": dict(self.wait_burn_rate),
                "firing": sorted(f"{k}:{s}" for k, s in self._firing)}


__all__ = ["SEGMENTS", "AttributionCollector", "HealthMonitor"]
