"""Nestable tracing spans over an injectable monotonic clock.

The pipeline's perf story (sampling vs feature prep vs per-layer ops vs
comms vs refresh vs eviction) needs *stage-level* evidence, not one
end-to-end wall clock.  A ``Tracer`` records completed spans —

    with tracer.span("refresh.subset_plan") as sp:
        ...
        sp.set(rows=int(n))          # attach attrs once known

— into a fixed-capacity ring buffer (oldest spans drop first, counted in
``n_dropped``, so a long-lived serving process never grows unbounded).
Spans nest: the tracer tracks the live depth, so exporters can rebuild
the flame graph without parent pointers.

Clock: any zero-arg callable returning integer NANOSECONDS.  The default
is ``time.perf_counter_ns`` (monotonic); tests inject ``FakeClock`` so
span layout is bit-for-bit deterministic (golden exporter files).

The no-op story lives one level up (``obs.Telemetry.span`` /
``obs.span``): when telemetry is disabled those return the shared
``NOOP_SPAN`` singleton after a single attribute check — no ``_Span``
allocation, no clock read, nothing recorded.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

# one recorded span: (name, t_start_ns, dur_ns, depth, attrs-or-None)
SpanTuple = Tuple[str, int, int, int, Optional[dict]]


class FakeClock:
    """Deterministic test clock: every read advances by ``step`` ns, so
    a span's duration equals ``step * (clock reads inside it)``."""

    def __init__(self, start: int = 0, step: int = 1000):
        self.t = int(start)
        self.step = int(step)

    def __call__(self) -> int:
        t = self.t
        self.t += self.step
        return t

    def advance(self, ns: int) -> None:
        self.t += int(ns)


class NoopSpan:
    """Shared do-nothing span; falsy so call sites can skip building
    attrs dicts entirely (``if sp: sp.set(...)``)."""

    __slots__ = ()

    def __enter__(self) -> "NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def __bool__(self) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


NOOP_SPAN = NoopSpan()


class _Span:
    __slots__ = ("_tr", "name", "attrs", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: Optional[dict]):
        self._tr = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "_Span":
        tr = self._tr
        self._depth = tr.depth
        tr.depth += 1
        self._t0 = tr.clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tr
        t1 = tr.clock()
        tr.depth -= 1
        tr.record(self.name, self._t0, t1 - self._t0, self._depth,
                  self.attrs)
        return False

    def __bool__(self) -> bool:
        return True

    def set(self, **attrs) -> None:
        if self.attrs is None:
            self.attrs = {}
        self.attrs.update(attrs)


class Tracer:
    """Span recorder with a bounded ring buffer.

    Spans are recorded at EXIT (start + duration), so ``events`` is
    ordered by end time — exactly what the Chrome/Perfetto trace-event
    format wants (``ph: "X"`` complete events, order irrelevant)."""

    def __init__(self, clock=None, capacity: int = 65536):
        assert capacity > 0
        self.clock = clock if clock is not None else time.perf_counter_ns
        self.capacity = int(capacity)
        self.events: List[SpanTuple] = []
        self._next = 0              # ring write index once full
        self.n_dropped = 0
        self.depth = 0              # live nesting depth
        # optional (name, dur_ns, attrs) callback on every completed
        # span — ``obs.Telemetry`` feeds per-span-name ``_ms`` histograms
        # through it
        self.on_record = None

    def span(self, name: str, attrs: Optional[dict] = None) -> _Span:
        return _Span(self, name, attrs)

    def record(self, name: str, t0: int, dur: int, depth: int,
               attrs: Optional[dict]) -> None:
        """Append one completed span (public so instrumentation that
        already measured an interval can log it without re-timing)."""
        ev = (name, int(t0), int(dur), int(depth), attrs)
        if len(self.events) < self.capacity:
            self.events.append(ev)
        else:
            self.events[self._next] = ev
            self._next = (self._next + 1) % self.capacity
            self.n_dropped += 1
        if self.on_record is not None:
            self.on_record(name, dur, attrs)

    def clear(self) -> None:
        self.events = []
        self._next = 0
        self.n_dropped = 0

    def events_in_order(self) -> List[SpanTuple]:
        """Events oldest-first (unwraps the ring)."""
        if len(self.events) < self.capacity or self._next == 0:
            return list(self.events)
        return self.events[self._next:] + self.events[:self._next]

    # -- analytics (stage breakdowns, coverage) -------------------------
    def aggregate(self) -> Dict[str, Dict[str, float]]:
        """Per span name: call count, total/max duration in ms — the
        stage breakdown the bench JSON summaries report."""
        out: Dict[str, Dict[str, float]] = {}
        for name, _t0, dur, _d, _a in self.events:
            agg = out.setdefault(name, {"count": 0, "total_ms": 0.0,
                                        "max_ms": 0.0})
            agg["count"] += 1
            ms = dur / 1e6
            agg["total_ms"] += ms
            agg["max_ms"] = max(agg["max_ms"], ms)
        return out

    def window_ns(self) -> Tuple[int, int]:
        """(earliest start, latest end) over recorded spans."""
        if not self.events:
            return (0, 0)
        lo = min(t0 for _n, t0, _d, _dep, _a in self.events)
        hi = max(t0 + d for _n, t0, d, _dep, _a in self.events)
        return (lo, hi)

    def covered_ns(self) -> int:
        """Total ns covered by the UNION of all recorded spans — the
        numerator of the trace-coverage acceptance check (spans must
        account for >= 90% of the traced window)."""
        if not self.events:
            return 0
        iv = sorted((t0, t0 + d) for _n, t0, d, _dep, _a in self.events)
        total = 0
        cur_lo, cur_hi = iv[0]
        for lo, hi in iv[1:]:
            if lo > cur_hi:
                total += cur_hi - cur_lo
                cur_lo, cur_hi = lo, hi
            else:
                cur_hi = max(cur_hi, hi)
        return total + (cur_hi - cur_lo)

    def coverage(self) -> float:
        """Covered fraction of the traced window (0..1)."""
        lo, hi = self.window_ns()
        return self.covered_ns() / max(hi - lo, 1)
