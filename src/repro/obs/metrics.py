"""Typed counters / gauges / histograms in one named registry.

One naming scheme replaces the scattered per-subsystem ``stats()``
dicts: dotted lowercase paths, unit suffix where one applies —

    store.evictions                 counter
    store.recompute_ms              histogram (per outermost recompute)
    ops.spmm_ms                     histogram (per primitive call)
    delta.frontier_rows             counter
    plan_cache.hits / .misses       counters
    qos.tenant.<name>.wait_ms       histogram
    serve.gather_ms                 histogram

Metrics are get-or-create by name and STRICTLY typed: re-registering a
name as a different kind raises (silent type drift is how the old
``stats()`` keys diverged between store/engine/qos in the first place).
Histograms keep exact count/sum/min/max plus a bounded sample window
(newest ``MAX_SAMPLES`` observations) for stable p50/p95 without
O(observations) memory.
"""
from __future__ import annotations

from typing import Dict, List, Union

MAX_SAMPLES = 4096


class Counter:
    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    __slots__ = ("name", "count", "total", "vmin", "vmax", "samples")
    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self.samples.append(v)
        if len(self.samples) > MAX_SAMPLES:
            del self.samples[:len(self.samples) - MAX_SAMPLES]

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        # nearest-rank on the retained window (deterministic, no numpy)
        idx = min(int(round(q / 100.0 * (len(s) - 1))), len(s) - 1)
        return s[idx]

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p95": 0.0}
        return {"count": self.count, "sum": self.total,
                "mean": self.total / self.count, "min": self.vmin,
                "max": self.vmax, "p50": self.percentile(50),
                "p95": self.percentile(95)}


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named metrics, get-or-create, strictly typed per name."""

    def __init__(self):
        self._m: Dict[str, Metric] = {}

    def _get(self, name: str, cls) -> Metric:
        m = self._m.get(name)
        if m is None:
            m = cls(name)
            self._m[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                            f"{cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def __contains__(self, name: str) -> bool:
        return name in self._m

    def __iter__(self):
        return iter(self._m.values())

    def __len__(self) -> int:
        return len(self._m)

    def clear(self) -> None:
        self._m.clear()

    def to_dict(self) -> Dict[str, float]:
        """Flat name -> value view (sorted).  Histograms expand into
        ``<name>.count / .sum / .mean / .min / .max / .p50 / .p95``."""
        out: Dict[str, float] = {}
        for name in sorted(self._m):
            m = self._m[name]
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = m.value
        return out
