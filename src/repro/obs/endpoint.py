"""Live telemetry surface: a stdlib-only HTTP scrape endpoint plus a
periodic JSON snapshot writer.

``Session.serve()`` starts a ``TelemetryEndpoint`` when the config's
``TelemetrySpec`` asks for one (``http_port >= 0`` and/or a
``snapshot_path``); ``Session.close()`` stops it.  Everything here is
standard library — no prometheus_client, no web framework — because the
container bakes in only the jax toolchain.

Routes (GET):

    /metrics    the metrics registry in Prometheus exposition format
                (scrape this; burn-rate gauges and ``deal_health_alerts``
                counters surface SLO state without parsing a trace)
    /healthz    {"status": "ok"|"alerting", "n_alerts", "alerts": [...]}
    /stats      the full ``Session.stats()`` tree as JSON

Reads are point-in-time over the live single-threaded engine: a scrape
racing a serve step can observe a mid-step counter, which is the normal
Prometheus contract (monotonic counters, last-write gauges) — the engine
itself is never blocked or mutated by a scrape.

The snapshot writer appends nothing and rewrites atomically (tmp +
``os.replace``), so a crashed process always leaves a parseable last
snapshot behind for the report CLI.
"""
from __future__ import annotations

import json
import os
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional


def json_sanitize(obj):
    """Recursively coerce a stats tree to pure-JSON types (numpy scalars
    and arrays appear throughout the legacy ``stats()`` shapes)."""
    import numpy as np
    if isinstance(obj, dict):
        return {str(k): json_sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [json_sanitize(v) for v in obj.tolist()]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (bool, int, str)) or obj is None:
        return obj
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else None
    return str(obj)


class TelemetryEndpoint:
    """Serve /metrics, /healthz and /stats for one ``Session`` and
    (optionally) write periodic JSON snapshots of its stats tree."""

    def __init__(self, session, *, port: int = 0, host: str = "127.0.0.1",
                 snapshot_path: str = "", snapshot_every_s: float = 1.0):
        self.session = session
        self.host = host
        self.want_port = int(port)
        self.snapshot_path = snapshot_path
        self.snapshot_every_s = float(snapshot_every_s)
        self.port: Optional[int] = None     # bound port once started
        self._server: Optional[ThreadingHTTPServer] = None
        self._threads = []
        self._stop = threading.Event()
        self.n_snapshots = 0

    # -- payload builders (also used directly by tests) -----------------
    def _health_doc(self) -> dict:
        eng = getattr(self.session, "_engine", None)
        mon = getattr(eng, "health", None) if eng is not None else None
        summary = mon.summary() if mon is not None else {
            "n_alerts": 0, "alerts": [], "burn_rate": {},
            "wait_burn_rate": {}, "firing": []}
        summary["status"] = "alerting" if summary["firing"] else "ok"
        return json_sanitize(summary)

    def _stats_doc(self) -> dict:
        return json_sanitize(self.session.stats())

    def write_snapshot(self) -> None:
        doc = {"stats": self._stats_doc(), "health": self._health_doc()}
        tmp = f"{self.snapshot_path}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.snapshot_path)
        self.n_snapshots += 1

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "TelemetryEndpoint":
        if self.want_port >= 0:
            ep = self

            class _Handler(BaseHTTPRequestHandler):
                def log_message(self, *a):   # no stderr chatter per scrape
                    pass

                def do_GET(self):
                    try:
                        if self.path == "/metrics":
                            body = ep.session.prometheus_text().encode()
                            ctype = ("text/plain; version=0.0.4; "
                                     "charset=utf-8")
                        elif self.path == "/healthz":
                            body = json.dumps(
                                ep._health_doc(), sort_keys=True).encode()
                            ctype = "application/json"
                        elif self.path == "/stats":
                            body = json.dumps(
                                ep._stats_doc(), sort_keys=True).encode()
                            ctype = "application/json"
                        else:
                            self.send_error(404)
                            return
                    except Exception as exc:   # surface, don't wedge
                        self.send_error(500, str(exc))
                        return
                    self.send_response(200)
                    self.send_header("Content-Type", ctype)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)

            self._server = ThreadingHTTPServer((self.host, self.want_port),
                                               _Handler)
            self._server.daemon_threads = True
            self.port = self._server.server_address[1]
            t = threading.Thread(target=self._server.serve_forever,
                                 name="deal-telemetry-http", daemon=True)
            t.start()
            self._threads.append(t)
        if self.snapshot_path:
            t = threading.Thread(target=self._snapshot_loop,
                                 name="deal-telemetry-snapshot",
                                 daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def _snapshot_loop(self) -> None:
        while not self._stop.wait(self.snapshot_every_s):
            try:
                self.write_snapshot()
            except Exception:
                # a transient race with close() must not kill the loop;
                # the final snapshot in stop() still runs
                pass

    def stop(self) -> None:
        self._stop.set()
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads = []
        if self.snapshot_path:
            try:        # one last consistent snapshot on clean shutdown
                self.write_snapshot()
            except Exception:
                pass


__all__ = ["TelemetryEndpoint", "json_sanitize"]
