"""Schema + coverage validation for dumped Chrome/Perfetto traces.

    PYTHONPATH=src python -m repro.obs.validate TRACE.json \
        [--min-coverage 0.9] \
        [--require-cats construct,sample,featprep,ops,serve,refresh,store] \
        [--require-spans refresh.chunk,refresh.layer]

The CI obs smoke step runs this over ``Session.dump_trace`` output:

  * structural schema — the trace-event envelope Perfetto loads:
    ``traceEvents`` list, ``ph: "X"`` events with string names and
    numeric non-negative ts/dur, pid/tid present;
  * stage attribution — every required category (a span name's prefix
    before the first dot) appears at least once, so sampling / feature
    prep / per-layer ops / serve / refresh are each individually
    attributed, not lumped into one blob;
  * span inventory — every EXACT span name in ``--require-spans``
    appears at least once; categories are too coarse for the
    chunked-refresh path (``refresh.chunk`` / ``refresh.layer`` /
    ``refresh.route`` all share the ``refresh`` category with the
    plain inline-refresh spans, so only a name-level check proves the
    preemptible path actually ran and got traced);
  * coverage — the interval UNION of all spans must cover at least
    ``--min-coverage`` of the traced window (earliest start to latest
    end): the trace explains where the wall time went.

Exit code 0 with a one-line summary on success; every violation is
listed on stderr and the exit code is 1 otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

DEFAULT_CATS = "construct,sample,featprep,ops,serve,refresh,store"


def validate_trace(doc: dict, min_coverage: float = 0.9,
                   require_cats: Tuple[str, ...] = (),
                   require_spans: Tuple[str, ...] = ()
                   ) -> Tuple[List[str], Dict[str, float]]:
    """Returns (problems, summary).  Empty problems == valid."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return ([f"trace root must be a JSON object, got "
                 f"{type(doc).__name__}"], {})
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return (["traceEvents: missing or not a list"], {})

    names = set()
    spans = []       # (ts, dur, cat) in us
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"traceEvents[{i}]: not an object")
            continue
        ph = ev.get("ph")
        if ph == "M":
            continue                         # metadata events are free-form
        if ph != "X":
            problems.append(f"traceEvents[{i}]: ph must be 'X' or 'M', "
                            f"got {ph!r}")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            problems.append(f"traceEvents[{i}]: missing span name")
            continue
        ts, dur = ev.get("ts"), ev.get("dur")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"traceEvents[{i}] ({name}): bad ts {ts!r}")
            continue
        if not isinstance(dur, (int, float)) or dur < 0:
            problems.append(f"traceEvents[{i}] ({name}): bad dur {dur!r}")
            continue
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                problems.append(f"traceEvents[{i}] ({name}): missing {key}")
        names.add(name)
        spans.append((float(ts), float(dur),
                      ev.get("cat") or name.split(".", 1)[0]))

    if not spans:
        problems.append("trace contains no complete ('X') span events")
        return (problems, {"n_spans": 0, "coverage": 0.0})

    cats = {c for _, _, c in spans}
    for want in require_cats:
        if want and want not in cats:
            problems.append(
                f"required stage category {want!r} has no spans "
                f"(present: {', '.join(sorted(cats))})")
    for want in require_spans:
        if want and want not in names:
            prefix = want.split(".", 1)[0]
            near = sorted(n for n in names if n.startswith(prefix))
            problems.append(
                f"required span {want!r} never recorded "
                f"(nearest by prefix: {', '.join(near) or 'none'})")

    lo = min(ts for ts, _, _ in spans)
    hi = max(ts + dur for ts, dur, _ in spans)
    iv = sorted((ts, ts + dur) for ts, dur, _ in spans)
    covered, cur_lo, cur_hi = 0.0, iv[0][0], iv[0][1]
    for a, b in iv[1:]:
        if a > cur_hi:
            covered += cur_hi - cur_lo
            cur_lo, cur_hi = a, b
        else:
            cur_hi = max(cur_hi, b)
    covered += cur_hi - cur_lo
    coverage = covered / max(hi - lo, 1e-12)
    if coverage < min_coverage:
        problems.append(f"span coverage {coverage:.3f} of the traced "
                        f"window is below the required {min_coverage:g}")

    return (problems, {"n_spans": len(spans), "coverage": coverage,
                       "window_ms": (hi - lo) / 1e3,
                       "n_categories": len(cats)})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate a dumped repro.obs Chrome/Perfetto trace")
    ap.add_argument("trace", help="trace JSON file (Session.dump_trace)")
    ap.add_argument("--min-coverage", type=float, default=0.9,
                    help="required span-union fraction of the traced "
                         "window (default 0.9)")
    ap.add_argument("--require-cats", default=DEFAULT_CATS,
                    help="comma list of span-name prefixes that must "
                         f"each appear (default: {DEFAULT_CATS}; '' "
                         "disables the check)")
    ap.add_argument("--require-spans", default="",
                    help="comma list of EXACT span names that must each "
                         "appear (e.g. refresh.chunk,refresh.layer for "
                         "the chunked-refresh path; '' disables)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    cats = tuple(c for c in args.require_cats.split(",") if c)
    span_names = tuple(s for s in args.require_spans.split(",") if s)
    problems, summary = validate_trace(doc, args.min_coverage, cats,
                                       span_names)
    if problems:
        for p in problems:
            print(f"INVALID: {p}", file=sys.stderr)
        return 1
    print(f"OK: {summary['n_spans']} spans over "
          f"{summary['window_ms']:.1f}ms, coverage "
          f"{summary['coverage']:.3f}, {summary['n_categories']} stage "
          "categories")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
