"""Legacy ``stats()`` dicts -> the unified metric naming scheme.

``EmbeddingStore.stats()``, ``EmbeddingServeEngine.stats()`` and
``QoSScheduler.stats()`` each grew their own key shapes (flat, ``store_``
prefixed, and nested-per-tenant respectively).  Those dicts stay exactly
as they are — they are the compatibility alias existing callers
(launchers, benches, tests) keep reading — and this module derives the
ONE flat unified view from them:

    serve.queries, serve.gather_steps, serve.refreshes, ...
    store.evictions, store.hits, store.misses, store.recompute_ms, ...
    qos.tenant.<name>.p95_wait_steps, .rows_served, .preemptions, ...
    plan_cache.hits / plan_cache.misses
    construct.exchanged_bytes, construct.shuffle_ms, ...
    delta.frontier_rows, delta.rows_gemm, ...

``Session.stats()["metrics"]`` is this translation merged UNDER the live
telemetry registry (real measured histograms win over derived counters
when both exist).  Counter-style names map 1:1; times are normalized to
milliseconds (``_ms`` suffix, like every span-derived histogram).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

# unified name -> legacy EmbeddingStore.stats() key (values copied as-is)
STORE_MAP = {
    "store.version": "version",
    "store.lookups": "n_lookups",
    "store.rows_gathered": "rows_gathered",
    "store.swaps": "n_swaps",
    "store.shards": "n_shards",
    "store.levels": "n_levels",
    "store.tail_shards": "n_tail_shards",
    "store.hits": "hits",
    "store.misses": "misses",
    "store.hit_rate": "hit_rate",
    "store.evictions": "n_evictions",
    "store.rows_evicted": "rows_evicted",
    "store.recomputes": "n_recomputes",
    "store.recompute_spans": "n_recompute_spans",
    "store.rows_recomputed": "rows_recomputed",
    "store.resident_bytes": "resident_bytes",
    "store.budget_rows": "budget_rows",
    "store.budget_util": "budget_util",
}

# unified name -> legacy engine.stats() key (the non-store, non-tenant part)
ENGINE_MAP = {
    "serve.queries": "n_served",
    "serve.gather_steps": "n_gather_steps",
    "serve.refreshes": "n_refreshes",
    "serve.refresh_chunks": "n_refresh_chunks",
    "serve.full_epochs": "n_full_epochs",
    "serve.onboarded": "n_onboarded",
    "serve.pending_mutations": "pending_mutations",
}

# unified name -> Session.stats()["refresh_cutover"] key (the PR 7
# dist-vs-local routing decision counters + the PR 8 tail-row routing)
CUTOVER_MAP = {
    "refresh.cutover_threshold": "threshold",
    "refresh.route_local": "n_local",
    "refresh.route_dist": "n_dist",
    "refresh.route_tail_rows": "n_tail",
}

# unified per-tenant suffix -> legacy QoSScheduler.stats() tenant key.
# These step-denominated waits are the derived alias; the wall-clock
# ``qos.tenant.<name>.wait_ms`` histogram comes from live telemetry.
TENANT_MAP = {
    "n_served": "n_served",
    "rows_served": "rows_served",
    "p50_wait_steps": "wait_p50_steps",
    "p95_wait_steps": "wait_p95_steps",
    "staleness_p95": "staleness_p95",
    "staleness_max": "staleness_max",
    "staleness_slo": "staleness_slo",
    "slo_violations": "slo_violations",
    "refresh_rows_charged": "refresh_rows_charged",
    "refresh_triggers": "n_refresh_triggers",
    "quota_util": "quota_util",
    "preemptions": "n_preemptions",
    "view_restarts": "n_view_restarts",
    "deferred_pins": "n_deferred_pins",
    "view_version": "view_version",
}

# the tenant fields external consumers read TODAY (benchmarks/bench_qos.py
# and repro.launch.serve_embeddings.drive) — the key-drift guard test
# pins QoSScheduler.stats() to at least this contract
TENANT_CONSUMED_FIELDS = frozenset(
    ["n_served", "rows_served", "wait_p50_steps", "wait_p95_steps",
     "staleness_max", "staleness_slo", "slo_violations",
     "refresh_rows_charged", "quota_util", "n_preemptions"])


def unified_from_engine(engine_stats: Dict[str, Any]) -> Dict[str, float]:
    """Flatten one ``EmbeddingServeEngine.stats()`` dict (which embeds
    the store's stats under ``store_`` and tenants under ``tenants``)
    onto the unified names."""
    out: Dict[str, float] = {}
    for uni, legacy in ENGINE_MAP.items():
        if legacy in engine_stats:
            out[uni] = engine_stats[legacy]
    for uni, legacy in STORE_MAP.items():
        key = f"store_{legacy}"
        if key in engine_stats:
            out[uni] = engine_stats[key]
    if "store_recompute_s" in engine_stats:
        out["store.recompute_ms"] = engine_stats["store_recompute_s"] * 1e3
    for name, t in engine_stats.get("tenants", {}).items():
        for uni, legacy in TENANT_MAP.items():
            if legacy in t:
                out[f"qos.tenant.{name}.{uni}"] = t[legacy]
    return out


def unified_from_store(store_stats: Dict[str, Any]) -> Dict[str, float]:
    """Same translation for a bare ``EmbeddingStore.stats()`` dict."""
    out = {uni: store_stats[legacy] for uni, legacy in STORE_MAP.items()
           if legacy in store_stats}
    if "recompute_s" in store_stats:
        out["store.recompute_ms"] = store_stats["recompute_s"] * 1e3
    return out


def unified_from_construct(construct_stats: Dict[str, Any]
                           ) -> Dict[str, float]:
    """``csr_from_edges_distributed`` stats -> unified names."""
    out: Dict[str, float] = {}
    if "exchanged_bytes" in construct_stats:
        out["construct.exchanged_bytes"] = construct_stats["exchanged_bytes"]
    for uni, legacy in (("construct.shuffle_ms", "shuffle_s"),
                        ("construct.build_ms", "build_s"),
                        ("construct.modeled_parallel_ms",
                         "modeled_parallel_s")):
        if legacy in construct_stats:
            out[uni] = construct_stats[legacy] * 1e3
    if "n_workers" in construct_stats:
        out["construct.workers"] = construct_stats["n_workers"]
    return out


def unified_from_refresh(refresh_stats: Dict[str, Any]) -> Dict[str, float]:
    """The LAST refresh's ``DeltaReinference.refresh`` result -> unified
    names (cumulative frontier counters live in telemetry; this is the
    latest-refresh gauge view)."""
    out: Dict[str, float] = {}
    if "rows_gemm" in refresh_stats:
        out["delta.rows_gemm"] = refresh_stats["rows_gemm"]
    for uni, legacy in (("delta.resampled", "n_resampled"),
                        ("delta.feat_updates", "n_feat_updates"),
                        ("delta.rev_splices", "rev_splices"),
                        ("delta.rev_rebuilds", "rev_rebuilds"),
                        ("delta.chunks", "n_chunks"),
                        ("delta.tail_routed", "n_tail_routed"),
                        ("delta.onboarded", "n_onboarded")):
        if legacy in refresh_stats:
            out[uni] = refresh_stats[legacy]
    if "local_cutover" in refresh_stats:
        out["delta.local_cutover"] = int(bool(refresh_stats["local_cutover"]))
    for l, n in enumerate(refresh_stats.get("frontier_sizes", [])):
        out[f"delta.frontier_rows.layer{l}"] = n
    return out


def unified_from_cutover(cutover: Dict[str, Any]) -> Dict[str, float]:
    """``Session.stats()["refresh_cutover"]`` -> unified names."""
    return {uni: cutover[legacy] for uni, legacy in CUTOVER_MAP.items()
            if legacy in cutover}


# Session.stats() keys that are structural containers or derived views
# rather than metric leaves: each one is either translated by a dedicated
# map above, merged from the live registry, or an aggregate the report
# CLI consumes wholesale.  Anything outside these AND the maps is key
# drift — ``unified_from_session`` returns it as unmapped so the guard
# test fails loudly instead of the unified view silently thinning out.
SESSION_PASSTHROUGH = frozenset([
    "metrics",          # already the unified view
    "attribution",      # per-tenant critical-path aggregate (report CLI)
    "health",           # HealthMonitor summary (alert list + burn rates)
])
SESSION_SCALARS = {
    "n_nodes": "session.n_nodes",
    "n_edges": "session.n_edges",
}


def unified_from_session(stats: Dict[str, Any]
                         ) -> Tuple[Dict[str, float], List[str]]:
    """Walk a full ``Session.stats()`` tree and resolve EVERY leaf to a
    registered unified metric name.  Returns ``(unified, unmapped)`` —
    the guard test asserts ``unmapped == []`` so new stats keys cannot
    land without a naming-scheme entry."""
    unified: Dict[str, float] = {}
    unmapped: List[str] = []
    for k, v in stats.items():
        if k in SESSION_PASSTHROUGH:
            continue
        if k in SESSION_SCALARS:
            unified[SESSION_SCALARS[k]] = v
        elif k.startswith("t_") and isinstance(v, (int, float)):
            unified[f"session.{k[2:].removesuffix('_s')}_ms"] = v * 1e3
        elif k == "plan_cache" and isinstance(v, dict):
            for kk, vv in v.items():
                if kk in ("hits", "misses"):
                    unified[f"plan_cache.{kk}"] = vv
                else:
                    unmapped.append(f"plan_cache.{kk}")
        elif k == "refresh_cutover" and isinstance(v, dict):
            unified.update(unified_from_cutover(v))
            known = set(CUTOVER_MAP.values())
            unmapped.extend(f"refresh_cutover.{kk}" for kk in v
                            if kk not in known)
        elif k == "tenants" and isinstance(v, dict):
            rev = {legacy: uni for uni, legacy in TENANT_MAP.items()}
            for name, t in v.items():
                for kk, vv in t.items():
                    if kk in rev:
                        unified[f"qos.tenant.{name}.{rev[kk]}"] = vv
                    else:
                        unmapped.append(f"tenants.{name}.{kk}")
        elif k == "store_recompute_s":
            unified["store.recompute_ms"] = v * 1e3
        elif k.startswith("store_"):
            rev = {legacy: uni for uni, legacy in STORE_MAP.items()}
            legacy = k[len("store_"):]
            if legacy in rev:
                unified[rev[legacy]] = v
            else:
                unmapped.append(k)
        else:
            rev = {legacy: uni for uni, legacy in ENGINE_MAP.items()}
            if k in rev:
                unified[rev[k]] = v
            else:
                unmapped.append(k)
    return unified, unmapped


def unified_metrics(engine_stats: Optional[Dict[str, Any]] = None,
                    construct_stats: Optional[Dict[str, Any]] = None,
                    refresh_stats: Optional[Dict[str, Any]] = None,
                    plan_cache: Optional[Dict[str, int]] = None,
                    timings: Optional[Dict[str, float]] = None,
                    live: Optional[Dict[str, float]] = None,
                    cutover: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, float]:
    """The whole unified view: every legacy shape translated, then the
    LIVE telemetry registry merged on top (measured beats derived)."""
    out: Dict[str, float] = {}
    if construct_stats:
        out.update(unified_from_construct(construct_stats))
    if engine_stats:
        out.update(unified_from_engine(engine_stats))
    if refresh_stats:
        out.update(unified_from_refresh(refresh_stats))
    if cutover:
        out.update(unified_from_cutover(cutover))
    if plan_cache:
        out["plan_cache.hits"] = plan_cache.get("hits", 0)
        out["plan_cache.misses"] = plan_cache.get("misses", 0)
    for k, v in (timings or {}).items():
        out[f"session.{k.removesuffix('_s')}_ms"] = v * 1e3
    out.update(live or {})
    return dict(sorted(out.items()))
