"""Post-run serving-tier health report + bench trajectory gate.

Report mode — render one dumped trace (``Session.dump_trace`` output)
as an operator-readable text report:

    PYTHONPATH=src python -m repro.obs.report TRACE.json \
        [--top-k 10] [--check]

Sections: the span-stage breakdown (count/total/max per span name), the
top-k per-query critical paths (the engine's ``serve.query`` events,
slowest first, with their segment ledgers), the per-tenant attribution
tables (the ``deal_attribution`` payload ``Session.dump_trace`` embeds),
and every ``health.alert`` event.  ``--check`` exits non-zero unless the
trace parses, contains spans, and — when query events are present —
every tenant's attribution closes within the 5% reconciliation bound
(the CI smoke gate).

Trajectory mode — the tracked bench history in
``results/TRAJECTORY.json`` (every ``benchmarks/run.py`` invocation,
``--smoke`` included, appends one entry via ``append_trajectory``):

    PYTHONPATH=src python -m repro.obs.report \
        --trajectory results/TRAJECTORY.json [--last-n 8] \
        [--share-tolerance 0.3] [--min-share 0.1]

The gate compares the LATEST entry against the median of the previous
up-to-N entries with the same (executor, smoke) key, per bench and per
span stage.  It compares each stage's SHARE of its bench's total span
time rather than absolute ms — shares survive machine changes (a CI
runner vs the laptop that seeded the file) while still catching the
regression class that matters: a stage suddenly dominating the
end-to-end profile.  A stage regresses when its share grew by more than
``--share-tolerance`` (absolute) AND ended above ``--min-share``; a
bench that newly failed always regresses.  With fewer than 2 comparable
entries the gate passes (the seed run), and identical entries always
pass — the gate passes against itself.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

from repro.obs.health import SEGMENTS

# attribution must close within 5% of measured end-to-end wall time
ATTRIBUTION_TOLERANCE = 0.05

TRAJECTORY_MAX_ENTRIES = 200      # absolute file cap (all keys)
TRAJECTORY_MAX_PER_KEY = 20       # history kept per (executor, smoke)


# ----------------------------------------------------------------------
# trace report
# ----------------------------------------------------------------------

def load_trace(path) -> dict:
    with open(path) as f:
        return json.load(f)


def _spans(doc: dict) -> List[dict]:
    return [ev for ev in doc.get("traceEvents", [])
            if isinstance(ev, dict) and ev.get("ph") == "X"]


def _fmt_row(cells, widths) -> str:
    return "  ".join(str(c).rjust(w) if i else str(c).ljust(w)
                     for i, (c, w) in enumerate(zip(cells, widths)))


def _table(headers, rows) -> List[str]:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              if rows else len(str(h)) for i, h in enumerate(headers)]
    out = [_fmt_row(headers, widths),
           _fmt_row(["-" * w for w in widths], widths)]
    out += [_fmt_row(r, widths) for r in rows]
    return out


def stage_breakdown(doc: dict) -> Dict[str, Dict[str, float]]:
    """Per span name: count, total_ms, max_ms (ts/dur are us)."""
    agg: Dict[str, Dict[str, float]] = {}
    for ev in _spans(doc):
        a = agg.setdefault(ev["name"],
                           {"count": 0, "total_ms": 0.0, "max_ms": 0.0})
        a["count"] += 1
        ms = float(ev.get("dur", 0)) / 1e3
        a["total_ms"] += ms
        a["max_ms"] = max(a["max_ms"], ms)
    return agg


def query_events(doc: dict) -> List[dict]:
    """The engine's per-query critical-path events, slowest first."""
    out = [ev for ev in _spans(doc) if ev["name"] == "serve.query"]
    out.sort(key=lambda ev: -float(ev.get("dur", 0)))
    return out


def alert_events(doc: dict) -> List[dict]:
    return [ev for ev in _spans(doc) if ev["name"] == "health.alert"]


def render_report(doc: dict, top_k: int = 10) -> str:
    lines: List[str] = []
    spans = _spans(doc)
    lines.append("== serving-tier health report ==")
    lines.append(f"{len(spans)} spans"
                 + (f", {doc['deal_dropped_spans']} dropped (ring "
                    "buffer wrapped)" if doc.get("deal_dropped_spans")
                    else ""))

    agg = stage_breakdown(doc)
    lines.append("")
    lines.append("-- stage breakdown (by total time) --")
    rows = [(n, a["count"], f"{a['total_ms']:.2f}", f"{a['max_ms']:.2f}")
            for n, a in sorted(agg.items(),
                               key=lambda kv: -kv[1]["total_ms"])]
    lines += _table(("span", "count", "total_ms", "max_ms"), rows)

    qevents = query_events(doc)
    if qevents:
        lines.append("")
        lines.append(f"-- top-{min(top_k, len(qevents))} critical paths "
                     f"(of {len(qevents)} served queries) --")
        rows = []
        for ev in qevents[:top_k]:
            args = ev.get("args", {})
            rows.append((f"{args.get('tenant', '?')}/"
                         f"{args.get('uid', '?')}",
                         f"{float(ev.get('dur', 0)) / 1e3:.2f}",
                         *(f"{args.get(f'{s}_ms', 0):.2f}"
                           for s in SEGMENTS)))
        lines += _table(("query", "e2e_ms", *SEGMENTS), rows)

    attribution = doc.get("deal_attribution")
    if attribution:
        lines.append("")
        lines.append("-- per-tenant attribution (latency budget) --")
        rows = []
        for tenant, a in sorted(attribution.items()):
            rows.append((tenant, a["n_queries"],
                         f"{a['e2e_ms']['p50']:.2f}",
                         f"{a['e2e_ms']['p95']:.2f}",
                         *(f"{100 * a['segments_frac'][s]:.1f}%"
                           for s in SEGMENTS),
                         f"{a['attributed_frac']:.3f}"))
        lines += _table(("tenant", "queries", "p50_ms", "p95_ms",
                         *SEGMENTS, "attributed"), rows)

    health = doc.get("deal_health")
    alerts = alert_events(doc)
    lines.append("")
    if alerts or (health and health.get("alerts")):
        lines.append(f"-- health alerts ({len(alerts)}) --")
        seen = alerts or [{"args": a, "ts": None}
                          for a in health.get("alerts", [])]
        for ev in seen:
            a = ev.get("args", {})
            detail = {k: v for k, v in a.items()
                      if k not in ("kind", "subject", "depth")}
            when = ("" if ev.get("ts") is None
                    else f" @ {float(ev['ts']) / 1e3:.1f}ms")
            lines.append(f"ALERT {a.get('kind', '?')} "
                         f"[{a.get('subject', '?')}]{when} {detail}")
        if health and health.get("burn_rate"):
            lines.append("burn rates: " + ", ".join(
                f"{t}={b:.2f}" for t, b in
                sorted(health["burn_rate"].items())))
    else:
        lines.append("-- health: no alerts --")
    return "\n".join(lines) + "\n"


def check_trace(doc: dict, top_k: int = 10) -> List[str]:
    """The ``--check`` gate: structural problems in a rendered report's
    inputs (empty list == healthy enough for CI)."""
    problems: List[str] = []
    if not _spans(doc):
        problems.append("trace contains no span events")
        return problems
    try:
        render_report(doc, top_k)
    except Exception as exc:        # report must never crash on real dumps
        problems.append(f"report rendering failed: {exc!r}")
    attribution = doc.get("deal_attribution") or {}
    for tenant, a in sorted(attribution.items()):
        frac = a.get("attributed_frac", 0.0)
        if abs(frac - 1.0) > ATTRIBUTION_TOLERANCE:
            problems.append(
                f"tenant {tenant!r}: attribution closes at "
                f"{frac:.3f} of measured e2e (must be within "
                f"{ATTRIBUTION_TOLERANCE:.0%})")
    if query_events(doc) and not attribution:
        problems.append("serve.query events present but no "
                        "deal_attribution payload (dump_trace drift?)")
    return problems


# ----------------------------------------------------------------------
# bench trajectory
# ----------------------------------------------------------------------

def load_trajectory(path) -> List[dict]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return []
    return doc if isinstance(doc, list) else []


def _trajectory_key(entry: dict) -> Tuple[str, bool]:
    """The baseline identity the gate compares within."""
    return str(entry.get("executor", "?")), bool(entry.get("smoke"))


def trim_trajectory(entries: List[dict],
                    max_per_key: int = TRAJECTORY_MAX_PER_KEY
                    ) -> List[dict]:
    """Keep the newest ``max_per_key`` entries PER (executor, smoke)
    key (order preserved) — the gate only ever baselines against
    ``--last-n`` same-key entries, so older history is dead weight that
    would otherwise grow the checked-in file without bound."""
    counts: Dict[Tuple[str, bool], int] = {}
    keep: List[dict] = []
    for e in reversed(entries):
        k = _trajectory_key(e)
        if counts.get(k, 0) < max_per_key:
            counts[k] = counts.get(k, 0) + 1
            keep.append(e)
    keep.reverse()
    return keep[-TRAJECTORY_MAX_ENTRIES:]


def append_trajectory(path, entry: dict) -> List[dict]:
    """Append one bench-run entry, keeping the last
    ``TRAJECTORY_MAX_PER_KEY`` per (executor, smoke) key (and
    ``TRAJECTORY_MAX_ENTRIES`` overall).  Entry shape (see
    benchmarks/run.py): {ts, git, smoke, executor, failures: [...],
     benches: {key: {stages: {span: {count, total_ms}}, coverage}}}."""
    entries = load_trajectory(path)
    entries.append(entry)
    entries = trim_trajectory(entries)
    with open(path, "w") as f:
        json.dump(entries, f, indent=1, sort_keys=True)
        f.write("\n")
    return entries


def _stage_shares(bench: dict) -> Dict[str, float]:
    stages = bench.get("stages", {})
    total = sum(float(s.get("total_ms", 0)) for s in stages.values())
    if total <= 0:
        return {}
    return {name: float(s.get("total_ms", 0)) / total
            for name, s in stages.items()}


def _median(vals: List[float]) -> float:
    vs = sorted(vals)
    n = len(vs)
    return vs[n // 2] if n % 2 else 0.5 * (vs[n // 2 - 1] + vs[n // 2])


def check_trajectory(entries: List[dict], *, last_n: int = 8,
                     share_tolerance: float = 0.3,
                     min_share: float = 0.1
                     ) -> Tuple[List[str], Dict[str, Any]]:
    """Gate the LATEST entry against the median stage shares of the
    previous up-to-``last_n`` entries with the same (executor, smoke)
    key.  Returns (problems, summary); no baseline == pass."""
    if not entries:
        return [], {"n_entries": 0, "compared": 0, "verdict": "empty"}
    latest = entries[-1]
    problems: List[str] = []
    for bench in sorted(latest.get("failures", [])):
        problems.append(f"bench {bench!r} failed in the latest run")
    key = (latest.get("executor"), latest.get("smoke"))
    baseline = [e for e in entries[:-1]
                if (e.get("executor"), e.get("smoke")) == key
                and not e.get("failures")][-last_n:]
    compared = 0
    if baseline:
        base_shares: Dict[str, Dict[str, List[float]]] = {}
        for e in baseline:
            for bkey, bench in e.get("benches", {}).items():
                for stage, share in _stage_shares(bench).items():
                    base_shares.setdefault(bkey, {}).setdefault(
                        stage, []).append(share)
        for bkey, bench in sorted(latest.get("benches", {}).items()):
            for stage, share in sorted(_stage_shares(bench).items()):
                hist = base_shares.get(bkey, {}).get(stage)
                if not hist:
                    continue            # new stage: informational only
                compared += 1
                med = _median(hist)
                if share > med + share_tolerance and share > min_share:
                    problems.append(
                        f"{bkey}/{stage}: stage share grew to "
                        f"{share:.2f} of the bench profile (median of "
                        f"last {len(hist)}: {med:.2f}, tolerance "
                        f"+{share_tolerance:g})")
    return problems, {"n_entries": len(entries),
                      "n_baseline": len(baseline), "compared": compared,
                      "verdict": "fail" if problems else "ok"}


def render_trajectory(entries: List[dict], last_n: int = 8) -> str:
    lines = [f"== bench trajectory ({len(entries)} entries) =="]
    for e in entries[-last_n:]:
        benches = e.get("benches", {})
        total = sum(sum(float(s.get("total_ms", 0))
                        for s in b.get("stages", {}).values())
                    for b in benches.values())
        fails = e.get("failures", [])
        lines.append(
            f"ts={e.get('ts', '?')} git={e.get('git', '?')} "
            f"executor={e.get('executor', '?')} "
            f"smoke={e.get('smoke', '?')} benches={len(benches)} "
            f"span_total={total:.0f}ms"
            + (f" FAILURES={fails}" if fails else ""))
    return "\n".join(lines) + "\n"


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="render a serving-tier health report from a dumped "
                    "trace, or gate the tracked bench trajectory")
    ap.add_argument("trace", nargs="?",
                    help="trace JSON (Session.dump_trace output)")
    ap.add_argument("--top-k", type=int, default=10,
                    help="critical paths to render (default 10)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless the trace renders and every "
                         "tenant's attribution closes within "
                         f"{ATTRIBUTION_TOLERANCE:.0%}")
    ap.add_argument("--trajectory", metavar="PATH",
                    help="gate results/TRAJECTORY.json instead of "
                         "rendering a trace")
    ap.add_argument("--last-n", type=int, default=8,
                    help="baseline entries for the trajectory gate "
                         "(default 8)")
    ap.add_argument("--share-tolerance", type=float, default=0.3,
                    help="allowed absolute growth of a stage's share of "
                         "its bench profile (default 0.3)")
    ap.add_argument("--min-share", type=float, default=0.1,
                    help="stages below this share never regress "
                         "(default 0.1)")
    args = ap.parse_args(argv)

    if args.trajectory:
        entries = load_trajectory(args.trajectory)
        sys.stdout.write(render_trajectory(entries, args.last_n))
        problems, summary = check_trajectory(
            entries, last_n=args.last_n,
            share_tolerance=args.share_tolerance,
            min_share=args.min_share)
        for p in problems:
            print(f"REGRESSION: {p}", file=sys.stderr)
        print(f"gate: {summary['verdict']} "
              f"({summary.get('compared', 0)} stage shares compared "
              f"against {summary.get('n_baseline', 0)} baseline entries)")
        return 1 if problems else 0

    if not args.trace:
        ap.error("a trace path or --trajectory is required")
    doc = load_trace(args.trace)
    sys.stdout.write(render_report(doc, args.top_k))
    if args.check:
        problems = check_trace(doc, args.top_k)
        for p in problems:
            print(f"CHECK FAILED: {p}", file=sys.stderr)
        if problems:
            return 1
        print("check: ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
