"""repro.obs — unified tracing + metrics for the whole Deal pipeline.

One ``Telemetry`` object pairs a span ``Tracer`` (ring buffer, injectable
clock — see ``obs.trace``) with a ``MetricsRegistry`` (typed counters /
gauges / histograms under one naming scheme — see ``obs.metrics``), and
exporters turn either into a Perfetto-loadable trace JSON or a
Prometheus text dump (``obs.export``).

Instrumentation sites call the MODULE-LEVEL helpers so no tracer has to
be threaded through every constructor (the opentelemetry "current
provider" pattern):

    from repro import obs
    ...
    with obs.span("refresh.subset_plan") as sp:
        plan = build(...)
        if sp:                       # falsy in no-op mode: the attrs
            sp.set(rows=int(n))      # dict is never even built

    obs.add("store.evictions")       # counter += 1
    obs.observe("ops.spmm_ms", ms)   # histogram sample

The process default is a DISABLED singleton: every helper is a true
no-op whose cost is one attribute check (``tel.enabled``) and which
allocates nothing — hot paths stay instrumented at all times without a
perf tax.  ``api.Session`` builds a ``Telemetry`` from its config's
``TelemetrySpec`` and ``install``s it for the session's lifetime;
tests use the ``use(tel)`` context manager.  Only ONE telemetry is
current per process at a time (sessions that overlap share the last
installed one — spans say which session via the root span attrs).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

from repro.obs.export import (chrome_trace, dump_chrome_trace,
                              prometheus_text)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.trace import NOOP_SPAN, FakeClock, NoopSpan, Tracer


class Telemetry:
    """One session's telemetry: enabled flag + tracer + metrics."""

    __slots__ = ("enabled", "tracer", "metrics")

    def __init__(self, enabled: bool = True, clock=None,
                 capacity: int = 65536):
        self.enabled = enabled
        self.tracer = Tracer(clock=clock, capacity=capacity)
        self.metrics = MetricsRegistry()
        # every completed span also feeds a per-name duration histogram
        # (``ops.spmm`` span -> ``ops.spmm_ms`` metric), with a second
        # executor-attributed series when the span carries an
        # ``executor`` attr (``ops.spmm.pallas_ms``) — the pallas-vs-ref
        # breakdown falls out of the same instrumentation site
        self.tracer.on_record = self._span_metric

    def _span_metric(self, name, dur_ns, attrs) -> None:
        ms = dur_ns / 1e6
        self.metrics.histogram(name + "_ms").observe(ms)
        if attrs:
            ex = attrs.get("executor")
            if ex:
                self.metrics.histogram(f"{name}.{ex}_ms").observe(ms)

    # -- spans ----------------------------------------------------------
    def span(self, name: str, attrs: Optional[dict] = None):
        if not self.enabled:
            return NOOP_SPAN
        return self.tracer.span(name, attrs)

    # -- metrics --------------------------------------------------------
    def add(self, name: str, v: float = 1.0) -> None:
        if self.enabled:
            self.metrics.counter(name).inc(v)

    def gauge(self, name: str, v: float) -> None:
        if self.enabled:
            self.metrics.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        if self.enabled:
            self.metrics.histogram(name).observe(v)

    def now_ns(self) -> int:
        return self.tracer.clock()

    def clear(self) -> None:
        self.tracer.clear()
        self.metrics.clear()


DISABLED = Telemetry(enabled=False, capacity=1)
_CURRENT: Telemetry = DISABLED


def current() -> Telemetry:
    return _CURRENT


def enabled() -> bool:
    return _CURRENT.enabled


def install(tel: Optional[Telemetry]) -> Telemetry:
    """Make ``tel`` the process-current telemetry (None -> the disabled
    default).  Returns the previous one so callers can restore it."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tel if tel is not None else DISABLED
    return prev


@contextmanager
def use(tel: Optional[Telemetry]):
    """Scoped ``install`` (tests, benches)."""
    prev = install(tel)
    try:
        yield tel
    finally:
        install(prev)


# -- module-level hot-path helpers (single attribute check, zero
#    allocation when disabled) ------------------------------------------

def span(name: str, attrs: Optional[dict] = None):
    tel = _CURRENT
    if not tel.enabled:
        return NOOP_SPAN
    return tel.tracer.span(name, attrs)


def add(name: str, v: float = 1.0) -> None:
    tel = _CURRENT
    if tel.enabled:
        tel.metrics.counter(name).inc(v)


def gauge(name: str, v: float) -> None:
    tel = _CURRENT
    if tel.enabled:
        tel.metrics.gauge(name).set(v)


def observe(name: str, v: float) -> None:
    tel = _CURRENT
    if tel.enabled:
        tel.metrics.histogram(name).observe(v)


__all__ = ["Telemetry", "Tracer", "FakeClock", "MetricsRegistry",
           "Counter", "Gauge", "Histogram", "NoopSpan", "NOOP_SPAN",
           "DISABLED", "chrome_trace", "dump_chrome_trace",
           "prometheus_text", "current", "enabled", "install", "use",
           "span", "add", "gauge", "observe"]
