"""Exporters: Chrome/Perfetto trace-event JSON and Prometheus text.

``chrome_trace`` emits the Trace Event Format (the JSON flavor both
``chrome://tracing`` and https://ui.perfetto.dev load directly): one
``ph: "X"`` complete event per recorded span, timestamps/durations in
MICROseconds, span attrs under ``args`` (plus the recorded nesting
``depth``, which lets tooling rebuild the flame graph without relying on
timestamp containment).  The metrics registry rides along under a
top-level ``deal_metrics`` key — Perfetto ignores unknown keys, so one
file carries the whole telemetry picture.

Events recorded with a ``_track`` attr (the engine's per-query
``serve.query`` timelines) render on their own named thread row instead
of the main pipeline track, so long-lived query spans don't visually
swallow the nested step/gather flame graph.

``prometheus_text`` renders the registry in the Prometheus exposition
format (``# TYPE`` lines; dotted names sanitized to underscores;
histograms as summaries with p50/p95 quantile samples).
"""
from __future__ import annotations

import json
import re
from typing import Optional

from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import Tracer

TRACE_PID = 0
TRACE_TID = 0


def chrome_trace(tracer: Tracer,
                 metrics: Optional[MetricsRegistry] = None,
                 process_name: str = "deal",
                 extra: Optional[dict] = None) -> dict:
    events = [{"name": "process_name", "ph": "M", "pid": TRACE_PID,
               "tid": TRACE_TID, "args": {"name": process_name}}]
    tracks = {}                 # track label -> tid (1, 2, ...)
    for name, t0, dur, depth, attrs in tracer.events_in_order():
        args = dict(attrs) if attrs else {}
        tid = TRACE_TID
        track = args.pop("_track", None)
        if track is not None:
            tid = tracks.get(track)
            if tid is None:
                tid = tracks[track] = len(tracks) + 1
                events.append({"name": "thread_name", "ph": "M",
                               "pid": TRACE_PID, "tid": tid,
                               "args": {"name": str(track)}})
        args["depth"] = depth
        events.append({"name": name,
                       "cat": name.split(".", 1)[0],
                       "ph": "X",
                       "ts": t0 / 1e3,          # us
                       "dur": dur / 1e3,        # us
                       "pid": TRACE_PID,
                       "tid": tid,
                       "args": args})
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if tracer.n_dropped:
        out["deal_dropped_spans"] = tracer.n_dropped
    if metrics is not None:
        out["deal_metrics"] = metrics.to_dict()
    if extra:
        out.update(extra)
    return out


def dump_chrome_trace(tracer: Tracer, path,
                      metrics: Optional[MetricsRegistry] = None,
                      process_name: str = "deal",
                      extra: Optional[dict] = None) -> dict:
    doc = chrome_trace(tracer, metrics, process_name=process_name,
                       extra=extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return doc


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def prometheus_text(metrics: MetricsRegistry, prefix: str = "deal") -> str:
    """Prometheus exposition text: counters/gauges as single samples,
    histograms as summaries (sum + count + p50/p95 quantiles)."""
    lines = []
    for m in sorted(metrics, key=lambda m: m.name):
        name = f"{prefix}_{_prom_name(m.name)}" if prefix else \
            _prom_name(m.name)
        if isinstance(m, Histogram):
            s = m.summary()
            lines.append(f"# TYPE {name} summary")
            lines.append(f"{name}{{quantile=\"0.5\"}} {s['p50']:g}")
            lines.append(f"{name}{{quantile=\"0.95\"}} {s['p95']:g}")
            lines.append(f"{name}_sum {s['sum']:g}")
            lines.append(f"{name}_count {s['count']}")
        else:
            lines.append(f"# TYPE {name} {m.kind}")
            lines.append(f"{name} {m.value:g}")
    return "\n".join(lines) + ("\n" if lines else "")
