"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked prefill: intra-chunk quadratic (attention-like, MXU-friendly) +
inter-chunk linear state recurrence via lax.scan — the TPU adaptation of the
SSD block decomposition (chunk == the paper's "block", sized for VMEM).
Decode: O(1) state update per token.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm
from repro.sharding.context import constrain


class SSMCache(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, conv_dim)
    state: jax.Array   # (B, H, N, P) f32


def _split_proj(x, p, cfg):
    s = cfg.ssm
    d_in, G, N, H = s.d_inner, s.n_groups, s.d_state, s.n_heads
    xz = jnp.einsum("bsd,de->bse", x, p["w_xz"])
    x_in, z = xz[..., :d_in], xz[..., d_in:]
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return x_in, z, bc, dt


def _causal_conv(u, kernel):
    """Depthwise causal conv.  u: (B, S, C); kernel: (W, C)."""
    W = kernel.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(W):
        out = out + pad[:, i:i + u.shape[1]].astype(jnp.float32) * \
            kernel[i].astype(jnp.float32)
    return out.astype(u.dtype)


def ssd_chunked(x, dt, A, B_, C_, chunk: int):
    """SSD scan.  x:(B,S,H,P) dt:(B,S,H) A:(H,)<0 B_,C_:(B,S,G,N).

    Streaming form: ONE lax.scan over chunks carrying the (B,H,N,P) state;
    each (checkpointed) step does the intra-chunk quadratic block plus the
    contribution of the carried state.  Peak memory is one chunk's
    (L, L, H) tensors, independent of sequence length — the TPU analogue of
    the paper's grouped computation.

    Returns y:(B,S,H,P) and the final state (B,H,N,P) in f32.
    """
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    # ragged S: pad with dt=0 steps — exp(0*A)=1 and dt*B x = 0, so padding
    # is an exact no-op for both y rows (dropped) and the carried state.
    S_orig = S
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    nc = S // chunk
    Af = A.astype(jnp.float32)
    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]

    def to_chunks(a, extra):
        a = a.astype(jnp.float32).reshape((Bsz, nc, chunk) + extra)
        return jnp.moveaxis(a, 1, 0)           # (nc, B, L, ...)

    xs = (to_chunks(x, (H, P)), to_chunks(dt, (H,)),
          to_chunks(B_, (G, N)), to_chunks(C_, (G, N)))

    @jax.checkpoint
    def chunk_step(state, inp):
        xc, dtc, Bc, Cc = inp                  # (B,L,H,P) (B,L,H) (B,L,G,N)
        xc = constrain(xc, "dp", None, "tp")
        dtc = constrain(dtc, "dp", None, "tp")
        Bh = constrain(jnp.repeat(Bc, rep, axis=2), "dp", None, "tp")
        Ch = constrain(jnp.repeat(Cc, rep, axis=2), "dp", None, "tp")
        dA = dtc * Af                          # (B,L,H) negative
        cum = jnp.cumsum(dA, axis=1)
        seg = cum[:, -1]                       # (B,H)
        # intra-chunk quadratic block
        diff = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Li,Lj,H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("blhn,bmhn->blmh", Ch, Bh)
        w = constrain(scores * decay * dtc[:, None], "dp", None, None, "tp")
        y = jnp.einsum("blmh,bmhp->blhp", w, xc)
        # carried-state contribution
        y = y + jnp.einsum("blhn,bhnp->blhp",
                           Ch * jnp.exp(cum)[..., None], state)
        # state update
        to_end = jnp.exp(seg[:, None, :] - cum)            # (B,L,H)
        wB = Bh * (to_end * dtc)[..., None]                # (B,L,H,N)
        new_state = state * jnp.exp(seg)[..., None, None] + \
            jnp.einsum("blhn,blhp->bhnp", wB, xc)
        return new_state, y

    init = constrain(jnp.zeros((Bsz, H, N, P), jnp.float32), "dp", "tp")
    final, ys = jax.lax.scan(chunk_step, init, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)[:, :S_orig]
    return y.astype(x.dtype), final


def mamba2_block(x, p, cfg, return_state: bool = False):
    """Full Mamba-2 block, prefill/train path.  x: (B,S,D) -> (B,S,D)."""
    s = cfg.ssm
    B, S, D = x.shape
    H, P, N, G = s.n_heads, s.head_dim, s.d_state, s.n_groups
    x_in, z, bc, dt = _split_proj(x, p, cfg)
    conv_in = jnp.concatenate([x_in, bc], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv"]))
    x_c = conv_out[..., :s.d_inner]
    bc_c = conv_out[..., s.d_inner:]
    B_ = bc_c[..., :G * N].reshape(B, S, G, N)
    C_ = bc_c[..., G * N:].reshape(B, S, G, N)
    xh = x_c.reshape(B, S, H, P)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(xh, dt, A, B_, C_, s.chunk_size)
    y = y + xh * p["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, s.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if return_state:
        cache = SSMCache(conv=conv_in[:, S - (s.d_conv - 1):], state=final_state)
        return out, cache
    return out


def mamba2_decode(x, p, cfg, cache: SSMCache) -> Tuple[jax.Array, SSMCache]:
    """One-token decode.  x: (B,1,D)."""
    s = cfg.ssm
    B = x.shape[0]
    H, P, N, G = s.n_heads, s.head_dim, s.d_state, s.n_groups
    x_in, z, bc, dt = _split_proj(x, p, cfg)
    conv_in = jnp.concatenate([x_in, bc], axis=-1)       # (B,1,conv_dim)
    window = jnp.concatenate([cache.conv, conv_in], axis=1)  # (B,W,cd)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   p["conv"].astype(jnp.float32)))[:, None]
    new_conv = window[:, 1:]
    x_c = conv_out[..., :s.d_inner]
    bc_c = conv_out[..., s.d_inner:]
    B_ = bc_c[..., :G * N].reshape(B, G, N)
    C_ = bc_c[..., G * N:].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=1)                     # (B,H,N)
    Ch = jnp.repeat(C_, rep, axis=1)
    xh = x_c.reshape(B, H, P).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt1 = dt[:, 0]                                       # (B,H)
    dA = jnp.exp(dt1 * A)                                # (B,H)
    upd = jnp.einsum("bhn,bhp->bhnp", Bh * dt1[..., None], xh)
    state = cache.state * dA[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    y = y + xh * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, s.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, SSMCache(conv=new_conv, state=state)


def init_ssm_params(rng, cfg, dtype):
    s = cfg.ssm
    D = cfg.d_model
    conv_dim = s.d_inner + 2 * s.n_groups * s.d_state
    k = jax.random.split(rng, 6)
    init = jax.nn.initializers.normal(0.02)
    return {
        "w_xz": init(k[0], (D, 2 * s.d_inner), dtype),
        "w_bc": init(k[1], (D, 2 * s.n_groups * s.d_state), dtype),
        "w_dt": init(k[2], (D, s.n_heads), dtype),
        "dt_bias": jnp.zeros((s.n_heads,), jnp.float32),
        "conv": init(k[3], (s.d_conv, conv_dim), dtype),
        "A_log": jnp.zeros((s.n_heads,), jnp.float32),
        "D_skip": jnp.ones((s.n_heads,), jnp.float32),
        "norm": jnp.zeros((s.d_inner,), dtype),
        "w_out": init(k[4], (s.d_inner, D), dtype),
    }


def init_ssm_cache(batch, cfg, dtype):
    s = cfg.ssm
    conv_dim = s.d_inner + 2 * s.n_groups * s.d_state
    return SSMCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, s.n_heads, s.d_state, s.head_dim),
                        jnp.float32),
    )
