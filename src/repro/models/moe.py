"""Top-k MoE with sort-based capacity dispatch (pjit/GSPMD-friendly).

Dispatch is a static-shape argsort bucketing: tokens are sorted by expert id,
assigned a position within their expert bucket, and scattered into an
(E, C, D) buffer (capacity C; overflow tokens are dropped, standard for
capacity-based MoE).  The expert FFN is a single batched einsum over E so the
expert axis shards cleanly over the `model` mesh axis.  This is the SPMM-like
"route only what's needed" pattern of the paper applied to expert routing.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.sharding.compat import shard_map
from repro.sharding.context import constrain


def _capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k / n_experts * factor) + 1
    return max(c, 4)


def moe_ffn(buf: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    """buf: (E, C, D); expert weights (E, D, F) / (E, F, D)."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", g * u, w_down)


def _dispatch_compute(flat, logits, w_gate, w_up, w_down, *, n_experts,
                      top_k, capacity, expert_offset=0):
    """Sort-based capacity dispatch over `n_experts` LOCAL experts.

    flat: (T, D); logits: (T, E_total) f32.  Tokens routed to experts
    outside [expert_offset, expert_offset + n_experts) are masked out.
    Returns (out (T, D), gate (T, K), expert (T, K)).
    """
    T, D = flat.shape
    E, K, C = n_experts, top_k, capacity
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)                   # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    eflat = expert.reshape(T * K) - expert_offset
    local = (eflat >= 0) & (eflat < E)
    eflat = jnp.where(local, eflat, E)                       # E == "drop"
    gflat = gate.reshape(T * K)
    tok = jnp.arange(T * K) // K
    order = jnp.argsort(eflat)
    es, ts, gs = eflat[order], tok[order], gflat[order]
    starts = jnp.searchsorted(es, jnp.arange(E))
    pos = jnp.arange(T * K) - starts[jnp.minimum(es, E - 1)]
    keep = (pos < C) & (es < E)
    slot = jnp.where(keep, es * C + pos, E * C)

    buf = jnp.zeros((E * C + 1, D), flat.dtype)
    buf = buf.at[slot].set(flat[ts] * keep[:, None].astype(flat.dtype))
    out_buf = moe_ffn(buf[:-1].reshape(E, C, D), w_gate, w_up, w_down)
    out_flat = jnp.concatenate(
        [out_buf.reshape(E * C, D), jnp.zeros((1, D), flat.dtype)])
    gathered = out_flat[slot] * (gs * keep)[:, None].astype(flat.dtype)
    out = jnp.zeros((T, D), flat.dtype).at[ts].add(gathered)
    return out, probs, expert


def moe_block(x: jax.Array, p, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D).  Returns (out, aux_loss).

    With REPRO_TUNING=moe_ep and an active sharding context, dispatch runs
    expert-parallel inside shard_map (H2); otherwise the pjit/GSPMD global
    scatter path (baseline).
    """
    from repro import tuning
    from repro.sharding.context import current_mesh
    mesh = current_mesh()
    if tuning.on("moe_ep") and mesh is not None:
        return _moe_block_ep(x, p, cfg, mesh)
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = _capacity(T, E, K, m.capacity_factor)
    flat = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", flat.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)                   # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch-style) ----
    me = probs.mean(axis=0)                                  # (E,)
    one_hot = jax.nn.one_hot(expert[:, 0], E, dtype=jnp.float32)
    ce = one_hot.mean(axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    eflat = expert.reshape(T * K)
    gflat = gate.reshape(T * K)
    tok = jnp.arange(T * K) // K
    order = jnp.argsort(eflat)
    es, ts, gs = eflat[order], tok[order], gflat[order]
    starts = jnp.searchsorted(es, jnp.arange(E))             # (E,)
    pos = jnp.arange(T * K) - starts[es]
    keep = pos < C
    slot = jnp.where(keep, es * C + pos, E * C)              # drop -> scratch

    buf = jnp.zeros((E * C + 1, D), x.dtype)
    buf = buf.at[slot].set(flat[ts] * keep[:, None].astype(x.dtype))
    buf = constrain(buf[:-1].reshape(E, C, D), "tp", "dp")
    out_buf = constrain(moe_ffn(buf, p["w_gate"], p["w_up"], p["w_down"]),
                        "tp", "dp")
    out_flat = jnp.concatenate(
        [out_buf.reshape(E * C, D), jnp.zeros((1, D), x.dtype)])
    gathered = out_flat[slot] * (gs * keep)[:, None].astype(x.dtype)
    out = constrain(jnp.zeros((T, D), x.dtype).at[ts].add(gathered), "dp")

    # ---- shared experts (always-on dense path) ----
    if m.n_shared_experts:
        g = jax.nn.silu(jnp.einsum("td,df->tf", flat, p["shared_w_gate"]))
        u = jnp.einsum("td,df->tf", flat, p["shared_w_up"])
        out = out + jnp.einsum("tf,fd->td", g * u, p["shared_w_down"])

    return out.reshape(B, S, D), aux


def _moe_block_ep(x, p, cfg, mesh):
    """H2: expert-parallel MoE in shard_map.

    Tokens are sharded over the dp axes and REPLICATED over `model`; each
    model-chip dispatches every local token to ITS E/M experts only and the
    per-chip partial outputs (zero where not routed here) psum over
    `model` — one activation-sized collective per MoE layer instead of the
    replicated global scatter.  This is DEAL's "only the owners compute,
    exchange the small results" applied to expert routing.
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import logical_axes, shard_if_divisible

    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    ax = logical_axes(mesh)
    dp, tp = ax["dp"], ax["tp"]
    M = mesh.shape["model"]
    assert E % M == 0, (E, M)
    E_loc = E // M
    import math
    b_ax = shard_if_divisible(mesh, B, dp)
    n_dp = 1 if b_ax is None else math.prod(mesh.shape[a] for a in dp)
    T_loc = (B // n_dp) * S
    C = _capacity(T_loc, E, K, m.capacity_factor)

    def local(x, router, w_gate, w_up, w_down):
        # x: (B_loc, S, D); experts: (E_loc, D, F)
        x = x.reshape(-1, D)
        logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                            router.astype(jnp.float32))
        m_idx = jax.lax.axis_index("model")
        out, probs, expert = _dispatch_compute(
            x, logits, w_gate, w_up, w_down, n_experts=E_loc, top_k=K,
            capacity=C, expert_offset=m_idx * E_loc)
        out = jax.lax.psum(out, "model")
        # aux loss (identical on every model chip before psum-mean)
        me = probs.mean(axis=0)
        ce = jax.nn.one_hot(expert[:, 0], E, dtype=jnp.float32).mean(axis=0)
        aux = E * jnp.sum(me * ce)
        if b_ax is not None:
            aux = jax.lax.pmean(aux, dp)
        return out, aux

    in_specs = (P(b_ax, None, None), P(None, None),
                P("model", None, None), P("model", None, None),
                P("model", None, None))
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(b_ax, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    out = out.reshape(B, S, D)

    if m.n_shared_experts:
        flat = x.reshape(B * S, D)
        g = jax.nn.silu(jnp.einsum("td,df->tf", flat, p["shared_w_gate"]))
        u = jnp.einsum("td,df->tf", flat, p["shared_w_up"])
        out = out + jnp.einsum("tf,fd->td", g * u,
                               p["shared_w_down"]).reshape(B, S, D)
    return out, aux


def init_moe_params(rng, cfg, dtype):
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_ff_expert
    k = jax.random.split(rng, 7)
    init = jax.nn.initializers.normal(0.02)
    p = {
        "router": init(k[0], (D, E), jnp.float32),
        "w_gate": init(k[1], (E, D, F), dtype),
        "w_up": init(k[2], (E, D, F), dtype),
        "w_down": init(k[3], (E, F, D), dtype),
    }
    if m.n_shared_experts:
        Fs = F * m.n_shared_experts
        p["shared_w_gate"] = init(k[4], (D, Fs), dtype)
        p["shared_w_up"] = init(k[5], (D, Fs), dtype)
        p["shared_w_down"] = init(k[6], (Fs, D), dtype)
    return p
