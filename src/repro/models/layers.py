"""Shared transformer building blocks (pure functions, bf16-friendly)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def swiglu_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    # broadcast over the head axis: (..., S, 1, half)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, dim: int) -> jax.Array:
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed_tokens(embedding: jax.Array, tokens: jax.Array,
                 scale: Optional[float] = None) -> jax.Array:
    out = jnp.take(embedding, tokens, axis=0)
    if scale is not None:
        out = out * jnp.asarray(scale, out.dtype)
    return out
