"""Composable model zoo: one functional forward/decode per architecture family.

Families: dense (incl. sliding-window local:global), moe (interleaved &
first-dense), ssm (Mamba-2), hybrid (Mamba-2 + shared attention), audio
(enc-dec backbone, stub frontend), vlm (decoder backbone, stub projector).

Everything is `lax.scan` over stacked per-layer params so the lowered HLO is
O(1) in depth — essential for the 512-device dry-runs.

API:
  init_params(cfg, rng)            real weights (smoke tests / examples)
  abstract_params(cfg)             ShapeDtypeStructs via eval_shape (dry-run)
  forward(cfg, params, batch, mode, return_cache)   train / prefill
  decode_step(cfg, params, cache, batch)            one-token serve step
  init_cache(cfg, batch, seq) / abstract_cache(...)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (decode_attention, flash_attention_jnp,
                                    mla_decode, mla_new_cache_entries,
                                    mla_prefill)
from repro.models.layers import (embed_tokens, gelu_mlp, layer_norm, rms_norm,
                                 rope, sinusoidal_positions, swiglu_mlp)
from repro.models.moe import init_moe_params, moe_block
from repro.sharding.context import constrain

_BIG_WINDOW = 1 << 30
Params = Dict[str, Any]


# ======================================================================
# layer metadata (static per config)
# ======================================================================

def layer_meta(cfg: ModelConfig):
    """Per-layer (window, rope_theta) arrays for the dense stack."""
    windows, thetas = [], []
    for l in range(cfg.n_layers):
        is_global = (cfg.global_interval == 0
                     or (l + 1) % cfg.global_interval == 0)
        if cfg.sliding_window is not None and not is_global:
            windows.append(cfg.sliding_window)
            thetas.append(10_000.0)          # gemma3: local layers use 10k
        else:
            windows.append(_BIG_WINDOW)
            thetas.append(cfg.rope_theta)
    return (jnp.asarray(windows, jnp.int32), jnp.asarray(thetas, jnp.float32))


# ======================================================================
# parameter init
# ======================================================================

def _init_attn(rng, cfg: ModelConfig, dtype, d_in=None):
    D = d_in or cfg.d_model
    hd = cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    k = jax.random.split(rng, 4)
    init = jax.nn.initializers.normal(0.02)
    p = {
        "wq": init(k[0], (D, H * hd), dtype),
        "wk": init(k[1], (D, K * hd), dtype),
        "wv": init(k[2], (D, K * hd), dtype),
        "wo": init(k[3], (H * hd, cfg.d_model), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _init_mla(rng, cfg: ModelConfig, dtype):
    a, D, H = cfg.mla, cfg.d_model, cfg.n_heads
    k = jax.random.split(rng, 5)
    init = jax.nn.initializers.normal(0.02)
    return {
        "wq_a": init(k[0], (D, a.q_lora_rank), dtype),
        "q_norm": jnp.zeros((a.q_lora_rank,), dtype),
        "wq_b": init(k[1], (a.q_lora_rank,
                            H * (a.nope_head_dim + a.rope_head_dim)), dtype),
        "wkv_a": init(k[2], (D, a.kv_lora_rank + a.rope_head_dim), dtype),
        "kv_norm": jnp.zeros((a.kv_lora_rank,), dtype),
        "wkv_b": init(k[3], (a.kv_lora_rank,
                             H * (a.nope_head_dim + a.v_head_dim)), dtype),
        "wo": init(k[4], (H * a.v_head_dim, D), dtype),
    }


def _init_mlp(rng, cfg: ModelConfig, dtype, d_ff=None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    k = jax.random.split(rng, 3)
    init = jax.nn.initializers.normal(0.02)
    return {
        "w_gate": init(k[0], (D, F), dtype),
        "w_up": init(k[1], (D, F), dtype),
        "w_down": init(k[2], (F, D), dtype),
    }


def _init_dense_block(rng, cfg, dtype, d_ff=None):
    k = jax.random.split(rng, 2)
    return {
        "pre_attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": _init_attn(k[0], cfg, dtype),
        "pre_mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        "mlp": _init_mlp(k[1], cfg, dtype, d_ff),
    }


def _stack(rngs, fn):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[fn(r) for r in rngs])


def _stack_n(rng, fn, n):
    """Like _stack but supports n == 0 (empty scanned stacks)."""
    if n == 0:
        proto = fn(rng)
        return jax.tree.map(lambda x: jnp.zeros((0,) + x.shape, x.dtype),
                            proto)
    return _stack(jax.random.split(rng, n), fn)


def init_params(cfg: ModelConfig, rng: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    init = jax.nn.initializers.normal(0.02)
    k = jax.random.split(rng, 8)
    params: Params = {
        "embed": init(k[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init(k[1], (cfg.d_model, cfg.vocab_size), dtype)

    if cfg.frontend is not None:
        params["projector"] = init(k[2], (cfg.frontend_dim, cfg.d_model),
                                   dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        rngs = jax.random.split(k[3], cfg.n_layers)
        params["blocks"] = _stack(rngs, lambda r: _init_dense_block(r, cfg, dtype))
    elif fam == "moe":
        params.update(_init_moe_arch(cfg, k[3], dtype))
    elif fam == "ssm":
        rngs = jax.random.split(k[3], cfg.n_layers)
        params["blocks"] = _stack(rngs, lambda r: {
            "pre_norm": jnp.zeros((cfg.d_model,), dtype),
            "ssm": ssm_mod.init_ssm_params(r, cfg, dtype)})
    elif fam == "hybrid":
        params.update(_init_hybrid_arch(cfg, k[3], dtype))
    elif fam == "audio":
        params.update(_init_audio_arch(cfg, k[3], dtype))
    else:
        raise ValueError(fam)
    return params


def _init_moe_arch(cfg, rng, dtype):
    m = cfg.moe
    k = jax.random.split(rng, 4)
    out: Params = {}
    if m.first_dense_layers:       # deepseek-v2 layout
        assert m.period == 1
        n_moe = cfg.n_layers - m.first_dense_layers
        rngs = jax.random.split(k[0], m.first_dense_layers)
        out["first_blocks"] = _stack(rngs, lambda r: {
            "pre_attn_norm": jnp.zeros((cfg.d_model,), dtype),
            "attn": _init_mla(r, cfg, dtype),
            "pre_mlp_norm": jnp.zeros((cfg.d_model,), dtype),
            "mlp": _init_mlp(r, cfg, dtype, m.d_ff_dense)})
        rngs = jax.random.split(k[1], n_moe)
        out["blocks"] = _stack(rngs, lambda r: {
            "pre_attn_norm": jnp.zeros((cfg.d_model,), dtype),
            "attn": _init_mla(r, cfg, dtype),
            "pre_mlp_norm": jnp.zeros((cfg.d_model,), dtype),
            "moe": init_moe_params(r, cfg, dtype)})
    else:                          # llama4 layout: (dense, moe) super-blocks
        assert m.period == 2 and cfg.n_layers % 2 == 0
        n_super = cfg.n_layers // 2
        rngs = jax.random.split(k[0], n_super)

        def super_block(r):
            r1, r2, r3 = jax.random.split(r, 3)
            return {
                "dense": _init_dense_block(r1, cfg, dtype,
                                           cfg.moe.d_ff_dense or cfg.d_ff),
                "moe_attn": {
                    "pre_attn_norm": jnp.zeros((cfg.d_model,), dtype),
                    "attn": _init_attn(r2, cfg, dtype),
                    "pre_mlp_norm": jnp.zeros((cfg.d_model,), dtype)},
                "moe": init_moe_params(r3, cfg, dtype),
            }
        out["super_blocks"] = _stack(rngs, super_block)
    return out


def _init_hybrid_arch(cfg, rng, dtype):
    """zamba2: 13 super-blocks of (6 mamba + shared attn w/ LoRA) + 3 tail."""
    n_super, inner = _hybrid_layout(cfg)
    tail = cfg.n_layers - n_super * inner
    k = jax.random.split(rng, 5)
    init = jax.nn.initializers.normal(0.02)
    hd, H, K, D = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    r = cfg.shared_attn_lora_rank

    def mamba(rr):
        return {"pre_norm": jnp.zeros((D,), dtype),
                "ssm": ssm_mod.init_ssm_params(rr, cfg, dtype)}

    def lora(rr):
        ks = jax.random.split(rr, 6)
        return {
            "a_q": init(ks[0], (D, r), dtype), "b_q": jnp.zeros((r, H * hd), dtype),
            "a_k": init(ks[1], (D, r), dtype), "b_k": jnp.zeros((r, K * hd), dtype),
            "a_v": init(ks[2], (D, r), dtype), "b_v": jnp.zeros((r, K * hd), dtype),
        }

    rngs = jax.random.split(k[0], n_super * inner)
    mb = _stack(rngs, mamba)
    mb = jax.tree.map(lambda x: x.reshape((n_super, inner) + x.shape[1:]), mb)
    out = {
        "mamba_blocks": mb,
        "tail_blocks": _stack_n(k[1], mamba, tail),
        "shared_attn": {
            "pre_attn_norm": jnp.zeros((D,), dtype),
            "attn": _init_attn(k[2], cfg, dtype),
            "pre_mlp_norm": jnp.zeros((D,), dtype),
            "mlp": _init_mlp(k[3], cfg, dtype),
        },
        "lora": _stack(jax.random.split(k[4], n_super), lora),
    }
    return out


def _hybrid_layout(cfg) -> Tuple[int, int]:
    inner = cfg.attn_interval
    n_super = cfg.n_layers // inner
    return n_super, inner


def _init_audio_arch(cfg, rng, dtype):
    """whisper: LayerNorm enc-dec with biased attention + GELU MLPs."""
    D, F = cfg.d_model, cfg.d_ff
    init = jax.nn.initializers.normal(0.02)
    k = jax.random.split(rng, 3)

    def ln():
        return {"scale": jnp.ones((D,), dtype), "bias": jnp.zeros((D,), dtype)}

    def gmlp(rr):
        k1, k2 = jax.random.split(rr)
        return {"w_in": init(k1, (D, F), dtype), "b_in": jnp.zeros((F,), dtype),
                "w_out": init(k2, (F, D), dtype), "b_out": jnp.zeros((D,), dtype)}

    def enc_block(rr):
        r1, r2 = jax.random.split(rr)
        return {"ln1": ln(), "attn": _init_attn(r1, cfg, dtype),
                "ln2": ln(), "mlp": gmlp(r2)}

    def dec_block(rr):
        r1, r2, r3 = jax.random.split(rr, 3)
        return {"ln1": ln(), "self_attn": _init_attn(r1, cfg, dtype),
                "ln2": ln(), "cross_attn": _init_attn(r2, cfg, dtype),
                "ln3": ln(), "mlp": gmlp(r3)}

    return {
        "enc_blocks": _stack(jax.random.split(k[0], cfg.n_encoder_layers),
                             enc_block),
        "enc_final_ln": ln(),
        "dec_blocks": _stack(jax.random.split(k[1], cfg.n_layers), dec_block),
        "dec_final_ln": ln(),
    }


def abstract_params(cfg: ModelConfig) -> Params:
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


# ======================================================================
# attention sub-blocks
# ======================================================================

def _qkv(x, p, cfg, lora=None):
    hd = cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if lora is not None:
        q = q + jnp.einsum("bsd,dr,re->bse", x, lora["a_q"], lora["b_q"])
        k = k + jnp.einsum("bsd,dr,re->bse", x, lora["a_k"], lora["b_k"])
        v = v + jnp.einsum("bsd,dr,re->bse", x, lora["a_v"], lora["b_v"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, K, hd),
            v.reshape(B, S, K, hd))


def _gqa_full(x, p, cfg, positions, theta, window, causal=True, lora=None):
    """Full-sequence GQA attention (train/prefill).  Returns (out, k, v)."""
    q, k, v = _qkv(x, p, cfg, lora)
    if theta is not None:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    o = flash_attention_jnp(q, k, v, causal=causal, window=window)
    B, S = x.shape[:2]
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), p["wo"])
    return out, k, v


def _update_cache(cache, new, pos):
    """Per-sequence cache write: cache (B,S,...) <- new (B,1,...) at pos.

    Scalar pos (aligned decode, the dry-run path) uses ONE
    dynamic_update_slice — GSPMD keeps the sharded cache in place.  The
    vmap'd per-row path (ragged continuous batching) makes GSPMD gather
    the cache; only the CPU serving engine takes it (H4-iter3).
    """
    if jnp.ndim(pos) == 0:
        return jax.lax.dynamic_update_slice(
            cache, new.astype(cache.dtype),
            (0, pos) + (0,) * (cache.ndim - 2))
    return jax.vmap(
        lambda c, u, p: jax.lax.dynamic_update_slice(
            c, u.astype(c.dtype), (p,) + (0,) * (c.ndim - 1))
    )(cache, new, pos)


def _gqa_decode(x, p, cfg, pos, theta, window, kc, vc, lora=None):
    """One-token GQA decode; updates (kc, vc) at per-sequence ``pos``
    (scalar or (B,) — continuous batching slots may differ)."""
    from repro import tuning
    from repro.models.attention import cp_decode_attention
    from repro.sharding.context import current_mesh

    q, k, v = _qkv(x, p, cfg, lora)
    B = x.shape[0]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos), (B,))
    if theta is not None:
        q = rope(q, pos_vec[:, None], theta)
        k = rope(k, pos_vec[:, None], theta)
    kc = _update_cache(kc, k, pos)
    vc = _update_cache(vc, v, pos)
    mesh = current_mesh()
    if (tuning.on("cp_decode") and mesh is not None and B == 1
            and kc.shape[1] % mesh.shape["data"] == 0):
        # H3: seq-sharded cache — exchange softmax partials, not the cache
        o = cp_decode_attention(q, kc, vc, cache_len=pos_vec + 1,
                                mesh=mesh, window=window)
    else:
        o = decode_attention(q, kc, vc, cache_len=pos_vec + 1,
                             window=window)
    out = jnp.einsum("bse,ed->bsd", o.reshape(x.shape[0], 1, -1), p["wo"])
    return out, kc, vc


def _cross_attn(x, p, cfg, k, v):
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    o = flash_attention_jnp(q, k, v, causal=False)
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), p["wo"])


def _cross_kv(enc_out, p, cfg):
    hd = cfg.resolved_head_dim
    B, S, _ = enc_out.shape
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"])
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(B, S, cfg.n_kv_heads, hd),
            v.reshape(B, S, cfg.n_kv_heads, hd))


# ======================================================================
# forward (train / prefill)
# ======================================================================

def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, mode: str = "train", return_cache: bool = False,
            return_hidden: bool = False, remat: bool = True):
    """Returns (logits_or_hidden, aux_loss[, cache]).

    ``return_hidden=True`` skips the unembedding and returns the final-norm
    hidden states — used with the chunked CE loss and with last-token-only
    prefill logits so (B, S, V) logits are never materialized.
    """
    fam = cfg.family
    if fam == "audio":
        return _audio_forward(cfg, params, batch, return_cache=return_cache,
                              return_hidden=return_hidden,
                              remat=remat and mode == "train")
    x, positions = _embed_inputs(cfg, params, batch)
    use_remat = remat and mode == "train"

    if fam in ("dense", "vlm"):
        x, aux, cache = _dense_stack(cfg, params, x, positions,
                                     return_cache, use_remat)
    elif fam == "moe":
        x, aux, cache = _moe_stack(cfg, params, x, positions,
                                   return_cache, use_remat)
    elif fam == "ssm":
        x, aux, cache = _ssm_stack(cfg, params, x, return_cache, use_remat)
    elif fam == "hybrid":
        x, aux, cache = _hybrid_stack(cfg, params, x, positions,
                                      return_cache, use_remat)
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out = x if return_hidden else unembed(cfg, params, x)
    if return_cache:
        return out, aux, cache
    return out, aux


def unembed(cfg, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)


def final_hidden(cfg, params, x):
    """Final norm only (used with chunked loss to avoid full logits)."""
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def _embed_inputs(cfg, params, batch):
    scale = cfg.d_model ** 0.5 if cfg.arch_id.startswith("gemma") else None
    tok_emb = embed_tokens(params["embed"], batch["tokens"], scale)
    if cfg.frontend == "vision":
        patches = jnp.einsum("bnf,fd->bnd", batch["patches"],
                             params["projector"])
        x = jnp.concatenate([patches.astype(tok_emb.dtype), tok_emb], axis=1)
    else:
        x = tok_emb
    x = constrain(x, "dp", "tp")
    positions = jnp.arange(x.shape[1])
    return x, positions


def _maybe_remat(fn, use_remat):
    return jax.checkpoint(fn) if use_remat else fn


def _dense_stack(cfg, params, x, positions, return_cache, use_remat):
    windows, thetas = layer_meta(cfg)

    def body(h, xs):
        p, window, theta = xs
        h = constrain(h, "dp", "tp")
        a, k, v = _gqa_full(rms_norm(h, p["pre_attn_norm"], cfg.norm_eps),
                            p["attn"], cfg, positions, theta, window)
        h = h + a
        h = h + swiglu_mlp(rms_norm(h, p["pre_mlp_norm"], cfg.norm_eps),
                           **p["mlp"])
        return h, (k, v) if return_cache else None

    x, kv = jax.lax.scan(_maybe_remat(body, use_remat), x,
                         (params["blocks"], windows, thetas))
    cache = {"k": kv[0], "v": kv[1]} if return_cache else None
    return x, jnp.float32(0.0), cache


def _moe_stack(cfg, params, x, positions, return_cache, use_remat):
    m = cfg.moe
    if m.first_dense_layers:          # deepseek-v2: MLA + (dense then moe)
        def first_body(h, p):
            h = constrain(h, "dp", "tp")
            a, ckv, krope = mla_prefill(
                rms_norm(h, p["pre_attn_norm"], cfg.norm_eps), p["attn"],
                cfg, positions)
            h = h + a
            h = h + swiglu_mlp(rms_norm(h, p["pre_mlp_norm"], cfg.norm_eps),
                               **p["mlp"])
            return h, (ckv, krope) if return_cache else None

        def moe_body(carry, p):
            h, aux = carry
            h = constrain(h, "dp", "tp")
            a, ckv, krope = mla_prefill(
                rms_norm(h, p["pre_attn_norm"], cfg.norm_eps), p["attn"],
                cfg, positions)
            h = h + a
            mo, a_l = moe_block(rms_norm(h, p["pre_mlp_norm"], cfg.norm_eps),
                                p["moe"], cfg)
            return (h + mo, aux + a_l), (ckv, krope) if return_cache else None

        x, first_kv = jax.lax.scan(_maybe_remat(first_body, use_remat), x,
                                   params["first_blocks"])
        (x, aux), kv = jax.lax.scan(_maybe_remat(moe_body, use_remat),
                                    (x, jnp.float32(0.0)), params["blocks"])
        cache = None
        if return_cache:
            cache = {"first_c_kv": first_kv[0], "first_k_rope": first_kv[1],
                     "c_kv": kv[0], "k_rope": kv[1]}
        return x, aux, cache

    # llama4: (dense, moe) super-blocks
    windows = jnp.full((cfg.n_layers // 2,), _BIG_WINDOW, jnp.int32)

    def body(carry, xs):
        h, aux = carry
        p, window = xs
        h = constrain(h, "dp", "tp")
        d = p["dense"]
        a, k1, v1 = _gqa_full(rms_norm(h, d["pre_attn_norm"], cfg.norm_eps),
                              d["attn"], cfg, positions, cfg.rope_theta,
                              window)
        h = h + a
        h = h + swiglu_mlp(rms_norm(h, d["pre_mlp_norm"], cfg.norm_eps),
                           **d["mlp"])
        ma = p["moe_attn"]
        a, k2, v2 = _gqa_full(rms_norm(h, ma["pre_attn_norm"], cfg.norm_eps),
                              ma["attn"], cfg, positions, cfg.rope_theta,
                              window)
        h = h + a
        mo, a_l = moe_block(rms_norm(h, ma["pre_mlp_norm"], cfg.norm_eps),
                            p["moe"], cfg)
        h = h + mo
        ys = None
        if return_cache:
            ys = (jnp.stack([k1, k2]), jnp.stack([v1, v2]))
        return (h, aux + a_l), ys

    (x, aux), kv = jax.lax.scan(_maybe_remat(body, use_remat),
                                (x, jnp.float32(0.0)),
                                (params["super_blocks"], windows))
    cache = {"k": kv[0], "v": kv[1]} if return_cache else None
    return x, aux, cache


def _ssm_stack(cfg, params, x, return_cache, use_remat):
    def body(h, p):
        h = constrain(h, "dp", "tp")
        o = ssm_mod.mamba2_block(
            rms_norm(h, p["pre_norm"], cfg.norm_eps), p["ssm"], cfg,
            return_state=return_cache)
        if return_cache:
            o, c = o
            return h + o, c
        return h + o, None

    x, states = jax.lax.scan(_maybe_remat(body, use_remat), x,
                             params["blocks"])
    cache = {"ssm": states} if return_cache else None
    return x, jnp.float32(0.0), cache


def _hybrid_stack(cfg, params, x, positions, return_cache, use_remat):
    n_super, inner = _hybrid_layout(cfg)
    windows, theta = _BIG_WINDOW, cfg.rope_theta
    shared = params["shared_attn"]

    def mamba_body(h, p):
        h = constrain(h, "dp", "tp")
        o = ssm_mod.mamba2_block(
            rms_norm(h, p["pre_norm"], cfg.norm_eps), p["ssm"], cfg,
            return_state=return_cache)
        if return_cache:
            o, c = o
            return h + o, c
        return h + o, None

    def super_body(h, xs):
        mb, lora = xs
        h, mstates = jax.lax.scan(mamba_body, h, mb)
        a, k, v = _gqa_full(
            rms_norm(h, shared["pre_attn_norm"], cfg.norm_eps),
            shared["attn"], cfg, positions, theta, windows, lora=lora)
        h = h + a
        h = h + swiglu_mlp(rms_norm(h, shared["pre_mlp_norm"], cfg.norm_eps),
                           **shared["mlp"])
        return h, (k, v, mstates) if return_cache else None

    x, ys = jax.lax.scan(_maybe_remat(super_body, use_remat), x,
                         (params["mamba_blocks"], params["lora"]))
    x, tail_states = jax.lax.scan(mamba_body, x, params["tail_blocks"])
    cache = None
    if return_cache:
        cache = {"k": ys[0], "v": ys[1], "mamba": ys[2],
                 "tail": tail_states}
    return x, jnp.float32(0.0), cache


def _audio_forward(cfg, params, batch, *, return_cache, return_hidden,
                   remat):
    frames, tokens = batch["frames"], batch["tokens"]
    enc_out = encode_audio(cfg, params, frames)
    x = embed_tokens(params["embed"], tokens)
    S = x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]

    def body(h, p):
        h = constrain(h, "dp", "tp")
        a, k, v = _gqa_full(
            layer_norm(h, p["ln1"]["scale"], p["ln1"]["bias"]),
            p["self_attn"], cfg, jnp.arange(S), None, _BIG_WINDOW)
        h = h + a
        ck, cv = _cross_kv(enc_out, p["cross_attn"], cfg)
        h = h + _cross_attn(layer_norm(h, p["ln2"]["scale"], p["ln2"]["bias"]),
                            p["cross_attn"], cfg, ck, cv)
        h = h + gelu_mlp(layer_norm(h, p["ln3"]["scale"], p["ln3"]["bias"]),
                         **p["mlp"])
        return h, (k, v, ck, cv) if return_cache else None

    x, kvs = jax.lax.scan(_maybe_remat(body, remat), x, params["dec_blocks"])
    x = layer_norm(x, params["dec_final_ln"]["scale"],
                   params["dec_final_ln"]["bias"])
    out = x if return_hidden else unembed(cfg, params, x)
    if return_cache:
        cache = {"k": kvs[0], "v": kvs[1],
                 "cross_k": kvs[2], "cross_v": kvs[3]}
        return out, jnp.float32(0.0), cache
    return out, jnp.float32(0.0)


def encode_audio(cfg, params, frames):
    """Whisper encoder over stub frame embeddings (B, S_enc, fd)."""
    x = jnp.einsum("bsf,fd->bsd", frames, params["projector"])
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(h, p):
        h = constrain(h, "dp", "tp")
        a, _, _ = _gqa_full(
            layer_norm(h, p["ln1"]["scale"], p["ln1"]["bias"]), p["attn"],
            cfg, jnp.arange(h.shape[1]), None, _BIG_WINDOW, causal=False)
        h = h + a
        h = h + gelu_mlp(layer_norm(h, p["ln2"]["scale"], p["ln2"]["bias"]),
                         **p["mlp"])
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layer_norm(x, params["enc_final_ln"]["scale"],
                      params["enc_final_ln"]["bias"])


# ======================================================================
# KV / state caches
# ======================================================================

def init_cache(cfg: ModelConfig, batch: int, seq: int,
               enc_len: Optional[int] = None):
    dtype = jnp.dtype(cfg.dtype)
    hd, K = cfg.resolved_head_dim, cfg.n_kv_heads
    fam = cfg.family
    if fam in ("dense", "vlm"):
        shape = (cfg.n_layers, batch, seq, K, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if fam == "moe":
        m = cfg.moe
        if m.first_dense_layers:   # deepseek MLA latent caches
            a = cfg.mla
            nf, nm = m.first_dense_layers, cfg.n_layers - m.first_dense_layers
            return {
                "first_c_kv": jnp.zeros((nf, batch, seq, a.kv_lora_rank), dtype),
                "first_k_rope": jnp.zeros((nf, batch, seq, a.rope_head_dim), dtype),
                "c_kv": jnp.zeros((nm, batch, seq, a.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((nm, batch, seq, a.rope_head_dim), dtype),
            }
        n_super = cfg.n_layers // 2
        shape = (n_super, 2, batch, seq, K, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if fam == "ssm":
        zero = ssm_mod.init_ssm_cache(batch, cfg, dtype)
        return {"ssm": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), zero)}
    if fam == "hybrid":
        n_super, inner = _hybrid_layout(cfg)
        tail = cfg.n_layers - n_super * inner
        zero = ssm_mod.init_ssm_cache(batch, cfg, dtype)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.zeros((n_super, inner) + a.shape, a.dtype), zero),
            "tail": jax.tree.map(
                lambda a: jnp.zeros((tail,) + a.shape, a.dtype), zero),
            "k": jnp.zeros((n_super, batch, seq, K, hd), dtype),
            "v": jnp.zeros((n_super, batch, seq, K, hd), dtype),
        }
    if fam == "audio":
        enc_len = enc_len or cfg.n_frontend_tokens
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, seq, K, hd), dtype),
            "v": jnp.zeros((L, batch, seq, K, hd), dtype),
            "cross_k": jnp.zeros((L, batch, enc_len, K, hd), dtype),
            "cross_v": jnp.zeros((L, batch, enc_len, K, hd), dtype),
        }
    raise ValueError(fam)


def abstract_cache(cfg, batch, seq, enc_len=None):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, seq, enc_len))


# ======================================================================
# decode step (one new token, cache at ``pos``)
# ======================================================================

def decode_step(cfg: ModelConfig, params: Params, cache,
                batch: Dict[str, jax.Array]):
    """batch = {"token": (B,1) int32, "pos": scalar int32}.

    Returns (logits (B,1,V) f32, new_cache).
    """
    token, pos = batch["token"], batch["pos"]
    fam = cfg.family
    scale = cfg.d_model ** 0.5 if cfg.arch_id.startswith("gemma") else None
    x = embed_tokens(params["embed"], token, scale)

    if fam in ("dense", "vlm"):
        windows, thetas = layer_meta(cfg)

        def body(h, xs):
            p, window, theta, kc, vc = xs
            a, kc, vc = _gqa_decode(
                rms_norm(h, p["pre_attn_norm"], cfg.norm_eps), p["attn"],
                cfg, pos, theta, window, kc, vc)
            h = h + a
            h = h + swiglu_mlp(rms_norm(h, p["pre_mlp_norm"], cfg.norm_eps),
                               **p["mlp"])
            return h, (kc, vc)

        x, (k, v) = jax.lax.scan(
            body, x, (params["blocks"], windows, thetas,
                      cache["k"], cache["v"]))
        new_cache = {"k": k, "v": v}

    elif fam == "moe":
        x, new_cache = _moe_decode(cfg, params, cache, x, pos)

    elif fam == "ssm":
        def body(h, xs):
            p, c = xs
            o, c = ssm_mod.mamba2_decode(
                rms_norm(h, p["pre_norm"], cfg.norm_eps), p["ssm"], cfg, c)
            return h + o, c

        x, new_ssm = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        new_cache = {"ssm": new_ssm}

    elif fam == "hybrid":
        x, new_cache = _hybrid_decode(cfg, params, cache, x, pos)

    elif fam == "audio":
        x, new_cache = _audio_decode(cfg, params, cache, x, pos)
    else:
        raise ValueError(fam)

    x = _final_norm_decode(cfg, params, x)
    logits = unembed(cfg, params, x)
    return logits, new_cache


def _final_norm_decode(cfg, params, x):
    if cfg.family == "audio":
        p = params["dec_final_ln"]
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def _moe_decode(cfg, params, cache, x, pos):
    m = cfg.moe
    if m.first_dense_layers:       # deepseek: absorbed MLA decode
        B = x.shape[0]
        pos_vec = jnp.broadcast_to(jnp.asarray(pos), (B,))

        def first_body(h, xs):
            p, ckv_c, kr_c = xs
            hn = rms_norm(h, p["pre_attn_norm"], cfg.norm_eps)
            ckv, krope = mla_new_cache_entries(hn, p["attn"], cfg, pos_vec)
            ckv_c = _update_cache(ckv_c, ckv, pos)
            kr_c = _update_cache(kr_c, krope, pos)
            a = mla_decode(hn, p["attn"], cfg, ckv_c, kr_c, pos_vec + 1,
                           pos_vec)
            h = h + a
            h = h + swiglu_mlp(rms_norm(h, p["pre_mlp_norm"], cfg.norm_eps),
                               **p["mlp"])
            return h, (ckv_c, kr_c)

        def moe_body(h, xs):
            p, ckv_c, kr_c = xs
            hn = rms_norm(h, p["pre_attn_norm"], cfg.norm_eps)
            ckv, krope = mla_new_cache_entries(hn, p["attn"], cfg, pos_vec)
            ckv_c = _update_cache(ckv_c, ckv, pos)
            kr_c = _update_cache(kr_c, krope, pos)
            a = mla_decode(hn, p["attn"], cfg, ckv_c, kr_c, pos_vec + 1,
                           pos_vec)
            h = h + a
            mo, _ = moe_block(rms_norm(h, p["pre_mlp_norm"], cfg.norm_eps),
                              p["moe"], cfg)
            return h + mo, (ckv_c, kr_c)

        x, first = jax.lax.scan(first_body, x,
                                (params["first_blocks"], cache["first_c_kv"],
                                 cache["first_k_rope"]))
        x, rest = jax.lax.scan(moe_body, x,
                               (params["blocks"], cache["c_kv"],
                                cache["k_rope"]))
        return x, {"first_c_kv": first[0], "first_k_rope": first[1],
                   "c_kv": rest[0], "k_rope": rest[1]}

    # llama4 super-blocks
    def body(h, xs):
        p, kc, vc = xs
        d = p["dense"]
        a, k1, v1 = _gqa_decode(
            rms_norm(h, d["pre_attn_norm"], cfg.norm_eps), d["attn"], cfg,
            pos, cfg.rope_theta, _BIG_WINDOW, kc[0], vc[0])
        h = h + a
        h = h + swiglu_mlp(rms_norm(h, d["pre_mlp_norm"], cfg.norm_eps),
                           **d["mlp"])
        ma = p["moe_attn"]
        a, k2, v2 = _gqa_decode(
            rms_norm(h, ma["pre_attn_norm"], cfg.norm_eps), ma["attn"], cfg,
            pos, cfg.rope_theta, _BIG_WINDOW, kc[1], vc[1])
        h = h + a
        mo, _ = moe_block(rms_norm(h, ma["pre_mlp_norm"], cfg.norm_eps),
                          p["moe"], cfg)
        h = h + mo
        return h, (jnp.stack([k1, k2]), jnp.stack([v1, v2]))

    x, (k, v) = jax.lax.scan(body, x,
                             (params["super_blocks"], cache["k"], cache["v"]))
    return x, {"k": k, "v": v}


def _hybrid_decode(cfg, params, cache, x, pos):
    shared = params["shared_attn"]

    def mamba_body(h, xs):
        p, c = xs
        o, c = ssm_mod.mamba2_decode(
            rms_norm(h, p["pre_norm"], cfg.norm_eps), p["ssm"], cfg, c)
        return h + o, c

    def super_body(h, xs):
        mb, lora, mcache, kc, vc = xs
        h, mcache = jax.lax.scan(mamba_body, h, (mb, mcache))
        a, kc, vc = _gqa_decode(
            rms_norm(h, shared["pre_attn_norm"], cfg.norm_eps),
            shared["attn"], cfg, pos, cfg.rope_theta, _BIG_WINDOW,
            kc, vc, lora=lora)
        h = h + a
        h = h + swiglu_mlp(rms_norm(h, shared["pre_mlp_norm"], cfg.norm_eps),
                           **shared["mlp"])
        return h, (mcache, kc, vc)

    x, (mamba_c, k, v) = jax.lax.scan(
        super_body, x, (params["mamba_blocks"], params["lora"],
                        cache["mamba"], cache["k"], cache["v"]))
    x, tail_c = jax.lax.scan(mamba_body, x,
                             (params["tail_blocks"], cache["tail"]))
    return x, {"mamba": mamba_c, "tail": tail_c, "k": k, "v": v}


def _audio_decode(cfg, params, cache, x, pos):
    B, S = x.shape[:2]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos), (B,))
    table = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + jnp.take(table, pos_vec, axis=0)[:, None].astype(x.dtype)

    def body(h, xs):
        p, kc, vc, ck, cv = xs
        a, kc, vc = _gqa_decode(
            layer_norm(h, p["ln1"]["scale"], p["ln1"]["bias"]),
            p["self_attn"], cfg, pos, None, _BIG_WINDOW, kc, vc)
        h = h + a
        h = h + _cross_attn(layer_norm(h, p["ln2"]["scale"], p["ln2"]["bias"]),
                            p["cross_attn"], cfg, ck, cv)
        h = h + gelu_mlp(layer_norm(h, p["ln3"]["scale"], p["ln3"]["bias"]),
                         **p["mlp"])
        return h, (kc, vc)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    return x, {"k": k, "v": v,
               "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
