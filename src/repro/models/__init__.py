from repro.models.transformer import (abstract_cache, abstract_params,
                                      decode_step, forward, init_cache,
                                      init_params)

__all__ = ["abstract_cache", "abstract_params", "decode_step", "forward",
           "init_cache", "init_params"]
