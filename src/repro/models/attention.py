"""Attention variants: GQA flash (chunked online-softmax), decode, MLA.

All math in f32 accumulators, inputs/outputs in the activation dtype.
The chunked prefill path is the pure-JAX twin of kernels/flash_attention.py
(the Pallas TPU kernel); tests assert they agree.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding.compat import shard_map
from repro.sharding.context import constrain

_NEG_INF = -1e30


def _mask(q_pos, kv_pos, *, causal: bool, window: Optional[int]):
    """(bq, bk) boolean mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - kv_pos[None, :]) < window
    return m


def simple_attention(q, k, v, *, q_offset=0, causal=True,
                     window: Optional[int] = None,
                     kv_valid_len: Optional[jax.Array] = None,
                     scale: Optional[float] = None):
    """Reference unchunked GQA attention.  q:(B,Sq,H,hd) k,v:(B,Skv,K,hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q.reshape(B, Sq, K, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(k.shape[1])
    m = _mask(q_pos, kv_pos, causal=causal, window=window)
    if kv_valid_len is not None:
        m &= (kv_pos < kv_valid_len)[None, :]
    s = jnp.where(m[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


class _Carry(NamedTuple):
    m: jax.Array
    l: jax.Array
    acc: jax.Array


def flash_attention_jnp(q, k, v, *, q_offset=0, causal=True,
                        window: Optional[int] = None,
                        q_block: int = 512, kv_block: int = 1024,
                        scale: Optional[float] = None):
    """Double-chunked online-softmax attention (memory O(block^2)).

    q: (B, Sq, H, hd); k, v: (B, Skv, K, hd) with H % K == 0.
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    vd = v.shape[-1]               # may differ from hd (MLA)
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    # pad ragged sequence lengths to block multiples (whisper's 1500 frames
    # etc.); padded kv is masked out, padded q rows are dropped at the end.
    Sq_orig, Skv_orig = Sq, Skv
    q_pad = (-Sq) % q_block
    kv_pad = (-Skv) % kv_block
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        Sq += q_pad
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        Skv += kv_pad
    nq, nk = Sq // q_block, Skv // kv_block

    # (nq, B, bq, K, G, hd) / (nk, B, bk, K, hd)
    qb = q.reshape(B, nq, q_block, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_block, K, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, K, vd).transpose(1, 0, 2, 3, 4)
    qb = constrain(qb, None, "dp")
    kb = constrain(kb, None, "dp")
    vb = constrain(vb, None, "dp")

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qf = qi.astype(jnp.float32) * scale
        q_pos = q_offset + iq * q_block + jnp.arange(q_block)

        @jax.checkpoint
        def kv_step(carry: _Carry, ki_vi_idx):
            # checkpointed: the bwd recomputes s/p per block (flash bwd)
            # instead of saving (bq, bk) score tensors per kv iteration.
            ki, vi, ik = ki_vi_idx
            kv_pos = ik * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qf, ki.astype(jnp.float32))
            s = constrain(s, "dp")
            msk = _mask(q_pos, kv_pos, causal=causal, window=window)
            msk &= (kv_pos < Skv_orig)[None, :]
            s = jnp.where(msk[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(carry.m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(carry.m - m_new)
            l_new = carry.l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vi.astype(jnp.float32))
            acc_new = carry.acc * corr[..., None] + pv
            return _Carry(m_new, l_new, acc_new), None

        init = _Carry(
            m=constrain(jnp.full((B, K, G, q_block), _NEG_INF, jnp.float32),
                        "dp"),
            l=constrain(jnp.zeros((B, K, G, q_block), jnp.float32), "dp"),
            acc=constrain(jnp.zeros((B, K, G, q_block, vd), jnp.float32),
                          "dp"),
        )
        carry, _ = jax.lax.scan(
            kv_step, init, (kb, vb, jnp.arange(nk)))
        out = carry.acc / jnp.maximum(carry.l, 1e-30)[..., None]
        # (B, K, G, bq, hd) -> (B, bq, K, G, hd)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, blocks = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    # (nq, B, bq, K, G, vd) -> (B, Sq, H, vd)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, vd)
    return out[:, :Sq_orig].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, cache_len,
                     window: Optional[int] = None,
                     scale: Optional[float] = None):
    """One-token GQA decode against a (B, S, K, hd) cache.

    ``cache_len``: number of valid cache entries per sequence — scalar or
    (B,) vector (continuous batching: slots may be at different lengths).
    The new token sits at cache_len - 1.  O(S) compute per token.
    """
    from repro import tuning

    B, Sq, H, hd = q.shape
    assert Sq == 1
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q.reshape(B, K, G, hd).astype(jnp.float32) * scale
    if tuning.on("gqa_cache_seq"):
        # cache S is tp-sharded: replicate the (tiny) q over `model` so the
        # score einsum stays shard-local instead of gathering the cache
        qf = constrain(qf, "dp", None, None, None)
        s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
        s = constrain(s, "dp", None, None, "tp")
    else:
        s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
        s = constrain(s, "dp")
    kv_pos = jnp.arange(S)
    clen = jnp.broadcast_to(jnp.asarray(cache_len), (B,))[:, None]  # (B,1)
    msk = kv_pos[None, :] < clen
    if window is not None:
        msk &= (clen - 1 - kv_pos[None, :]) < window
    s = jnp.where(msk[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def cp_decode_attention(q, k_cache, v_cache, *, cache_len, mesh,
                        window: Optional[int] = None,
                        scale: Optional[float] = None):
    """H3: sequence-parallel decode attention (long_500k path).

    The KV cache is sharded over `data` along the sequence; instead of
    letting GSPMD all-gather the whole cache per layer, each shard computes
    its local (m, l, acc) online-softmax partials and ONLY those are
    psum/pmax'd — the paper's "exchange the small partial results, never
    the big tensor" (§3.4 SPMM / SDDMM-(ii)) applied to attention.
    Collective payload: O(B*H*hd) per layer vs O(S*K*hd).
    """
    from jax.sharding import PartitionSpec as P

    B, Sq, H, hd = q.shape
    assert Sq == 1
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    n_shards = mesh.shape["data"]
    S_loc = S // n_shards
    scale_ = scale if scale is not None else 1.0 / math.sqrt(hd)

    def local(q, kc, vc, clen):
        i = jax.lax.axis_index("data")
        kv_pos = i * S_loc + jnp.arange(S_loc)
        qf = q.reshape(B, K, G, hd).astype(jnp.float32) * scale_
        s = jnp.einsum("bkgd,bskd->bkgs", qf, kc.astype(jnp.float32))
        cl = jnp.broadcast_to(clen, (B,))[:, None]
        msk = kv_pos[None, :] < cl
        if window is not None:
            msk &= (cl - 1 - kv_pos[None, :]) < window
        s = jnp.where(msk[:, None, None, :], s, _NEG_INF)
        m_loc = s.max(axis=-1)                          # (B,K,G)
        m_glob = jax.lax.pmax(m_loc, "data")
        p = jnp.exp(s - m_glob[..., None])
        l = jax.lax.psum(p.sum(axis=-1), "data")
        acc = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
        acc = jax.lax.psum(acc, "data")
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(B, 1, H, hd).astype(q.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, "data", None, None),
                  P(None, "data", None, None), P()),
        out_specs=P(), check_vma=False,
    )(q, k_cache, v_cache,
      jnp.broadcast_to(jnp.asarray(cache_len), (B,)))


# ----------------------------------------------------------------------
# MLA (DeepSeek-V2) — latent-space attention with absorbed decode.
# ----------------------------------------------------------------------

def mla_prefill(x, p, cfg, positions):
    """Multi-head latent attention, training/prefill path.

    p: dict with wq_a (D,qr), q_norm (qr,), wq_b (qr,H*(nope+rope)),
       wkv_a (D,kvr+rope), kv_norm (kvr,), wkv_b (kvr,H*(nope+v)),
       wo (H*v, D).
    Returns (out, c_kv, k_rope) so the caches can be kept for decode.
    """
    from repro.models.layers import rms_norm, rope as apply_rope

    a = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    nd, rd, vd = a.nope_head_dim, a.rope_head_dim, a.v_head_dim

    q_lat = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rh->bsh", q_lat, p["wq_b"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(kv_a[..., :a.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv_a[..., None, a.kv_lora_rank:], positions,
                        cfg.rope_theta)  # (B,S,1,rd) shared across heads
    kv = jnp.einsum("bsr,rh->bsh", c_kv, p["wkv_b"]).reshape(B, S, H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))],
                        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(nd + rd)
    o = flash_attention_jnp(q_full, k, v, causal=True, scale=scale)
    out = jnp.einsum("bshv,hvd->bsd", o.reshape(B, S, H, vd),
                     p["wo"].reshape(H, vd, D))
    return out, c_kv, k_rope[..., 0, :]


def mla_decode(x, p, cfg, c_kv_cache, k_rope_cache, cache_len, position):
    """Absorbed MLA decode: attend in the kv_lora latent space.

    c_kv_cache: (B, S, kvr) — already includes the current token's entry.
    """
    from repro.models.layers import rms_norm, rope as apply_rope

    a = cfg.mla
    B, Sq, D = x.shape
    assert Sq == 1
    H = cfg.n_heads
    nd, rd, vd = a.nope_head_dim, a.rope_head_dim, a.v_head_dim
    kvr = a.kv_lora_rank

    q_lat = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rh->bsh", q_lat, p["wq_b"]).reshape(B, 1, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    pos_bs = jnp.broadcast_to(jnp.asarray(position), (B,))[:, None]  # (B,1)
    q_rope = apply_rope(q_rope, pos_bs, cfg.rope_theta)

    wkv_b = p["wkv_b"].reshape(kvr, H, nd + vd)
    w_uk, w_uv = wkv_b[..., :nd], wkv_b[..., nd:]
    # absorb W_uk into q: (B,1,H,kvr)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(nd + rd)
    s = jnp.einsum("bqhr,bsr->bhqs", q_abs,
                   c_kv_cache.astype(jnp.float32)) * scale
    s += jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32) * scale,
                    k_rope_cache.astype(jnp.float32))
    kv_pos = jnp.arange(c_kv_cache.shape[1])
    clen = jnp.broadcast_to(jnp.asarray(cache_len), (B,))[:, None]
    s = jnp.where((kv_pos[None, :] < clen)[:, None, None, :], s, _NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", prob, c_kv_cache.astype(jnp.float32))
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv.astype(jnp.float32))
    out = jnp.einsum("bqhv,hvd->bqd", o.astype(x.dtype),
                     p["wo"].reshape(H, vd, D))
    return out


def mla_new_cache_entries(x, p, cfg, position):
    """Compute the (c_kv, k_rope) entries for one new token.

    ``position``: scalar or (B,) per-sequence positions.
    """
    from repro.models.layers import rms_norm, rope as apply_rope
    a = cfg.mla
    B = x.shape[0]
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(kv_a[..., :a.kv_lora_rank], p["kv_norm"])
    pos_bs = jnp.broadcast_to(jnp.asarray(position), (B,))[:, None]
    k_rope = apply_rope(kv_a[..., None, a.kv_lora_rank:], pos_bs,
                        cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope
