"""World checkpoints: everything a serving process needs to rejoin an
epoch without recomputing it.

``EmbeddingStore.dump``/``load`` cover the store alone; a *restart*
needs more — the mutated CSR, the (possibly grown and resampled) layer
graphs, and the engine counters that drive staleness accounting — or
the rebuilt process would re-derive its world from the config's seeds
and silently lose every mutation folded since build time.  One
``save_world`` artifact (a single ``.npz``) captures:

  * the store's committed front (``EmbeddingStore.state_arrays``),
  * the engine's CURRENT graph (indptr/indices — post edge splices),
  * every layer graph (nbr/mask/fanout — post resamples and tail
    growth),
  * engine/refresh counters (``ops_drained``, refresh/epoch counts,
    onboarded extent) and the delta engine's frozen ``n_main`` (the
    main-partition extent the dist tail-routing check keys on, which a
    naive rebuild would wrongly infer from the GROWN node count),
  * an opaque ``committed_seq`` the cluster tier uses to mark how much
    of a shard's mutation log the checkpoint already contains.

``restore_into_session`` is the surgical inverse: given a freshly built
``Session`` (same ``DealConfig``), it swaps in the checkpointed world
and stands up the serving engine WITHOUT running the full epoch —
``Session.from_checkpoint`` is the user-facing wrapper, and the cluster
``ShardWorker`` uses the same path before replaying its WAL segment.

Bitwise contract: a restored world serves exactly the bytes the dumped
one served — store rows restore verbatim (residency included), layer
graphs restore verbatim (so recompute-on-miss and later delta refreshes
re-derive identical rows), and the engine's counters resume where they
stopped (so refresh scheduling decisions continue unchanged).
"""
from __future__ import annotations

import json
from typing import Dict, Optional

import numpy as np

from repro.core.graph import Graph
from repro.core.sampler import LayerGraph
from repro.gnnserve.store import EmbeddingStore

FORMAT = 1


def save_world(path, engine, *, committed_seq: int = 0) -> Dict:
    """Dump one serving engine's world to ``path`` (.npz).  Returns the
    metadata dict that was embedded."""
    reinfer = engine.reinfer
    meta = {"format": FORMAT,
            "committed_seq": int(committed_seq),
            "n_main": int(reinfer.n_main),
            "n_layer_graphs": len(reinfer.layer_graphs),
            "fanouts": [int(lg.fanout) for lg in reinfer.layer_graphs],
            "ops_drained": int(engine.ops_drained),
            "n_refreshes": int(engine.n_refreshes),
            "n_full_epochs": int(engine.n_full_epochs),
            "n_onboarded": int(engine.n_onboarded),
            "n_refresh_chunks": int(engine.n_refresh_chunks)}
    arrays = {"world_meta": np.frombuffer(
                  json.dumps(meta, sort_keys=True).encode(), np.uint8),
              "g_indptr": engine.graph.indptr,
              "g_indices": engine.graph.indices}
    for l, lg in enumerate(reinfer.layer_graphs):
        arrays[f"lg{l}_nbr"] = lg.nbr
        arrays[f"lg{l}_mask"] = lg.mask
    arrays.update(engine.store.state_arrays(prefix="store_"))
    with open(path, "wb") as f:
        np.savez_compressed(f, **arrays)
    return meta


def peek_meta(path) -> Dict:
    """Read only the metadata blob (``committed_seq`` etc.)."""
    with np.load(path) as z:
        return json.loads(bytes(np.asarray(z["world_meta"],
                                           np.uint8)).decode())


def load_world(path):
    """Load ``(meta, graph, layer_graphs, store)`` from a world
    checkpoint.  The store comes back with no recompute hook bound."""
    with np.load(path) as z:
        meta = json.loads(bytes(np.asarray(z["world_meta"],
                                           np.uint8)).decode())
        assert meta["format"] == FORMAT, \
            f"unknown checkpoint format {meta['format']}"
        graph = Graph(indptr=np.asarray(z["g_indptr"], np.int64).copy(),
                      indices=np.asarray(z["g_indices"], np.int32).copy(),
                      n_nodes=int(z["g_indptr"].shape[0]) - 1)
        lgs = [LayerGraph(nbr=np.asarray(z[f"lg{l}_nbr"], np.int32).copy(),
                          mask=np.asarray(z[f"lg{l}_mask"], bool).copy(),
                          fanout=int(meta["fanouts"][l]))
               for l in range(meta["n_layer_graphs"])]
        store = EmbeddingStore.from_state_arrays(z, prefix="store_")
    return meta, graph, lgs, store


def restore_into_session(session, path) -> Dict:
    """Swap a world checkpoint into a freshly BUILT (not yet serving)
    ``Session``: build the delta engine over the checkpointed layer
    graphs (``n_main`` restored from metadata, NOT inferred from the
    possibly-grown extent), attach the restored store, and stand up the
    serving engine — no full epoch runs.  Returns the checkpoint
    metadata."""
    from repro.gnnserve.delta import DeltaReinference, attach_recompute
    assert session._engine is None, \
        "restore must happen before the session serves"
    meta, graph, lgs, store = load_world(path)
    cfg = session.cfg
    session.graph = graph
    session.reinfer = DeltaReinference(
        lgs, cfg.model.name, session.params,
        sample_seed=cfg.refresh.sample_seed, executor=session.executor,
        local_cutover=cfg.refresh.dist_local_cutover)
    session.reinfer.n_main = int(meta["n_main"])
    if store.budget_rows is not None:
        attach_recompute(store, session.reinfer)
    engine = session._attach_engine(store)
    engine.graph = graph
    engine.ops_drained = int(meta["ops_drained"])
    engine.n_refreshes = int(meta["n_refreshes"])
    engine.n_full_epochs = int(meta["n_full_epochs"])
    engine.n_onboarded = int(meta["n_onboarded"])
    engine.n_refresh_chunks = int(meta["n_refresh_chunks"])
    if engine.qos is not None:
        # per-tenant views restart at the restored epoch: the scheduler
        # state (credits, lagged views) is advisory and rebuilds from
        # traffic; freshness restarts with nothing unobserved
        for name in engine.qos.registry.names:
            st = engine.qos.state(name)
            st.view_version = store.version
            st.ops_at_view = engine.ops_drained
        engine.qos.record_epoch(store.version, engine.ops_drained,
                                store.snapshot())
    return meta


__all__ = ["save_world", "load_world", "peek_meta",
           "restore_into_session", "FORMAT"]
