"""Multi-tenant QoS scheduling for the embedding serve engine.

One embedding store serves many workloads at once — user-facing lookups
next to bulk analytics scans.  Without isolation, one batch job starves
interactive traffic, or the whole store runs at the STRICTEST tenant's
staleness bound and every refresh is charged to everyone.  This module
replaces the engine's single global ``staleness_bound`` + FIFO queue
with three cooperating pieces:

``TenantRegistry``
    Tenants declared with a ``priority`` (weight in the row share), a
    ``slot_quota`` (guaranteed — and reclaimable — batch slots), a
    token-bucket ``rate`` (rows/step; 0 = unlimited) and a per-tenant
    ``staleness_slo`` (max pending mutations their reads may observe).

``QoSScheduler`` — weighted-fair slots and rows
    *Slots*: each tenant is guaranteed ``slot_quota`` of the engine's B
    slots.  Idle quota is lent out work-conserving; when the owner shows
    up, a borrowed slot is PREEMPTED (the in-flight query is paused with
    its cursor and pinned snapshot intact and resumes later — pausing
    never tears a response, because the response's epoch is pinned).
    *Rows*: the per-step ``rows_per_step`` budget is split by
    deficit-weighted round-robin (DRR): tenant t accrues a credit of
    ``budget * priority_t / sum(priorities active)`` per step, spends it
    on its slots' rows, and carries the deficit over.  Token buckets cap
    bursty tenants; unused budget is redistributed work-conserving.
    *Starvation bound*: every admitted query with work left makes
    progress within K steps, where K = 1 for unlimited-rate tenants and
    K = ceil(active_slots_t / rate_t) for rate-limited ones — a minimum
    grant overrides any charge- or deficit-depressed credit.

Deadline-driven refresh planning — per-tenant freshness views
    Instead of refreshing whenever global pending >= bound, the planner
    tracks, per tenant, the epoch its reads observe (``view_version``)
    and how many mutation ops that view pre-dates (``unobserved``).  A
    refresh runs only when the TIGHTEST *active* tenant SLO is due —
    mutation batches coalesce up to that deadline — and only the due
    tenants' views advance: a loose-SLO tenant keeps reading its older
    (pinned, never-torn) epoch while a strict tenant triggers a refresh
    next to it.  Refresh compute cost is charged against the LOWEST
    priority (batch) tenants' DRR credit first.

    Because ``delta.resample_rows`` seeds content-addressed (a row's
    draw depends only on its final CSR neighborhood, not on which
    refresh batch it rode in), folding a mutation stream at one tenant's
    deadlines or another's yields bitwise-identical store contents — so
    each tenant's outputs equal a single-tenant engine run at that
    tenant's SLO, bit for bit.

On a memory-budgeted store an old epoch is not reconstructible
(recompute-on-miss replays the CURRENT graphs): if a lagging view hits
evicted rows (``SnapshotMiss``), the engine restarts that query on the
current epoch — fresher than the SLO requires, never staler, and never
torn (counted in ``n_view_restarts``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

# ----------------------------------------------------------------------
# tenant model
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    name: str
    priority: float = 1.0       # weight in the DRR row share
    slot_quota: int = 1         # guaranteed (reclaimable) batch slots
    rate: float = 0.0           # token-bucket rows/step; <= 0 = unlimited
    staleness_slo: int = 64     # max pending mutations a read may observe

    def __post_init__(self):
        assert self.priority > 0, f"{self.name}: priority must be > 0"
        assert self.slot_quota >= 0, f"{self.name}: slot_quota must be >= 0"
        assert self.staleness_slo >= 1, \
            f"{self.name}: staleness_slo must be >= 1"


class TenantRegistry:
    """Declared tenants, by name.  Quotas are validated against the
    engine's slot count when the scheduler binds."""

    def __init__(self, specs: Sequence[TenantSpec]):
        names = [s.name for s in specs]
        assert len(names) == len(set(names)), f"duplicate tenants: {names}"
        assert names, "at least one tenant required"
        self._specs = {s.name: s for s in specs}

    def __iter__(self):
        return iter(self._specs.values())

    def __getitem__(self, name: str) -> TenantSpec:
        return self._specs[name]

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __len__(self) -> int:
        return len(self._specs)

    @property
    def names(self) -> List[str]:
        return list(self._specs)

    @property
    def total_quota(self) -> int:
        return sum(s.slot_quota for s in self._specs.values())


def parse_tenants(text: str) -> TenantRegistry:
    """Parse ``"name:priority:slot_quota:rate:slo,..."`` — the CLI
    format of ``--tenants`` (rate 0 = unlimited rows/step), e.g.
    ``"ui:4:2:0:8,batch:1:1:256:512"``."""
    specs = []
    for part in text.split(","):
        fields = part.strip().split(":")
        if len(fields) != 5:
            raise ValueError(
                f"tenant spec {part!r} is not name:priority:quota:rate:slo")
        name, prio, quota, rate, slo = fields
        specs.append(TenantSpec(name=name, priority=float(prio),
                                slot_quota=int(quota), rate=float(rate),
                                staleness_slo=int(slo)))
    return TenantRegistry(specs)


# ----------------------------------------------------------------------
# per-tenant runtime state
# ----------------------------------------------------------------------


@dataclasses.dataclass
class _TenantState:
    spec: TenantSpec
    queue: List = dataclasses.field(default_factory=list)
    credit: float = 0.0          # DRR deficit (negative = owes, e.g.
    #                              after absorbing a refresh charge)
    tokens: float = 0.0
    rr: int = 0                  # intra-tenant slot rotation
    view_version: int = 0        # epoch this tenant's reads observe
    ops_at_view: int = 0         # mutation ops folded into that epoch
    # observability
    n_served: int = 0
    rows_served: int = 0
    waits: List[int] = dataclasses.field(default_factory=list)
    stale_obs: List[int] = dataclasses.field(default_factory=list)
    refresh_rows_charged: float = 0.0
    n_refresh_triggers: int = 0
    slot_steps: int = 0
    n_preemptions: int = 0
    n_view_restarts: int = 0
    n_deferred_pins: int = 0     # pin-steps held behind an in-flight
    #                              chunked refresh (waiter / tail / hold)


# ----------------------------------------------------------------------
# the scheduler
# ----------------------------------------------------------------------


class QoSScheduler:
    """Weighted-fair slot/row scheduling plus deadline-driven refresh
    planning (see the module docstring).  The engine owns the mechanics
    (slots, gathers, the mutation log); this object owns the policy and
    the per-tenant bookkeeping."""

    def __init__(self, registry: TenantRegistry, *, batch_slots: int,
                 rows_per_step: int, burst_steps: float = 4.0,
                 credit_cap_steps: float = 4.0, refresh_charge: float = 1.0,
                 min_grant: int = 1):
        assert registry.total_quota <= batch_slots, \
            (f"sum of slot quotas ({registry.total_quota}) exceeds the "
             f"engine's {batch_slots} batch slots")
        self.registry = registry
        self.B = batch_slots
        self.rows_per_step = rows_per_step
        self.burst_steps = burst_steps
        self.credit_cap_steps = credit_cap_steps
        self.refresh_charge = refresh_charge
        self.min_grant = min_grant
        self.step_no = 0
        self.refresh_rows_uncharged = 0.0
        # tenants whose SLO / fresh=True demanded the refresh currently
        # in flight (chunked jobs only): their views advance at commit,
        # and their unpinned queries defer until then
        self.refresh_waiters: set = set()
        self._st: Dict[str, _TenantState] = {
            s.name: _TenantState(spec=s,
                                 tokens=(s.rate * burst_steps
                                         if s.rate > 0 else 0.0))
            for s in registry}
        # epoch version -> (ops folded, StoreSnapshot); pruned to the
        # versions some tenant's view still references
        self.epochs: Dict[int, Tuple[int, object]] = {}

    # -- ingress --------------------------------------------------------
    def route(self, q) -> None:
        if q.tenant not in self._st:
            raise KeyError(f"unknown tenant {q.tenant!r}; registered: "
                           f"{list(self._st)}")
        q.submit_step = self.step_no
        self._st[q.tenant].queue.append(q)

    def queued(self) -> int:
        return sum(len(t.queue) for t in self._st.values())

    def state(self, name: str) -> _TenantState:
        return self._st[name]

    # -- slots: quota + work-conserving lending + preemptive reclaim ----
    def plan_admission(self, slot_q: Sequence) -> Tuple[List[int], List]:
        """Returns ``(preempt, admit)``: slot indexes whose BORROWED
        query must be paused back to its tenant's queue, and
        ``(slot, query)`` admissions.  Guaranteed quotas are filled
        first (highest priority first), reclaiming borrowed slots when
        no free slot remains; leftover slots are lent round-robin."""
        slots = list(slot_q)
        active = {name: 0 for name in self._st}
        for q in slots:
            if q is not None:
                active[q.tenant] += 1
        free = [i for i, q in enumerate(slots) if q is None]
        order = sorted(self._st.values(),
                       key=lambda t: (-t.spec.priority, t.spec.name))
        preempt, admit = [], []

        def _borrowed_victim():
            # a slot of the lowest-priority tenant holding more slots
            # than its quota; highest slot index for determinism
            cands = []
            for i, q in enumerate(slots):
                if q is None:
                    continue
                t = self._st[q.tenant]
                if active[q.tenant] > t.spec.slot_quota:
                    cands.append((t.spec.priority,
                                  -(active[q.tenant] - t.spec.slot_quota),
                                  -i))
            if not cands:
                return None
            _, _, neg_i = min(cands)
            return -neg_i

        for t in order:
            while t.queue and active[t.spec.name] < t.spec.slot_quota:
                if free:
                    i = free.pop(0)
                else:
                    i = _borrowed_victim()
                    if i is None:
                        break
                    victim = slots[i]
                    preempt.append(i)
                    active[victim.tenant] -= 1
                    self._st[victim.tenant].n_preemptions += 1
                q = t.queue.pop(0)
                slots[i] = q
                active[t.spec.name] += 1
                admit.append((i, q))
        # work-conserving: leftover slots to whoever has work, rotating
        names = sorted(self._st)
        start = self.step_no % max(len(names), 1)
        rotation = names[start:] + names[:start]
        progressed = True
        while free and progressed:
            progressed = False
            for name in rotation:
                if not free:
                    break
                t = self._st[name]
                if t.queue:
                    i = free.pop(0)
                    q = t.queue.pop(0)
                    slots[i] = q
                    active[name] += 1
                    admit.append((i, q))
                    progressed = True
        tel = obs.current()
        if tel.enabled and (preempt or admit):
            # zero-duration structured events: the per-query causal
            # timeline (queue wait -> scheduler grant -> pin -> gather)
            # needs the grant/preempt instants, not just counters
            now = tel.now_ns()
            for i in preempt:
                victim = slot_q[i]
                tel.tracer.record("qos.preempt", now, 0, 0,
                                  {"slot": i, "uid": victim.uid,
                                   "tenant": victim.tenant})
            for i, q in admit:
                tel.tracer.record("qos.grant", now, 0, 0,
                                  {"slot": i, "uid": q.uid,
                                   "tenant": q.tenant})
        return preempt, admit

    def requeue_front(self, q) -> None:
        """A preempted query goes back to the FRONT of its tenant's
        queue, cursor and pinned snapshot intact — it resumes, it does
        not restart."""
        self._st[q.tenant].queue.insert(0, q)

    # -- freshness views ------------------------------------------------
    def unobserved_of(self, name: str, pending: int,
                      ops_drained: int) -> int:
        """Mutation ops a read through this tenant's view pre-dates:
        ops drained into epochs past the view, plus the undrained log."""
        t = self._st[name]
        return (ops_drained - t.ops_at_view) + pending

    def due_tenants(self, slot_q: Sequence, pending: int,
                    ops_drained: int) -> List[str]:
        """Tenants (with demand) whose freshness deadline has passed —
        the tightest active SLO decides whether THIS step refreshes."""
        active = {q.tenant for q in slot_q if q is not None}
        fresh = {q.tenant for q in slot_q
                 if q is not None and q.fresh and q.snap is None}
        due = []
        for name, t in self._st.items():
            if name not in active and not t.queue:
                continue
            if name in fresh or (self.unobserved_of(name, pending,
                                                    ops_drained)
                                 >= t.spec.staleness_slo):
                due.append(name)
        return due

    def record_epoch(self, version: int, ops_folded: int,
                     snapshot) -> None:
        self.epochs[version] = (ops_folded, snapshot)
        self._prune_epochs(version)

    def epoch_snapshot(self, version: int):
        return self.epochs[version][1]

    def advance_views(self, names: Sequence[str], version: int,
                      ops_drained: int, *, refreshed: bool = True) -> None:
        """Move the due tenants' views to ``version``.  ``refreshed``
        is False when no refresh actually ran (the log was empty and the
        view just caught up to an epoch someone else paid for) — only a
        real refresh counts as a trigger."""
        for n in names:
            t = self._st[n]
            if version >= t.view_version:
                t.view_version = version
                t.ops_at_view = ops_drained
                if refreshed:
                    t.n_refresh_triggers += 1
        self._prune_epochs(version)

    def _prune_epochs(self, current: int) -> None:
        live = {t.view_version for t in self._st.values()} | {current}
        self.epochs = {v: e for v, e in self.epochs.items() if v in live}

    def charge_refresh(self, rows_gemm: float) -> None:
        """Charge one refresh's compute against tenants' DRR credit,
        LOWEST priority (batch) first — batch analytics pays for the
        freshness it forces onto the shared store before interactive
        tenants do.  Each tenant absorbs down to a floor of
        ``-credit_cap_steps * rows_per_step`` so the starvation bound
        survives (the minimum grant ignores negative credit)."""
        cost = float(rows_gemm) * self.refresh_charge
        floor = -self.credit_cap_steps * self.rows_per_step
        for t in sorted(self._st.values(),
                        key=lambda t: (t.spec.priority, t.spec.name)):
            if cost <= 0:
                break
            room = max(t.credit - floor, 0.0)
            take = min(cost, room)
            t.credit -= take
            t.refresh_rows_charged += take
            cost -= take
        self.refresh_rows_uncharged += max(cost, 0.0)

    # -- rows: DRR + token buckets + work-conserving redistribution -----
    def allocate(self, active: Sequence[Tuple[int, str, int]],
                 budget: int) -> Dict[int, int]:
        """Split ``budget`` gather rows across the active slots.
        ``active`` is ``[(slot, tenant, rows_still_needed)]``.  The
        returned grants satisfy: sum(grants) <= budget, grants[slot] <=
        need, and every needy slot of a token-solvent tenant gets at
        least ``min_grant`` rows (the starvation bound)."""
        for t in self._st.values():            # token refill, idle incl.
            if t.spec.rate > 0:
                t.tokens = min(t.tokens + t.spec.rate,
                               t.spec.rate * self.burst_steps)
        by_t: Dict[str, List[Tuple[int, int]]] = {}
        for slot, name, need in active:
            if need > 0:
                by_t.setdefault(name, []).append((slot, need))
        if not by_t:
            return {}
        states = [self._st[n] for n in sorted(by_t)]
        wsum = sum(t.spec.priority for t in states)
        want = {t.spec.name: sum(nd for _, nd in by_t[t.spec.name])
                for t in states}

        def _avail(t):
            return t.tokens if t.spec.rate > 0 else float("inf")

        grants: Dict[str, int] = {}
        funded: Dict[str, int] = {}   # the credit-funded share, pre-lending
        total = 0
        for t in states:
            quantum = budget * t.spec.priority / wsum
            t.credit = min(t.credit + quantum,
                           self.credit_cap_steps * quantum)
            g = int(min(want[t.spec.name], max(t.credit, 0.0), _avail(t)))
            # starvation bound: progress every step, token-permitting,
            # regardless of refresh charges or carried deficit
            g = max(g, int(min(want[t.spec.name],
                               len(by_t[t.spec.name]) * self.min_grant,
                               _avail(t))))
            grants[t.spec.name] = g
            funded[t.spec.name] = g
            total += g
        leftover = budget - total
        if leftover < 0:
            # over budget (a credit-rich tenant claimed a burst): trim
            # lowest priority first, but never below a tenant's minimum
            # grant — the starvation bound survives bursts
            for t in sorted(states,
                            key=lambda t: (t.spec.priority, t.spec.name)):
                floor_t = int(min(want[t.spec.name],
                                  len(by_t[t.spec.name]) * self.min_grant,
                                  _avail(t)))
                cut = min(grants[t.spec.name] - floor_t, -leftover)
                if cut > 0:
                    grants[t.spec.name] -= cut
                    leftover += cut
                if leftover >= 0:
                    break
            if leftover < 0:          # budget < sum of min grants
                for t in sorted(states,
                                key=lambda t: (t.spec.priority,
                                               t.spec.name)):
                    cut = min(grants[t.spec.name], -leftover)
                    grants[t.spec.name] -= cut
                    leftover += cut
                    if leftover >= 0:
                        break
        guard = 0
        while leftover > 0 and guard < 64:     # work-conserving rounds
            guard += 1
            cands = [t for t in sorted(
                         states,
                         key=lambda t: (-t.spec.priority, t.spec.name))
                     if grants[t.spec.name] < min(want[t.spec.name],
                                                  _avail(t))]
            if not cands:
                break
            for t in cands:
                room = int(min(want[t.spec.name], _avail(t))) \
                    - grants[t.spec.name]
                extra = min(room, max(leftover // len(cands), 1), leftover)
                grants[t.spec.name] += extra
                leftover -= extra
                if leftover <= 0:
                    break
        out: Dict[int, int] = {}
        for t in states:
            g = grants[t.spec.name]
            # deficit carries over — but only the credit-funded share is
            # charged: rows soaked up work-conserving from capacity NO
            # other tenant wanted are free (use-it-or-lose-it), so idle-
            # time borrowing can never pin a tenant below its weighted
            # share once contention returns
            t.credit -= min(g, funded[t.spec.name])
            if t.spec.rate > 0:
                t.tokens = max(t.tokens - g, 0.0)
            slots = sorted(by_t[t.spec.name])
            k = len(slots)
            base, rem = g // k, g % k
            start = t.rr % k
            t.rr += 1
            for j, (slot, nd) in enumerate(slots):
                extra = 1 if ((j - start) % k) < rem else 0
                out[slot] = min(nd, base + extra)
            spare = g - sum(out[slot] for slot, _ in slots)
            for slot, nd in slots:             # intra-tenant leftovers
                if spare <= 0:
                    break
                add = min(nd - out[slot], spare)
                out[slot] += add
                spare -= add
        return out

    # -- observability --------------------------------------------------
    # wait/staleness sample history per tenant: enough for stable
    # p50/p95, bounded so a long-lived engine can't grow O(queries)
    MAX_SAMPLES = 4096

    def _sample(self, lst: List[int], v: int) -> None:
        lst.append(int(v))
        if len(lst) > self.MAX_SAMPLES:
            del lst[:len(lst) - self.MAX_SAMPLES]

    def on_pin(self, q, staleness: int) -> None:
        t = self._st[q.tenant]
        q.observed_staleness = staleness
        q.first_gather_step = self.step_no
        self._sample(t.stale_obs, staleness)
        self._sample(t.waits, self.step_no - q.submit_step)

    def on_rows(self, name: str, rows: int) -> None:
        self._st[name].rows_served += int(rows)

    def on_view_restart(self, name: str) -> None:
        self._st[name].n_view_restarts += 1

    def on_defer(self, name: str) -> None:
        """One pin-step held behind an in-flight chunked refresh."""
        self._st[name].n_deferred_pins += 1
        obs.add("qos.deferred_pins")

    def on_done(self, q) -> None:
        t = self._st[q.tenant]
        t.n_served += 1
        if q.first_gather_step < 0:            # empty query: never pinned
            self._sample(t.waits, self.step_no - q.submit_step)

    def account_slots(self, slot_q: Sequence) -> None:
        for q in slot_q:
            if q is not None:
                self._st[q.tenant].slot_steps += 1

    def stats(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant p50/p95 queue wait (steps from submit to first
        gather), rows served, observed staleness vs SLO, refresh
        charges, and quota utilization."""
        out: Dict[str, Dict[str, float]] = {}
        steps = max(self.step_no, 1)
        for name, t in self._st.items():
            w = np.asarray(t.waits if t.waits else [0], np.float64)
            so = np.asarray(t.stale_obs if t.stale_obs else [0], np.float64)
            out[name] = {
                "n_served": t.n_served,
                "rows_served": t.rows_served,
                "wait_p50_steps": float(np.percentile(w, 50)),
                "wait_p95_steps": float(np.percentile(w, 95)),
                "staleness_p95": float(np.percentile(so, 95)),
                "staleness_max": float(so.max()),
                "staleness_slo": float(t.spec.staleness_slo),
                "slo_violations": int((so > t.spec.staleness_slo).sum()),
                "refresh_rows_charged": float(t.refresh_rows_charged),
                "n_refresh_triggers": t.n_refresh_triggers,
                "quota_util": (t.slot_steps
                               / (max(t.spec.slot_quota, 1) * steps)),
                "n_preemptions": t.n_preemptions,
                "n_view_restarts": t.n_view_restarts,
                "n_deferred_pins": t.n_deferred_pins,
                "view_version": t.view_version,
            }
        return out


__all__ = ["TenantSpec", "TenantRegistry", "parse_tenants", "QoSScheduler"]
