"""Edge/node mutation log and a CSR delta overlay over ``core.graph``.

Online traffic mutates the graph between epochs: edges appear/disappear
and node features change.  Rebuilding the CSR per mutation batch would
cost O(E); the overlay records per-destination adds/removes and splices
ONLY the affected rows at ``materialize`` time, so the cost is
O(sum of affected row lengths) plus two bulk copies — the same
"touch only what changed" principle the delta re-inference applies to
compute.

Node additions are recorded (``add_nodes``, optionally with the new
rows' features).  With ``store.onboarding == "tail"`` the engine
onboards them incrementally: ``grow_graph`` appends empty CSR rows, the
store appends a tail partition, and the new ids ride the next delta
refresh's resampled set — no re-partition until the next full epoch
folds the tail in.  Without tail onboarding the engine still refuses
them (growing N invalidates the static partition bounds).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class MutationBatch:
    """One drained batch of mutations, ready to apply.

    ``edge_ops`` preserves the client's edge-op ORDER (("add"|"del", src,
    dst)); the add_*/del_* arrays are order-free projections of it for
    analytics and requeueing.
    """
    add_src: np.ndarray
    add_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray
    feat_ids: np.ndarray
    feat_rows: np.ndarray          # (len(feat_ids), D)
    edge_ops: List[tuple] = dataclasses.field(default_factory=list)
    n_new_nodes: int = 0
    # (n_new_nodes, D) features for the onboarded nodes, or None (zeros)
    new_node_rows: np.ndarray = None

    @property
    def n_edge_ops(self) -> int:
        return int(self.add_src.size + self.del_src.size)

    @property
    def n_ops(self) -> int:
        """Pending-count contribution of this batch (edge ops + distinct
        feature rows + node adds) — what the engine's staleness/SLO
        accounting folds into ``ops_drained`` on a successful refresh.
        NOTE: repeated feature updates of the SAME id inside one undrained
        window collapse (last-writer-wins), matching ``MutationLog.pending``."""
        return self.n_edge_ops + int(self.feat_ids.size) + self.n_new_nodes

    def affected_dsts(self) -> np.ndarray:
        """Destinations whose CSR row (in-neighborhood) changes."""
        return np.unique(np.concatenate([self.add_dst, self.del_dst]
                                        ).astype(np.int64))


class MutationLog:
    """Append-only log; the engine drains it at each refresh."""

    def __init__(self):
        # one ordered stream: ("add"|"del", src, dst) — intra-batch
        # add-then-remove of the same edge must net out to a no-op
        self._edges: List[tuple] = []
        self._feat: Dict[int, np.ndarray] = {}   # last-writer-wins
        self._new_nodes = 0
        self._node_adds: List[tuple] = []        # (k, rows-or-None)

    def add_edge(self, src: int, dst: int) -> None:
        self._edges.append(("add", int(src), int(dst)))

    def add_edges(self, src: np.ndarray, dst: np.ndarray) -> None:
        self._edges.extend(("add", int(s), int(d)) for s, d in
                           zip(np.asarray(src), np.asarray(dst)))

    def remove_edge(self, src: int, dst: int) -> None:
        self._edges.append(("del", int(src), int(dst)))

    def remove_edges(self, src: np.ndarray, dst: np.ndarray) -> None:
        self._edges.extend(("del", int(s), int(d)) for s, d in
                           zip(np.asarray(src), np.asarray(dst)))

    def update_features(self, ids: np.ndarray, rows: np.ndarray) -> None:
        for i, r in zip(np.asarray(ids).tolist(), np.asarray(rows)):
            self._feat[int(i)] = np.asarray(r, np.float32)

    def add_nodes(self, k: int, rows: np.ndarray = None) -> None:
        """Record ``k`` brand-new nodes, optionally with their (k, D)
        feature rows (zeros otherwise).  Ids are assigned contiguously
        past the current node count at refresh time."""
        k = int(k)
        if rows is not None:
            rows = np.asarray(rows, np.float32)
            assert rows.shape[0] == k, "need one feature row per new node"
        self._node_adds.append((k, rows))
        self._new_nodes += k

    @property
    def pending(self) -> int:
        return len(self._edges) + len(self._feat) + self._new_nodes

    @property
    def pending_node_adds(self) -> int:
        """Node additions not yet folded — the NEXT new node gets id
        ``graph.n_nodes + pending_node_adds`` at refresh time."""
        return self._new_nodes

    @property
    def has_node_adds(self) -> bool:
        return self._new_nodes > 0

    def requeue(self, batch: MutationBatch) -> None:
        """Put a drained batch BACK (a failed refresh must not discard
        the good mutations drained alongside a bad one).  Edge ops replay
        from ``batch.edge_ops`` in their original order — rebuilding from
        the add_*/del_* projections would reorder del-then-add of the
        same edge into add-then-del and flip its net effect."""
        for kind, s, d in batch.edge_ops:
            (self.add_edge if kind == "add" else self.remove_edge)(s, d)
        if batch.feat_ids.size:
            self.update_features(batch.feat_ids, batch.feat_rows)
        if batch.n_new_nodes:
            self.add_nodes(batch.n_new_nodes, batch.new_node_rows)

    def drain(self) -> MutationBatch:
        def _cols(kind):
            pairs = [(s, d) for k, s, d in self._edges if k == kind]
            if not pairs:
                return (np.empty(0, np.int64), np.empty(0, np.int64))
            a = np.asarray(pairs, np.int64)
            return a[:, 0], a[:, 1]

        add_src, add_dst = _cols("add")
        del_src, del_dst = _cols("del")
        ids = np.fromiter(self._feat.keys(), np.int64, len(self._feat))
        rows = (np.stack([self._feat[int(i)] for i in ids])
                if ids.size else np.empty((0, 0), np.float32))
        new_rows = None
        if any(r is not None for _, r in self._node_adds):
            d = next(r.shape[1] for _, r in self._node_adds
                     if r is not None)
            new_rows = np.concatenate(
                [r if r is not None else np.zeros((k, d), np.float32)
                 for k, r in self._node_adds])
        batch = MutationBatch(add_src=add_src, add_dst=add_dst,
                              del_src=del_src, del_dst=del_dst,
                              feat_ids=ids, feat_rows=rows,
                              edge_ops=list(self._edges),
                              n_new_nodes=self._new_nodes,
                              new_node_rows=new_rows)
        self._edges, self._feat = [], {}
        self._new_nodes = 0
        self._node_adds = []
        return batch


def grow_graph(g: Graph, n_new: int) -> Graph:
    """A NEW graph with ``n_new`` appended nodes and empty CSR rows —
    the structural half of incremental node onboarding (edges touching
    the new ids then splice in via ``apply_edge_mutations``)."""
    assert n_new > 0
    indptr = np.concatenate(
        [g.indptr, np.full(n_new, g.indptr[-1], np.int64)])
    # indices are shared, not copied: the grown rows are empty, and
    # apply_edge_mutations never writes into its input's indices
    return Graph(indptr=indptr, indices=g.indices,
                 n_nodes=g.n_nodes + int(n_new))


def apply_edge_mutations(g: Graph, batch: MutationBatch) -> Graph:
    """Splice the batch into a NEW Graph, touching only affected rows.

    Ops replay per destination IN LOG ORDER: adds append to the row,
    removals delete the first matching occurrence (multigraph CSR
    semantics) — so add-then-remove of the same edge inside one batch
    nets out to a no-op.  Removing an absent edge is a no-op.
    """
    affected = batch.affected_dsts()
    if affected.size == 0:
        return Graph(indptr=g.indptr.copy(), indices=g.indices.copy(),
                     n_nodes=g.n_nodes)
    assert affected.min() >= 0 and affected.max() < g.n_nodes, \
        "edge mutation references an unknown node"
    for arr in (batch.add_src, batch.del_src):
        assert arr.size == 0 or (arr.min() >= 0 and arr.max() < g.n_nodes), \
            "edge mutation references an unknown source node"

    ops: Dict[int, List[tuple]] = {}
    for kind, s, d in batch.edge_ops:
        ops.setdefault(int(d), []).append((kind, int(s)))

    new_rows: Dict[int, np.ndarray] = {}
    for v in affected:
        row = g.neighbors(int(v)).tolist()
        for kind, s in ops.get(int(v), ()):
            if kind == "add":
                row.append(s)
            else:
                try:
                    row.remove(s)
                except ValueError:
                    pass                    # removing an absent edge
        new_rows[int(v)] = np.asarray(row, np.int32)

    deg = g.degrees().astype(np.int64)
    for v, row in new_rows.items():
        deg[v] = row.size
    indptr = np.zeros(g.n_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = np.empty(indptr[-1], np.int32)
    # bulk-copy the untouched spans between affected rows, splice the rest
    prev = 0
    for v in affected:
        v = int(v)
        indices[indptr[prev]:indptr[v]] = g.indices[g.indptr[prev]:g.indptr[v]]
        indices[indptr[v]:indptr[v + 1]] = new_rows[v]
        prev = v + 1
    indices[indptr[prev]:] = g.indices[g.indptr[prev]:]
    return Graph(indptr=indptr, indices=indices, n_nodes=g.n_nodes)
