"""Incremental delta re-inference over the layerwise engine's output.

A mutation batch dirties two kinds of state: level-0 rows (feature
updates) and sampled layer-graph rows (edge churn re-samples the
destinations' fixed-fanout rows, deterministically, from the spliced
CSR).  Because DEAL's layer graphs are static between refreshes, the
forward-affected set is computable in closed form BEFORE any compute:

    dirty_0   = feature-updated nodes
    dirty_l+1 = resampled_rows  ∪  dirty_l  ∪  consumers_l(dirty_l)

where ``consumers_l`` is the REVERSE of layer l's fanout matrix (who
sampled me?) — the same frontier machinery as ``core.sharing``'s
backward dependency walk, run forward.  Re-inference then re-runs ONLY
those rows through the pluggable executor layer (``core.ops``): the
layer math comes from the same declarative spec as every other engine,
and the backend is selectable —

  ref / pallas   single-host row-subset mode: neighbor ids remapped onto
                 the gathered universe exactly like the ego-batched
                 baseline;
  dist           ``DistExecutor.run_rows``: the frontier is split per
                 partition and recomputed through the §3.4 shard_map
                 primitives on the mesh (a per-refresh SubsetPlan built
                 over the same 1-D ownership as the full CommPlan).

On every backend a delta-refreshed row is BITWISE equal to a from-scratch
epoch through the SAME executor (same per-row reductions, same order).

Masked fanout slots are remapped to position 0, never out-of-range:
jnp's gather fills OOB with NaN and NaN*0 poisons the aggregation.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro import obs
from repro.core.gnn_models import model_spec
from repro.core.graph import Graph
from repro.core.ops import DenseIO, DistExecutor, get_executor, run_layer
from repro.core.partition import invalidate_subset_plans, pad_bucket
from repro.core.sampler import LayerGraph
from repro.gnnserve.store import EmbeddingStore

import jax.numpy as jnp


# ----------------------------------------------------------------------
# content-addressed row hashing (splitmix64, vectorized)
# ----------------------------------------------------------------------

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX_B = np.uint64(0xBF58476D1CE4E5B9)
_MIX_C = np.uint64(0x94D049BB133111EB)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer over uint64 arrays (wrapping arithmetic) —
    the counter-based generator behind ``resample_rows``'s per-row
    independent streams.  A hash, not a crypto primitive."""
    x = x + _GOLDEN
    x ^= x >> np.uint64(30)
    x *= _MIX_B
    x ^= x >> np.uint64(27)
    x *= _MIX_C
    x ^= x >> np.uint64(31)
    return x


# ----------------------------------------------------------------------
# reverse fanout index: node u -> rows that sample u
# ----------------------------------------------------------------------

@dataclasses.dataclass
class ReverseIndex:
    indptr: np.ndarray     # (N+1,)
    rows: np.ndarray       # (#masked edges,) consumer row ids, grouped by src

    def consumers(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return np.empty(0, np.int64)
        # vectorized multi-span gather (this runs per layer per refresh)
        starts = self.indptr[ids]
        counts = self.indptr[ids + 1] - starts
        total = int(counts.sum())
        if total == 0:
            return np.empty(0, np.int64)
        offsets = np.repeat(starts - np.concatenate(
            [[0], np.cumsum(counts)[:-1]]), counts)
        return np.unique(self.rows[np.arange(total) + offsets])


def build_reverse_index(lg: LayerGraph) -> ReverseIndex:
    dst_rows, _ = np.nonzero(lg.mask)
    src = lg.nbr[lg.mask]
    order = np.argsort(src, kind="stable")
    counts = np.bincount(src, minlength=lg.n_nodes)
    indptr = np.zeros(lg.n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return ReverseIndex(indptr=indptr, rows=dst_rows[order].astype(np.int64))


def splice_reverse_index(rev: ReverseIndex, rows: np.ndarray,
                         old_nbr: np.ndarray, old_mask: np.ndarray,
                         new_nbr: np.ndarray, new_mask: np.ndarray
                         ) -> ReverseIndex:
    """Splice the resampled ``rows``' old/new entries into an existing
    reverse index, EXACTLY equal to ``build_reverse_index`` on the
    mutated layer graph — sorting work is O(changed log changed) plus a
    few flat C array passes for the bulk moves, instead of the rebuild's
    full N*F nonzero + E log E argsort.

    The trick: a resampled row's old entries are precisely every
    occurrence of its id in ``rev.rows`` (one global delete mask), and
    because spans are source-ascending with row-sorted contents, the
    composite key ``src * (N+1) + row`` is GLOBALLY sorted — so the new
    entries' merge positions come from one ``searchsorted`` and one
    ``insert``, value-level merge included.

    ``old_nbr/old_mask`` are the rows' pre-resample fanout slices (the
    same copies ``DeltaReinference.refresh`` snapshots for rollback);
    ``new_nbr/new_mask`` their post-resample state.
    """
    rows = np.asarray(rows, np.int64)
    if rows.size == 0:
        return rev
    n_nodes = rev.indptr.size - 1
    old_src = old_nbr[old_mask].astype(np.int64)
    new_src = new_nbr[new_mask].astype(np.int64)

    # delete: every occurrence of a resampled consumer row
    keep = ~np.isin(rev.rows, rows)
    kept = rev.rows[keep]
    assert int((~keep).sum()) == int(old_mask.sum()), \
        "reverse index inconsistent with the rows' pre-resample state"
    src_kept = np.repeat(np.arange(n_nodes, dtype=np.int64),
                         np.diff(rev.indptr))[keep]

    # insert: new (src, row) pairs, value-level merged via composite key
    new_rows_rep = np.repeat(rows, new_mask.sum(axis=1))
    order = np.lexsort((new_rows_rep, new_src))
    ns, nr = new_src[order], new_rows_rep[order]
    stride = np.int64(n_nodes + 1)
    pos = np.searchsorted(src_kept * stride + kept, ns * stride + nr)
    out = np.insert(kept, pos, nr)

    counts = (np.diff(rev.indptr)
              - np.bincount(old_src, minlength=n_nodes)
              + np.bincount(new_src, minlength=n_nodes))
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    assert out.size == indptr[-1], "reverse-index splice drifted"
    return ReverseIndex(indptr=indptr, rows=out)


def resample_rows(g: Graph, layer_graphs: Sequence[LayerGraph],
                  rows: np.ndarray, seed: int) -> None:
    """Deterministically re-draw the given rows of every layer graph from
    the (mutated) CSR, in place — mirrors ``sampler.sample_layer_graphs``
    restricted to a row subset.

    Seeding is CONTENT-ADDRESSED per row: row r's draw is a pure
    function of (seed, r, layer index, r's CSR neighborhood bytes) — NOT
    of which refresh batch r happened to ride in.  That makes refresh
    *batching-invariant*: folding one mutation stream in one big batch
    or many small ones lands on bitwise-identical layer graphs (and,
    via the per-refresh full-epoch equivalence, identical store bytes)
    whenever the final CSR matches.  The QoS engine's per-tenant
    freshness views rely on this — a loose-SLO tenant coalescing at its
    own deadlines must read the same bits a single-tenant engine at
    that SLO would produce, even while a strict tenant forces extra
    intermediate refreshes on the shared store.
    """
    rows = np.asarray(rows, np.int64)
    if rows.size == 0:
        return
    deg = np.diff(g.indptr)[rows]
    starts = g.indptr[:-1][rows]
    crc = np.fromiter(
        (zlib.crc32(g.indices[g.indptr[r]:g.indptr[r + 1]].tobytes())
         for r in rows.tolist()), np.uint64, rows.size)
    key = _mix64(_mix64(_mix64(np.full(rows.size,
                                       int(seed) & 0xFFFFFFFFFFFFFFFF,
                                       np.uint64))
                        ^ rows.astype(np.uint64)) ^ crc)
    has = deg > 0
    maxdeg = np.maximum(deg, 1).astype(np.uint64)[:, None]
    for l, lg in enumerate(layer_graphs):
        F = lg.fanout
        lane = _mix64(_mix64(np.full(F, l + 1, np.uint64) * _GOLDEN)
                      + np.arange(F, dtype=np.uint64))
        # counter-based uniform draw: the vectorized stand-in for
        # draw_fixed_fanout's rng.integers (same take-all / mask
        # semantics below; modulo bias is ~deg/2^64, nil)
        draw = (_mix64(key[:, None] ^ lane[None, :])
                % maxdeg).astype(np.int64)
        take_all = deg[:, None] <= F        # small rows: each nbr once
        seqidx = np.arange(F)[None, :]
        draw = np.where(take_all,
                        np.minimum(seqidx, np.maximum(deg - 1, 0)[:, None]),
                        draw)
        idx = starts[:, None] + draw
        lg.nbr[rows] = g.indices[np.minimum(idx, max(g.n_edges - 1, 0))] \
            .astype(np.int32)
        lg.mask[rows] = has[:, None] & ((seqidx < deg[:, None])
                                        | (deg[:, None] > F))
        invalidate_subset_plans(lg)     # cached frontier plans are stale


def forward_frontier(rev: Sequence[ReverseIndex], feat_dirty: np.ndarray,
                     resampled: np.ndarray, n_layers: int
                     ) -> List[np.ndarray]:
    """frontier[l] = rows whose level-(l+1) value must be recomputed."""
    feat_dirty = np.asarray(feat_dirty, np.int64)
    resampled = np.asarray(resampled, np.int64)
    out, dirty = [], feat_dirty
    for l in range(n_layers):
        dirty = np.unique(np.concatenate(
            [resampled, dirty, rev[l].consumers(dirty)]))
        out.append(dirty)
    return out


# ----------------------------------------------------------------------
# delta re-inference
# ----------------------------------------------------------------------

def _pow2(n: int, floor: int = 256) -> int:
    """Pad bucket (``partition.pad_bucket``): floored high so tiny
    frontiers share one compiled shape instead of minting many."""
    return pad_bucket(n, floor)


def _remap(nbr_rows: np.ndarray, mask_rows: np.ndarray, universe: np.ndarray):
    """Map global neighbor ids onto positions in `universe`; masked slots
    pin to position 0 (see module docstring)."""
    pos = np.searchsorted(universe, nbr_rows)
    pos = np.where(mask_rows, pos, 0)
    return np.clip(pos, 0, max(universe.size - 1, 0)).astype(np.int32)


class DeltaReinference:
    """Row-subset re-inference bound to one model + its layer graphs.

    ``layer_graphs`` are held by reference and mutated in place by
    ``resample_rows``; reverse indexes for mutated layers are rebuilt
    lazily at the next refresh.  ``executor`` selects the backend
    ("ref" | "pallas" | a ``DistExecutor`` instance for mesh refresh).
    """

    def __init__(self, layer_graphs: Sequence[LayerGraph], model: str,
                 params, *, sample_seed: int = 0, executor="ref",
                 local_cutover: int = 0):
        # model resolves through the registry below (model_spec raises
        # with every registered name on a typo)
        self.layer_graphs = list(layer_graphs)
        self.model = model
        self.params = params
        self.spec = model_spec(model, params)
        self.executor = get_executor(executor)
        self.sample_seed = sample_seed
        self.rows_gemm = 0
        self.rev_rebuilds = 0
        self.rev_splices = 0
        # frontier-size cutover (dist executor only): a layer whose
        # universe (rows_gemm unit) is below the threshold routes to a
        # lazily-built LOCAL executor instead of the mesh — collective
        # setup + cold subset plans dominate tiny frontiers.  0 = off
        # (the default: routing changes which reduction produced the
        # bits, so dist-vs-dist bitwise equivalence only holds with the
        # cutover disabled or thresholds equal).
        self.local_cutover = int(local_cutover)
        self.n_local_cutovers = 0
        self.n_dist_layers = 0
        # main-partition extent for the dist executor: tail-onboarded
        # rows (ids >= n_main) never fit the `n % P == 0` subset-plan
        # geometry, so any row that IS or READS a tail node routes
        # through the local executor instead (see _layer_rows_dist).
        # Frozen for the lifetime of this instance — re-partitioning the
        # grown graph would change per-row reduction orders and break
        # bitwise equality with the epochs already served; folding the
        # tail back into the mesh is a rebind (new session), not a flag.
        self.n_main = (int(self.layer_graphs[0].n_nodes)
                       if self.layer_graphs else 0)
        self.n_tail_routed = 0
        self._local_ex = None
        self._table_pool: List[np.ndarray] = []
        self._rev: List[Optional[ReverseIndex]] = \
            [None] * len(self.layer_graphs)

    @property
    def n_layers(self) -> int:
        return len(self.spec.layers)

    def _reverse(self, l: int) -> ReverseIndex:
        if self._rev[l] is None:
            self._rev[l] = build_reverse_index(self.layer_graphs[l])
            self.rev_rebuilds += 1
        return self._rev[l]

    def _local_executor(self):
        """The single-host executor tiny dist frontiers cut over to."""
        if self._local_ex is None:
            self._local_ex = get_executor("ref")
        return self._local_ex

    def _scratch_table(self, n: int) -> np.ndarray:
        """Node-count-sized int32 scratch for the fused id translation,
        drawn from a pool (``_layer_rows`` returns it after resetting
        its touched entries to 0, so stale ids always map to a valid
        position).  A pool rather than one persistent buffer because
        recompute-on-miss re-enters ``_layer_rows`` mid-layer on a
        budgeted store — the outer layer's table must survive the inner
        call."""
        while self._table_pool:
            t = self._table_pool.pop()
            if t.size >= n:
                return t
        return np.zeros(max(n, 1), np.int32)

    # -- incremental node onboarding ------------------------------------
    def extend_nodes(self, n_new: int) -> None:
        """Grow every layer graph (and any cached reverse index) by
        ``n_new`` brand-new rows with empty neighborhoods.  The new rows
        MUST ride the next refresh's ``resampled`` set — that refresh
        draws their fanout from the grown CSR and writes their levels
        through the staging overlay before anything reads them."""
        for l, lg in enumerate(self.layer_graphs):
            lg.nbr = np.concatenate(
                [lg.nbr, np.zeros((n_new, lg.fanout), np.int32)])
            lg.mask = np.concatenate(
                [lg.mask, np.zeros((n_new, lg.fanout), bool)])
            invalidate_subset_plans(lg)
            rev = self._rev[l]
            if rev is not None:
                # fresh rows have no consumers yet; extending indptr in
                # place keeps the splice path O(changed) at the refresh
                rev.indptr = np.concatenate(
                    [rev.indptr,
                     np.full(n_new, rev.indptr[-1], np.int64)])

    def shrink_nodes(self, n_new: int) -> None:
        """Inverse of ``extend_nodes`` — the engine's rollback when an
        onboarding refresh fails before commit."""
        for lg in self.layer_graphs:
            lg.nbr = lg.nbr[:-n_new]
            lg.mask = lg.mask[:-n_new]
            invalidate_subset_plans(lg)
        # a failed refresh already dropped the cached reverse indexes;
        # dropping again is cheap insurance against size drift
        self._rev = [None] * len(self.layer_graphs)

    # -- full epoch -----------------------------------------------------
    def full_levels(self, X: np.ndarray) -> List[np.ndarray]:
        """Run a full epoch, returning every level as the store keeps it:
        [X, input-of-layer-2, ..., final embedding]."""
        L = self.n_layers
        levels = [np.asarray(X, np.float32)]
        ids = np.arange(levels[0].shape[0], dtype=np.int64)
        for l in range(L):
            with obs.span("epoch.layer") as sp:
                H = self._layer_rows(l, ids,
                                     lambda lvl, want: levels[lvl][want])
                if sp:
                    sp.set(layer=l, rows=int(ids.size))
            levels.append(H)
        return levels

    # -- one layer over a row subset ------------------------------------
    def _layer_rows(self, l: int, rows: np.ndarray, read_level) -> np.ndarray:
        """Recompute layer l's output for `rows` through the bound
        executor; `read_level(level, ids)` supplies input rows (the
        store's staged view during a refresh)."""
        ex = self.executor
        if isinstance(ex, DistExecutor):
            return self._layer_rows_dist(l, rows, read_level, ex)
        return self._layer_rows_single(l, rows, read_level, ex)

    def _layer_rows_dist(self, l: int, rows: np.ndarray, read_level,
                         ex) -> np.ndarray:
        """Dist dispatch with tail-partition routing: rows that are, or
        sample, a tail-onboarded node (id >= n_main) cannot enter the
        ``n % P == 0`` subset-plan geometry without re-partitioning (and
        re-partitioning would change reduction orders, i.e. bits), so
        they route through the PR 7 local path; the remaining rows keep
        the frozen main geometry.  Outputs merge order-preserving."""
        lg = self.layer_graphs[l]
        n_main = self.n_main
        if lg.n_nodes > n_main:
            touches = rows >= n_main
            if rows.size:
                touches = touches | (
                    (lg.nbr[rows] >= n_main) & lg.mask[rows]).any(axis=1)
            if touches.any():
                tail_rows = rows[touches]
                main_rows = rows[~touches]
                self.n_tail_routed += int(tail_rows.size)
                with obs.span("refresh.route") as sp:
                    if sp:
                        sp.set(route="tail-local", layer=l,
                               rows=int(tail_rows.size), n_main=n_main)
                h_tail = self._layer_rows_single(
                    l, tail_rows, read_level, self._local_executor())
                if main_rows.size == 0:
                    return h_tail
                h_main = self._layer_rows_dist_main(
                    l, main_rows, read_level, ex)
                out = np.empty((rows.size, h_tail.shape[1]), h_tail.dtype)
                out[touches] = h_tail
                out[~touches] = h_main
                return out
        return self._layer_rows_dist_main(l, rows, read_level, ex)

    def _layer_rows_dist_main(self, l: int, rows: np.ndarray, read_level,
                              ex) -> np.ndarray:
        lg = self.layer_graphs[l]
        spec = self.spec
        layer = spec.layers[l]
        nbrs = lg.nbr[rows][lg.mask[rows]]
        U = np.unique(np.concatenate([rows, nbrs.astype(np.int64)]))
        if self.local_cutover and U.size < self.local_cutover:
            # tiny frontier: the mesh's collective setup + cold
            # subset plan costs more than just computing locally
            self.n_local_cutovers += 1
            with obs.span("refresh.route") as sp:
                if sp:
                    sp.set(route="local", layer=l,
                           rows=int(rows.size), universe=int(U.size),
                           threshold=self.local_cutover)
            return self._layer_rows_single(l, rows, read_level,
                                           self._local_executor())
        self.n_dist_layers += 1
        if self.local_cutover:
            with obs.span("refresh.route") as sp:
                if sp:
                    sp.set(route="dist", layer=l,
                           rows=int(rows.size),
                           universe=int(U.size),
                           threshold=self.local_cutover)
        h, take, n_src = ex.run_rows(
            layer, lg, rows, read_level, l, spec.heads,
            n_nodes=self.n_main if lg.n_nodes > self.n_main else None)
        self.rows_gemm += n_src
        if l < self.n_layers - 1:
            h = spec.activation(h)
        return np.asarray(jax.block_until_ready(h))[take]

    def _layer_rows_single(self, l: int, rows: np.ndarray, read_level,
                           ex) -> np.ndarray:
        """Single-host layer body.  Row/universe counts are padded to
        power-of-two buckets so the op-by-op compile cache hits across
        refreshes (frontier sizes vary per mutation batch; unpadded
        shapes would recompile every time).  Padding rows duplicate row 0
        with an all-False mask, so real rows stay bitwise-identical and
        the pad is sliced off on return.  The dist backend buckets inside
        its per-partition SubsetPlan instead.
        """
        lg = self.layer_graphs[l]
        L = self.n_layers
        spec = self.spec
        layer = spec.layers[l]

        F = lg.fanout
        nbrs = lg.nbr[rows][lg.mask[rows]]
        U = np.unique(np.concatenate([rows, nbrs.astype(np.int64)]))

        R, Rp = rows.size, _pow2(rows.size)
        Up = _pow2(U.size)
        # FUSED id translation: instead of densely remapping every
        # neighbor slot onto universe positions (an O(R*F log U)
        # searchsorted), hand the executor the GLOBAL neighbor ids plus
        # a scratch table with table[U] = universe positions — the
        # translation rides layer-1's gather (gather_spmm kernel on the
        # pallas path, a lazy take on ref).  Ids outside U (stale masked
        # slots, pad rows) read the scratch's resting 0, exactly the
        # position-0 pin `_remap` applied, so the bits cannot change.
        table = self._scratch_table(lg.nbr.shape[0])
        table[U] = np.arange(U.size, dtype=np.int32)
        nbr_np = np.zeros((Rp, F), np.int32)
        nbr_np[:R] = lg.nbr[rows]
        mask_np = np.zeros((Rp, F), bool)
        mask_np[:R] = lg.mask[rows]
        # pad with rows already being read (NOT row 0): on a budgeted
        # store a pad id pointing at an evicted row would trigger a
        # spurious recompute; pad values never reach real outputs
        rows_p = np.concatenate([rows, np.full(Rp - R, rows[0], np.int64)])
        U_p = np.concatenate([U, np.full(Up - U.size, U[0], np.int64)])
        self.rows_gemm += int(U.size)

        io = DenseIO(nbr_np, mask_np, table=table)
        h_src = jnp.asarray(read_level(l, U_p))
        h_tgt = lambda: jnp.asarray(read_level(l, rows_p))  # noqa: E731
        try:
            h = run_layer(ex, layer, io, h_tgt, h_src, spec.heads)
            if l < L - 1:
                h = spec.activation(h)
            out = np.asarray(jax.block_until_ready(h))[:R]
        finally:
            # reset AFTER the compute is done: jnp.asarray may alias the
            # scratch buffer zero-copy on CPU, so an early reset would
            # corrupt the very table the ops are reading
            table[U] = 0
            self._table_pool.append(table)
        return out

    # -- row-level recompute (decoupled from mutation batches) ----------
    def recompute_rows(self, store: EmbeddingStore, level: int,
                       ids: np.ndarray, *, staged: bool = False
                       ) -> np.ndarray:
        """Rebuild store level ``level`` (1..L) for ``ids`` from the
        lowest resident levels: one ``_layer_rows`` pass whose inputs
        read through the store — a non-resident input row recurses into
        the store's own recompute-on-miss path, terminating at level 0
        (the pinned features).  Bitwise-equal to the rows a never-evicted
        store would hold, because it is the SAME executor, reduction
        order, and activation as the epoch that produced them.

        ``staged=True`` reads through the open overlay (a mid-refresh
        miss); with ``staged=False`` between ``resample_rows`` and
        ``commit`` the result is undefined for frontier rows — the
        single-threaded engine never does that.
        """
        assert 1 <= level <= self.n_layers, level
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return np.empty((0, store.level_dim(level)), np.float32)
        assert ids.size == 1 or (np.diff(ids) > 0).all(), \
            "ids must be sorted unique (the frontier-split plans need it)"
        read = (store.lookup_staged if staged else
                lambda want, lvl: store._gather(
                    np.asarray(want, np.int64), lvl, staged=False))
        return self._layer_rows(level - 1, ids,
                                lambda lvl, want: read(want, lvl))

    # -- the refresh ----------------------------------------------------
    def begin_refresh(self, store: EmbeddingStore, g_new: Graph,
                      feat_ids: np.ndarray, feat_rows: np.ndarray,
                      resampled: np.ndarray, *, chunk_rows: int = 0
                      ) -> "RefreshJob":
        """Open an incremental refresh: run the cheap prologue eagerly
        (resample dirty rows, splice reverse indexes, walk the forward
        frontier, open the staging overlay, write feature rows) and
        return a :class:`RefreshJob` whose ``step()`` calls run the
        frontier compute one row chunk at a time.  Nothing is visible to
        readers until ``finish()`` commits.

        Chunking is bitwise-invariant: a row's output depends only on
        its own (already fully written) lower level, never on which rows
        share the batch, and the content-addressed resample seeds carry
        no chunk/batch term — so any ``chunk_rows`` produces the exact
        bits of the one-shot :meth:`refresh`.
        """
        resampled = np.asarray(resampled, np.int64)
        feat_ids = np.asarray(feat_ids, np.int64)
        self.rows_gemm = 0

        # snapshot the rows about to be resampled so a failed refresh can
        # roll the layer graphs back in lockstep with the store abort —
        # otherwise graphs and store drift apart and the skipped rows
        # never re-enter a frontier
        old_rows = ([(lg.nbr[resampled].copy(), lg.mask[resampled].copy())
                     for lg in self.layer_graphs]
                    if resampled.size else None)
        try:
            # content-addressed seeding (no version term): the draw for a
            # row depends only on its final CSR state, so refresh
            # batching never changes the bits (see resample_rows)
            with obs.span("refresh.resample") as sp:
                resample_rows(g_new, self.layer_graphs, resampled,
                              seed=self.sample_seed)
                if sp:
                    sp.set(rows=int(resampled.size))
            if resampled.size:
                # incremental maintenance: splice only the resampled
                # rows' old/new entries into each cached reverse index —
                # O(changed spans), not the O(N*F) rebuild
                for l, lg in enumerate(self.layer_graphs):
                    if self._rev[l] is not None:
                        old_nbr_l, old_mask_l = old_rows[l]
                        self._rev[l] = splice_reverse_index(
                            self._rev[l], resampled, old_nbr_l, old_mask_l,
                            lg.nbr[resampled], lg.mask[resampled])
                        self.rev_splices += 1
            with obs.span("refresh.frontier") as sp:
                frontier = forward_frontier(
                    [self._reverse(l) for l in range(self.n_layers)],
                    feat_ids, resampled, self.n_layers)
                if sp:
                    sp.set(rows=int(sum(f.size for f in frontier)))

            store.begin_update()
            if feat_ids.size:
                store.write_rows(0, feat_ids,
                                 np.asarray(feat_rows, np.float32))
            for l in range(self.n_layers):
                obs.add("delta.frontier_rows", frontier[l].size)
        except Exception:
            store.abort()       # readers stay on the last committed epoch
            if old_rows is not None:
                for lg, (nbr, mask) in zip(self.layer_graphs, old_rows):
                    lg.nbr[resampled] = nbr
                    lg.mask[resampled] = mask
                    # the failed refresh may have cached frontier plans
                    # over the now-rolled-back samples
                    invalidate_subset_plans(lg)
                self._rev = [None] * len(self.layer_graphs)
            raise
        return RefreshJob(self, store, frontier, chunk_rows,
                          resampled=resampled, feat_ids=feat_ids,
                          old_rows=old_rows)

    def refresh(self, store: EmbeddingStore, g_new: Graph,
                feat_ids: np.ndarray, feat_rows: np.ndarray,
                resampled: np.ndarray) -> Dict[str, float]:
        """Apply one mutation batch's compute in one shot: resample dirty
        rows of the layer graphs from `g_new`, walk the forward frontier,
        and rewrite only those store rows.  Commits a new store version.
        Equivalent to draining a :meth:`begin_refresh` job inline."""
        job = self.begin_refresh(store, g_new, feat_ids, feat_rows,
                                 resampled)
        while not job.done:
            job.step()
        return job.finish()


class RefreshJob:
    """One in-flight incremental refresh, split into schedulable chunks.

    The worklist is ordered: layer l+1's frontier reads layer l's staged
    rows through the overlay, so layers cannot interleave — but WITHIN a
    layer each output row depends only on its own inputs, never on its
    chunk-mates, so a layer's frontier splits freely into row chunks.
    Equal-size chunks reuse the pow2 pad buckets, so the executor's
    compile cache keeps hitting across chunk boundaries.

    Lifecycle: ``step()`` until ``done``, then ``finish()`` to commit;
    ``abort()`` (called automatically if a step raises) rolls the store
    AND the layer-graph resamples back so readers stay on the last
    committed epoch.  ``hold_rows`` is the top-level frontier — the
    monotone superset of every dirty row — which the engine uses to
    fence recompute-on-miss gathers off rows whose graph state is
    mid-flight (recompute through a resampled row before commit would
    replay the wrong neighborhood).
    """

    def __init__(self, reinfer: DeltaReinference, store: EmbeddingStore,
                 frontier: List[np.ndarray], chunk_rows: int, *,
                 resampled: np.ndarray, feat_ids: np.ndarray, old_rows):
        self.reinfer = reinfer
        self.store = store
        self.frontier = frontier
        self._resampled = resampled
        self._feat_ids = feat_ids
        self._old_rows = old_rows
        self.chunk_rows = int(chunk_rows)
        self._work: List[tuple] = []
        for l, rows in enumerate(frontier):
            if rows.size == 0:
                continue
            step = self.chunk_rows if self.chunk_rows > 0 else int(rows.size)
            for lo in range(0, int(rows.size), step):
                self._work.append((l, lo, min(lo + step, int(rows.size))))
        self._idx = 0
        self.n_chunks = len(self._work)
        self.rows_gemm = 0
        self.hold_rows = (frontier[-1] if frontier
                          else np.empty(0, np.int64))
        self._dead = False

    @property
    def done(self) -> bool:
        return self._idx >= self.n_chunks

    def step(self) -> Dict[str, int]:
        """Run one chunk against the staging overlay.  On any failure the
        whole job aborts (store + layer graphs roll back) and re-raises."""
        assert not self._dead, "job already finished/aborted"
        assert not self.done, "no chunks left; call finish()"
        l, lo, hi = self._work[self._idx]
        rows = self.frontier[l][lo:hi]
        ri = self.reinfer
        before = ri.rows_gemm
        try:
            with obs.span("refresh.layer") as sp:
                with obs.span("refresh.chunk") as csp:
                    h = ri._layer_rows(
                        l, rows,
                        lambda lvl, want: self.store.lookup_staged(
                            want, lvl))
                    self.store.write_rows(l + 1, rows, h)
                    if csp:
                        csp.set(layer=l, rows=int(rows.size),
                                chunk=self._idx, n_chunks=self.n_chunks)
                if sp:
                    sp.set(layer=l, rows=int(rows.size))
        except Exception:
            self.abort()
            raise
        self._idx += 1
        # per-chunk work delta off the instance counter, so concurrent
        # recompute-on-miss traffic between chunks doesn't pollute the
        # job's own accounting
        done_gemm = ri.rows_gemm - before
        self.rows_gemm += done_gemm
        return {"layer": l, "rows": int(rows.size),
                "rows_gemm": int(done_gemm),
                "chunk": self._idx, "n_chunks": self.n_chunks}

    def finish(self) -> Dict[str, float]:
        assert not self._dead, "job already finished/aborted"
        assert self.done, "chunks remain; step() until done"
        self._dead = True
        version = self.store.commit()
        ri = self.reinfer
        return {"version": version, "rows_gemm": self.rows_gemm,
                "frontier_sizes": [int(f.size) for f in self.frontier],
                "n_resampled": int(self._resampled.size),
                "n_feat_updates": int(self._feat_ids.size),
                "n_chunks": self.n_chunks,
                "rev_splices": ri.rev_splices,
                "rev_rebuilds": ri.rev_rebuilds,
                "local_cutover": ri.local_cutover,
                "n_local_cutovers": ri.n_local_cutovers,
                "n_dist_layers": ri.n_dist_layers,
                "n_tail_routed": ri.n_tail_routed}

    def abort(self) -> None:
        """Roll back the staged update and the layer-graph resamples."""
        if self._dead:
            return
        self._dead = True
        self.store.abort()      # readers stay on the last committed epoch
        ri = self.reinfer
        if self._old_rows is not None:
            for lg, (nbr, mask) in zip(ri.layer_graphs, self._old_rows):
                lg.nbr[self._resampled] = nbr
                lg.mask[self._resampled] = mask
                # the failed refresh may have cached frontier plans
                # over the now-rolled-back samples
                invalidate_subset_plans(lg)
            ri._rev = [None] * len(ri.layer_graphs)


# ----------------------------------------------------------------------
# recompute-on-miss: the store's eviction escape hatch
# ----------------------------------------------------------------------

class RecomputeOnMiss:
    """Binds a ``DeltaReinference`` to a memory-budgeted store as its
    recompute hook: a ``lookup`` (or mid-refresh ``lookup_staged``) that
    touches evicted rows rebuilds exactly those rows through the bound
    executor and re-admits them.

        store = store_from_inference(X, levels[1:], budget_rows=cap)
        store.recompute = RecomputeOnMiss(ri, store)

    The reinference instance must be the one whose layer graphs track the
    store's epochs (the engine's ``reinfer``) — recompute replays the
    CURRENT layer graphs, which is only bitwise-faithful for rows whose
    graph rows match the committed epoch (always true outside a refresh,
    and true for every non-frontier row inside one).
    """

    def __init__(self, reinfer: DeltaReinference, store: EmbeddingStore):
        self.reinfer = reinfer
        self.store = store

    def __call__(self, level: int, ids: np.ndarray,
                 staged: bool) -> np.ndarray:
        return self.reinfer.recompute_rows(self.store, level, ids,
                                           staged=staged)


def attach_recompute(store: EmbeddingStore,
                     reinfer: DeltaReinference) -> EmbeddingStore:
    """Convenience wiring used by the launchers and benches."""
    store.recompute = RecomputeOnMiss(reinfer, store)
    return store
