"""Incremental delta re-inference over the layerwise engine's output.

A mutation batch dirties two kinds of state: level-0 rows (feature
updates) and sampled layer-graph rows (edge churn re-samples the
destinations' fixed-fanout rows, deterministically, from the spliced
CSR).  Because DEAL's layer graphs are static between refreshes, the
forward-affected set is computable in closed form BEFORE any compute:

    dirty_0   = feature-updated nodes
    dirty_l+1 = resampled_rows  ∪  dirty_l  ∪  consumers_l(dirty_l)

where ``consumers_l`` is the REVERSE of layer l's fanout matrix (who
sampled me?) — the same frontier machinery as ``core.sharing``'s
backward dependency walk, run forward.  Re-inference then re-runs ONLY
those rows through the existing reference primitives, remapping each
layer's neighbor ids onto the gathered row set exactly like the
ego-batched baseline does — so a delta-refreshed row is BITWISE equal to
a from-scratch epoch (same per-row reductions, same order).

Masked fanout slots are remapped to position 0, never out-of-range:
jnp's gather fills OOB with NaN and NaN*0 poisons the aggregation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import primitives as prim
from repro.core.gnn_models import masked_softmax, mean_weights
from repro.core.graph import Graph
from repro.core.sampler import LayerGraph, draw_fixed_fanout
from repro.gnnserve.store import EmbeddingStore

import jax.numpy as jnp


# ----------------------------------------------------------------------
# reverse fanout index: node u -> rows that sample u
# ----------------------------------------------------------------------

@dataclasses.dataclass
class ReverseIndex:
    indptr: np.ndarray     # (N+1,)
    rows: np.ndarray       # (#masked edges,) consumer row ids, grouped by src

    def consumers(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return np.empty(0, np.int64)
        # vectorized multi-span gather (this runs per layer per refresh)
        starts = self.indptr[ids]
        counts = self.indptr[ids + 1] - starts
        total = int(counts.sum())
        if total == 0:
            return np.empty(0, np.int64)
        offsets = np.repeat(starts - np.concatenate(
            [[0], np.cumsum(counts)[:-1]]), counts)
        return np.unique(self.rows[np.arange(total) + offsets])


def build_reverse_index(lg: LayerGraph) -> ReverseIndex:
    dst_rows, _ = np.nonzero(lg.mask)
    src = lg.nbr[lg.mask]
    order = np.argsort(src, kind="stable")
    counts = np.bincount(src, minlength=lg.n_nodes)
    indptr = np.zeros(lg.n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return ReverseIndex(indptr=indptr, rows=dst_rows[order].astype(np.int64))


def resample_rows(g: Graph, layer_graphs: Sequence[LayerGraph],
                  rows: np.ndarray, seed: int) -> None:
    """Deterministically re-draw the given rows of every layer graph from
    the (mutated) CSR, in place — mirrors ``sampler.sample_layer_graphs``
    restricted to a row subset."""
    rows = np.asarray(rows, np.int64)
    if rows.size == 0:
        return
    rng = np.random.default_rng(seed)
    deg = np.diff(g.indptr)[rows]
    starts = g.indptr[:-1][rows]
    for lg in layer_graphs:
        nbr, mask = draw_fixed_fanout(deg, starts, g.indices, g.n_edges,
                                      lg.fanout, rng)
        lg.nbr[rows] = nbr
        lg.mask[rows] = mask


def forward_frontier(rev: Sequence[ReverseIndex], feat_dirty: np.ndarray,
                     resampled: np.ndarray, n_layers: int
                     ) -> List[np.ndarray]:
    """frontier[l] = rows whose level-(l+1) value must be recomputed."""
    feat_dirty = np.asarray(feat_dirty, np.int64)
    resampled = np.asarray(resampled, np.int64)
    out, dirty = [], feat_dirty
    for l in range(n_layers):
        dirty = np.unique(np.concatenate(
            [resampled, dirty, rev[l].consumers(dirty)]))
        out.append(dirty)
    return out


# ----------------------------------------------------------------------
# delta re-inference
# ----------------------------------------------------------------------

def _pow2(n: int, floor: int = 256) -> int:
    """Pad bucket: next power of two, floored so tiny frontiers share one
    compiled shape instead of minting many."""
    return max(floor, 1 << max(0, int(n - 1).bit_length()))


def _remap(nbr_rows: np.ndarray, mask_rows: np.ndarray, universe: np.ndarray):
    """Map global neighbor ids onto positions in `universe`; masked slots
    pin to position 0 (see module docstring)."""
    pos = np.searchsorted(universe, nbr_rows)
    pos = np.where(mask_rows, pos, 0)
    return np.clip(pos, 0, max(universe.size - 1, 0)).astype(np.int32)


class DeltaReinference:
    """Row-subset re-inference bound to one model + its layer graphs.

    ``layer_graphs`` are held by reference and mutated in place by
    ``resample_rows``; reverse indexes for mutated layers are rebuilt
    lazily at the next refresh.
    """

    def __init__(self, layer_graphs: Sequence[LayerGraph], model: str,
                 params, *, sample_seed: int = 0):
        assert model in ("gcn", "gat", "sage"), model
        self.layer_graphs = list(layer_graphs)
        self.model = model
        self.params = params
        self.sample_seed = sample_seed
        self.rows_gemm = 0
        self._rev: List[Optional[ReverseIndex]] = \
            [None] * len(self.layer_graphs)

    @property
    def n_layers(self) -> int:
        if self.model == "gcn":
            return len(self.params["w"])
        return len(self.params["layers"])

    def _reverse(self, l: int) -> ReverseIndex:
        if self._rev[l] is None:
            self._rev[l] = build_reverse_index(self.layer_graphs[l])
        return self._rev[l]

    # -- full epoch -----------------------------------------------------
    def full_levels(self, X: np.ndarray) -> List[np.ndarray]:
        """Run a full epoch, returning every level as the store keeps it:
        [X, input-of-layer-2, ..., final embedding]."""
        L = self.n_layers
        levels = [np.asarray(X, np.float32)]
        ids = np.arange(levels[0].shape[0], dtype=np.int64)
        for l in range(L):
            H = self._layer_rows(l, ids,
                                 lambda lvl, want: levels[lvl][want])
            levels.append(H)
        return levels

    # -- one layer over a row subset ------------------------------------
    def _layer_rows(self, l: int, rows: np.ndarray, read_level) -> np.ndarray:
        """Recompute layer l's output for `rows`; `read_level(level, ids)`
        supplies input rows (the store's staged view during a refresh).

        Row/universe counts are padded to power-of-two buckets so the
        op-by-op compile cache hits across refreshes (frontier sizes vary
        per mutation batch; unpadded shapes would recompile every time).
        Padding rows duplicate row 0 with an all-False mask, so real rows
        stay bitwise-identical and the pad is sliced off on return.
        """
        lg = self.layer_graphs[l]
        L = self.n_layers
        F = lg.fanout
        nbrs = lg.nbr[rows][lg.mask[rows]]
        U = np.unique(np.concatenate([rows, nbrs.astype(np.int64)]))
        R, Rp = rows.size, _pow2(rows.size)
        Up = _pow2(U.size)
        pos = np.zeros((Rp, F), np.int32)
        pos[:R] = _remap(lg.nbr[rows], lg.mask[rows], U)
        mask_np = np.zeros((Rp, F), bool)
        mask_np[:R] = lg.mask[rows]
        rows_p = np.concatenate([rows, np.zeros(Rp - R, np.int64)])
        U_p = np.concatenate([U, np.zeros(Up - U.size, np.int64)])
        rows = rows_p
        mask = jnp.asarray(mask_np)
        H_U = jnp.asarray(read_level(l, U_p))
        self.rows_gemm += int(U.size)

        if self.model == "gcn":
            w = self.params["w"][l]
            wts = jnp.asarray(mean_weights(mask_np))
            Hw = prim.ref_gemm(H_U, jnp.asarray(w))
            h = prim.ref_spmm(Hw, wts, jnp.asarray(pos), mask)
        elif self.model == "sage":
            p = self.params["layers"][l]
            wts = jnp.asarray(mean_weights(mask_np))
            agg = prim.ref_spmm(H_U, wts, jnp.asarray(pos), mask)
            own = jnp.asarray(read_level(l, rows))
            h = prim.ref_gemm(own, jnp.asarray(p["w_self"])) + \
                prim.ref_gemm(agg, jnp.asarray(p["w_nbr"]))
        else:                                           # gat
            p = self.params["layers"][l]
            heads = self.params["heads"]
            q = prim.ref_gemm(jnp.asarray(read_level(l, rows)),
                              jnp.asarray(p["wq"]))
            kf = prim.ref_gemm(H_U, jnp.asarray(p["wk"]))
            v = prim.ref_gemm(H_U, jnp.asarray(p["wv"]))
            # gat_head_scores with q (rows) and kf (universe) row counts
            # decoupled — same per-row ops, so still bitwise-identical
            n, D = q.shape
            dh = D // heads
            qh = q.reshape(n, heads, dh)
            kh = kf.reshape(-1, heads, dh)
            kn = jnp.take(kh, pos.reshape(-1), axis=0).reshape(
                pos.shape + (heads, dh))
            s = jnp.einsum("nhd,nfhd->nfh", qh, kn) / \
                jnp.sqrt(jnp.float32(dh))
            alpha = masked_softmax(s.transpose(0, 2, 1),
                                   mask[:, None, :]).transpose(0, 2, 1)
            vn = jnp.take(v.reshape(-1, heads, dh), pos.reshape(-1),
                          axis=0).reshape(pos.shape + (heads, dh))
            h = jnp.einsum("nfh,nfhd->nhd", alpha, vn).reshape(n, D)

        if l < L - 1:
            act = jax.nn.relu if self.model in ("gcn", "sage") else jax.nn.elu
            h = act(h)
        return np.asarray(jax.block_until_ready(h))[:R]

    # -- the refresh ----------------------------------------------------
    def refresh(self, store: EmbeddingStore, g_new: Graph,
                feat_ids: np.ndarray, feat_rows: np.ndarray,
                resampled: np.ndarray) -> Dict[str, float]:
        """Apply one mutation batch's compute: resample dirty rows of the
        layer graphs from `g_new`, walk the forward frontier, and rewrite
        only those store rows.  Commits a new store version."""
        resampled = np.asarray(resampled, np.int64)
        feat_ids = np.asarray(feat_ids, np.int64)
        self.rows_gemm = 0

        # snapshot the rows about to be resampled so a failed refresh can
        # roll the layer graphs back in lockstep with the store abort —
        # otherwise graphs and store drift apart and the skipped rows
        # never re-enter a frontier
        old_rows = ([(lg.nbr[resampled].copy(), lg.mask[resampled].copy())
                     for lg in self.layer_graphs]
                    if resampled.size else None)
        try:
            resample_rows(g_new, self.layer_graphs, resampled,
                          seed=self.sample_seed + store.version + 1)
            if resampled.size:
                # NOTE: full O(N*F) rebuild per mutated refresh;
                # incremental splice of the resampled rows' old/new
                # entries would make this O(changed) — ROADMAP open item
                self._rev = [None] * len(self.layer_graphs)
            frontier = forward_frontier(
                [self._reverse(l) for l in range(self.n_layers)],
                feat_ids, resampled, self.n_layers)

            store.begin_update()
            if feat_ids.size:
                store.write_rows(0, feat_ids,
                                 np.asarray(feat_rows, np.float32))
            for l in range(self.n_layers):
                rows = frontier[l]
                if rows.size == 0:
                    continue
                h = self._layer_rows(
                    l, rows, lambda lvl, want: store.lookup_staged(want, lvl))
                store.write_rows(l + 1, rows, h)
        except Exception:
            store.abort()       # readers stay on the last committed epoch
            if old_rows is not None:
                for lg, (nbr, mask) in zip(self.layer_graphs, old_rows):
                    lg.nbr[resampled] = nbr
                    lg.mask[resampled] = mask
                self._rev = [None] * len(self.layer_graphs)
            raise
        version = store.commit()
        return {"version": version, "rows_gemm": self.rows_gemm,
                "frontier_sizes": [int(f.size) for f in frontier],
                "n_resampled": int(resampled.size),
                "n_feat_updates": int(feat_ids.size)}
