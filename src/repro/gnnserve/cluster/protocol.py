"""Length-prefixed JSON/binary framing for the cluster serving tier.

Everything is standard library (sockets + struct + json), like
``obs/endpoint.py`` — the container bakes in only the jax toolchain.

Wire format, one frame per message:

    u32 frame_len                           # bytes after this field
    u32 header_len
    header_len bytes of UTF-8 JSON          # op/fields + array manifest
    concatenated raw array bytes            # in manifest order

The JSON header carries the small fields (op name, seq numbers, stats
trees); numpy arrays ride OUTSIDE the JSON as raw bytes, described by a
``_arrays`` manifest (``[{name, dtype, shape}, ...]``) so a 10MB float32
gather never round-trips through decimal text.  Both directions use the
same frame; responses carry ``ok: true`` or ``ok: false`` + ``error`` +
``traceback``.

``Channel`` is the client half: one persistent connection, one
request/response in flight at a time (a lock serializes callers), a
configurable timeout that surfaces as ``WorkerTimeout`` so the
deployment can consult the worker's heartbeat file and diagnose a wedge
by stage name instead of a bare socket timeout.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
from typing import Dict, Optional, Tuple

import numpy as np

# one u32 length prefix; frames above this are a protocol error, not an
# allocation bomb — a corrupt/malicious prefix must not trigger a
# multi-GiB allocation in ``_recv_exact``.  A full-graph gather at
# smoke scale is ~MBs; 256 MiB leaves two orders of headroom.  Callers
# with genuinely larger worlds pass ``max_frame`` explicitly.
MAX_FRAME = 1 << 28


class ProtocolError(RuntimeError):
    """Malformed frame / unexpected EOF on the wire."""


class WorkerError(RuntimeError):
    """The remote worker raised; carries its traceback text."""

    def __init__(self, message: str, remote_traceback: str = ""):
        super().__init__(message)
        self.remote_traceback = remote_traceback


class WorkerTimeout(RuntimeError):
    """No response within the channel timeout — the caller should check
    the worker's heartbeat file before deciding dead vs slow."""


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(min(n - len(buf), 1 << 20))
        except socket.timeout as exc:
            raise WorkerTimeout(
                f"no bytes for {sock.gettimeout()}s mid-frame") from exc
        if not chunk:
            raise ProtocolError(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def send_msg(sock: socket.socket, header: Dict,
             arrays: Optional[Dict[str, np.ndarray]] = None, *,
             max_frame: int = MAX_FRAME) -> None:
    """Send one frame: JSON ``header`` plus raw ``arrays`` payloads."""
    arrays = arrays or {}
    manifest = []
    blobs = []
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        manifest.append({"name": name, "dtype": arr.dtype.str,
                         "shape": list(arr.shape)})
        blobs.append(arr.tobytes())
    doc = dict(header)
    doc["_arrays"] = manifest
    head = json.dumps(doc).encode()
    body = b"".join([struct.pack("<I", len(head)), head] + blobs)
    if len(body) + 4 > max_frame:
        raise ProtocolError(f"frame too large ({len(body)} bytes)")
    sock.sendall(struct.pack("<I", len(body)) + body)


def recv_msg(sock: socket.socket, *, max_frame: int = MAX_FRAME
             ) -> Tuple[Dict, Dict[str, np.ndarray]]:
    """Receive one frame -> (header, arrays).  Raises ProtocolError on
    EOF/garbage, WorkerTimeout if a frame stalls mid-flight.  A timeout
    BEFORE any byte of a frame arrives re-raises ``socket.timeout``
    as-is — that's idleness at a frame boundary, not a torn frame, and
    the worker serve loop uses it to stamp heartbeats while idle
    (``Channel.request`` converts it to WorkerTimeout: there a silent
    peer IS the failure)."""
    raw = sock.recv(4)
    if not raw:
        raise ProtocolError("connection closed")
    raw += _recv_exact(sock, 4 - len(raw)) if len(raw) < 4 else b""
    (frame_len,) = struct.unpack("<I", raw)
    if frame_len > max_frame:
        raise ProtocolError(f"frame length {frame_len} exceeds cap")
    body = _recv_exact(sock, frame_len)
    (head_len,) = struct.unpack("<I", body[:4])
    if head_len + 4 > frame_len:
        raise ProtocolError("header length exceeds frame")
    try:
        header = json.loads(body[4:4 + head_len].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise ProtocolError(f"bad JSON header: {exc}") from None
    arrays: Dict[str, np.ndarray] = {}
    off = 4 + head_len
    for spec in header.pop("_arrays", []):
        dt = np.dtype(spec["dtype"])
        shape = tuple(int(x) for x in spec["shape"])
        n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        if off + n > len(body):
            raise ProtocolError(
                f"array {spec['name']!r} overruns the frame")
        arrays[spec["name"]] = np.frombuffer(
            body[off:off + n], dtype=dt).reshape(shape).copy()
        off += n
    return header, arrays


class Channel:
    """One persistent client connection to a ShardWorker, with a lock so
    concurrent router threads serialize their request/response pairs."""

    def __init__(self, host: str, port: int, *,
                 timeout: Optional[float] = 30.0,
                 connect_timeout: float = 5.0):
        self.host, self.port = host, int(port)
        self.timeout = timeout
        self._lock = threading.Lock()
        self._sock = socket.create_connection((host, int(port)),
                                              timeout=connect_timeout)
        self._sock.settimeout(timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def request(self, op: str,
                arrays: Optional[Dict[str, np.ndarray]] = None,
                **fields) -> Tuple[Dict, Dict[str, np.ndarray]]:
        """One round trip.  Raises ``WorkerError`` when the remote
        handler failed, ``WorkerTimeout``/``ProtocolError`` when the
        connection did."""
        header = {"op": op, **fields}
        with self._lock:
            send_msg(self._sock, header, arrays)
            try:
                resp, resp_arrays = recv_msg(self._sock)
            except socket.timeout as exc:
                raise WorkerTimeout(
                    f"no response to {op!r} within "
                    f"{self._sock.gettimeout()}s") from exc
        if not resp.get("ok", False):
            raise WorkerError(
                f"shard op {op!r} failed: {resp.get('error', '?')}",
                resp.get("traceback", ""))
        return resp, resp_arrays

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


__all__ = ["Channel", "MAX_FRAME", "ProtocolError", "WorkerError",
           "WorkerTimeout", "recv_msg", "send_msg"]
