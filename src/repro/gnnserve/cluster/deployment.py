"""ClusterDeployment — spawn, readiness, liveness, and the
drive-compatible ``ClusterEngine`` facade.

``Session.serve()`` builds one of these when ``DealConfig.cluster``
asks for shards: it dumps the config to the run directory, spawns one
``cluster.worker`` process per shard (each builds — or restores +
replays — the full world from that config), waits for readiness (the
port file is written only after the world stands and the socket
listens), and wires a ``Router`` over persistent channels.

Liveness extends the PR 8 heartbeat/wedge harness to cluster
subprocesses: every worker stamps ``shard<i>.hb`` from its MAIN thread;
``check_heartbeats`` reads the stamps and ``kill_wedged`` kills a stale
worker with a STAGE-NAMED diagnosis ("wedged in op:lookup for 12.3s")
instead of a bare timeout.  A killed worker is restartable in place —
``restart_worker`` respawns it against the same run directory, where it
reloads its checkpoint and replays its WAL segment (``worker.py``'s
bitwise rejoin contract); the router's reconnect hook does this
transparently when an RPC hits a dead channel.

``ClusterEngine`` gives the deployment the exact engine surface the
launchers and benchmarks already drive (submit/step/run, mutate,
refresh, full_epoch, stats, memory_stats): queries serve strictly in
submission order, and the refresh decision replicates the single-
process FIFO rule — refresh when the buffered log reaches the bound (or
a query demands fresh) — which is what makes cluster-served bytes equal
to a single-process ``Session`` on the same config: pins happen in
submission order in both, so each query serves the same epoch.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from repro.gnnserve.cluster.protocol import Channel
from repro.gnnserve.cluster.router import Router, RouterEndpoint


def _src_root() -> str:
    # repro is a namespace package (__file__ is None): the import root
    # is the parent of its first __path__ entry
    import repro
    return os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


def read_heartbeat(path: str):
    """``(stamp, stage)`` from a heartbeat file, or ``(None, "?")``."""
    try:
        with open(path) as f:
            stamp, _, stage = f.read().strip().partition(" ")
        return float(stamp), stage or "?"
    except (OSError, ValueError):
        return None, "?"


class WorkerWedged(RuntimeError):
    """A worker's main thread stopped stamping its heartbeat; the
    message names the stage it wedged in."""


class ClusterDeployment:
    def __init__(self, cfg, *, run_dir: Optional[str] = None,
                 env: Optional[Dict[str, str]] = None):
        spec = cfg.cluster
        assert spec.n_shards > 0, "ClusterSpec.n_shards must be > 0"
        self.cfg = cfg
        self.n_shards = int(spec.n_shards)
        self.host = spec.host
        self.run_dir = run_dir or spec.run_dir or tempfile.mkdtemp(
            prefix="deal-cluster-")
        os.makedirs(self.run_dir, exist_ok=True)
        self.config_path = os.path.join(self.run_dir, "config.json")
        cfg.dump(self.config_path)
        self._env = dict(os.environ if env is None else env)
        self._env["PYTHONPATH"] = os.pathsep.join(
            p for p in (_src_root(), self._env.get("PYTHONPATH")) if p)
        self.procs: List[Optional[subprocess.Popen]] = [None] * self.n_shards
        self.n_restarts = 0
        self.ready_wait_s = 0.0
        t0 = time.perf_counter()
        for i in range(self.n_shards):
            self._spawn(i)
        channels = [self._wait_ready(i, timeout=spec.ready_timeout_s)
                    for i in range(self.n_shards)]
        self.ready_wait_s = time.perf_counter() - t0
        st = channels[0].request("status")[0]
        self.n_levels = int(st["n_levels"])
        dims = [int(d) for d in st["dims"]]
        bounds = np.linspace(0, int(st["n_nodes"]),
                             self.n_shards + 1).astype(np.int64)
        self.router = Router(channels, bounds, dims,
                             reconnect=self._reconnect)
        self.router.n_nodes = int(st["n_nodes"])
        self.engine = ClusterEngine(self, self.router)
        self.endpoint: Optional[RouterEndpoint] = None
        if spec.http_port >= 0:
            self.endpoint = RouterEndpoint(
                self, port=spec.http_port, host=spec.host).start()

    # -- process lifecycle ----------------------------------------------
    def _paths(self, shard: int) -> Dict[str, str]:
        return {k: os.path.join(self.run_dir, f"shard{shard}.{ext}")
                for k, ext in (("port", "port"), ("hb", "hb"),
                               ("log", "log"))}

    def _spawn(self, shard: int) -> None:
        p = self._paths(shard)
        if os.path.exists(p["port"]):   # stale marker must not fake
            os.unlink(p["port"])        # readiness for the new process
        ports = self.cfg.cluster.ports
        argv = [sys.executable, "-m", "repro.gnnserve.cluster.worker",
                "--config", self.config_path,
                "--shard", str(shard),
                "--n-shards", str(self.n_shards),
                "--dir", self.run_dir,
                "--host", self.host,
                "--heartbeat", p["hb"]]
        if ports:
            argv += ["--port", str(ports[shard])]
        logf = open(p["log"], "ab")
        try:
            self.procs[shard] = subprocess.Popen(
                argv, env=self._env, stdout=logf, stderr=logf,
                cwd=self.run_dir)
        finally:
            logf.close()            # the child holds its own descriptor

    def _wait_ready(self, shard: int, *, timeout: float) -> Channel:
        """Block until the worker's port file appears, then connect.
        On timeout, diagnose via the heartbeat: a moving stamp means
        slow (report the stage it is in), a stale one means wedged."""
        p = self._paths(shard)
        deadline = time.monotonic() + timeout
        while not os.path.exists(p["port"]):
            proc = self.procs[shard]
            if proc is not None and proc.poll() is not None:
                raise RuntimeError(
                    f"shard {shard} exited with rc={proc.returncode} "
                    f"before readiness — see {p['log']}")
            if time.monotonic() > deadline:
                stamp, stage = read_heartbeat(p["hb"])
                age = (time.time() - stamp) if stamp else float("inf")
                raise WorkerWedged(
                    f"shard {shard} not ready after {timeout:.0f}s, "
                    f"last heartbeat stage {stage!r} ({age:.1f}s ago)")
            time.sleep(0.05)
        with open(p["port"]) as f:
            port = int(f.read().strip())
        ch = Channel(self.host, port,
                     timeout=self.cfg.cluster.hang_timeout_s)
        ch.request("status")        # one probe proves the loop serves
        return ch

    def kill_worker(self, shard: int, *, sig=signal.SIGKILL) -> None:
        """Hard-kill one worker (the failure-injection hook the replay
        tests and the CI smoke use)."""
        proc = self.procs[shard]
        if proc is not None and proc.poll() is None:
            proc.send_signal(sig)
            proc.wait(timeout=30)

    def restart_worker(self, shard: int) -> Channel:
        """Respawn a (dead) worker against the same run directory: it
        restores its checkpoint, replays its WAL segment, and rejoins
        bitwise-equal.  Returns the fresh channel (also installed in
        the router if one exists)."""
        self.kill_worker(shard)
        self._spawn(shard)
        self.n_restarts += 1
        ch = self._wait_ready(shard,
                              timeout=self.cfg.cluster.ready_timeout_s)
        if getattr(self, "router", None) is not None:
            self.router.channels[shard].close()
            self.router.channels[shard] = ch
        return ch

    def _reconnect(self, shard: int) -> Channel:
        """Router hook on a broken channel: reconnect if the process is
        alive (a probe connection dropped us), full restart if not."""
        proc = self.procs[shard]
        if proc is not None and proc.poll() is None:
            p = self._paths(shard)
            with open(p["port"]) as f:
                port = int(f.read().strip())
            try:
                ch = Channel(self.host, port,
                             timeout=self.cfg.cluster.hang_timeout_s)
                ch.request("status")
                return ch
            except Exception:
                self.kill_worker(shard)
        return self.restart_worker(shard)

    # -- liveness (PR 8 wedge harness, cluster edition) ------------------
    def check_heartbeats(self) -> List[Dict]:
        """Per-shard liveness: last stamped stage + staleness."""
        out = []
        now = time.time()
        for i in range(self.n_shards):
            stamp, stage = read_heartbeat(self._paths(i)["hb"])
            proc = self.procs[i]
            out.append({"shard": i, "stage": stage,
                        "age_s": (now - stamp) if stamp else None,
                        "alive": proc is not None and proc.poll() is None})
        return out

    def kill_wedged(self, *, max_age_s: Optional[float] = None,
                    restart: bool = True) -> List[str]:
        """Kill workers whose MAIN thread stopped stamping for longer
        than ``max_age_s`` (default: the spec's hang timeout).  Returns
        one stage-named diagnosis per kill; with ``restart`` the worker
        respawns and replays in place."""
        max_age = (self.cfg.cluster.hang_timeout_s
                   if max_age_s is None else max_age_s)
        diagnoses = []
        for hb in self.check_heartbeats():
            if not hb["alive"] or hb["age_s"] is None:
                continue
            if hb["age_s"] > max_age:
                diagnoses.append(
                    f"shard {hb['shard']} wedged in stage "
                    f"{hb['stage']!r} for {hb['age_s']:.1f}s — killed")
                self.kill_worker(hb["shard"])
                if restart:
                    self.restart_worker(hb["shard"])
        return diagnoses

    # -- merged views ----------------------------------------------------
    def stats(self) -> Dict:
        """Merged ``Session.stats()`` schema + a ``cluster`` subtree."""
        out = self.router.session_stats()
        out["cluster"] = {"n_shards": self.n_shards,
                          "n_restarts": self.n_restarts,
                          "run_dir": self.run_dir,
                          "ready_wait_s": self.ready_wait_s,
                          "router": self.router.router_stats(),
                          "shards": self.router.statuses()}
        return out

    def shutdown(self) -> None:
        if self.endpoint is not None:
            self.endpoint.stop()
            self.endpoint = None
        if getattr(self, "router", None) is not None:
            self.router.shutdown()
        for i, proc in enumerate(self.procs):
            if proc is not None and proc.poll() is None:
                proc.terminate()
                try:
                    proc.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    proc.kill()
                    proc.wait(timeout=10)
            self.procs[i] = None

    def __enter__(self) -> "ClusterDeployment":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


# ----------------------------------------------------------------------
# drive-compatible engine facade
# ----------------------------------------------------------------------

class _StoreProxy:
    """The store attributes launcher loops read (extent, dims,
    budget)."""

    def __init__(self, router: Router, n_levels: int, budget_rows):
        self._router = router
        self.n_levels = n_levels
        self.budget_rows = budget_rows

    @property
    def n_nodes(self) -> int:
        return int(self._router.n_nodes)

    def level_dim(self, level: int) -> int:
        return self._router.dims[level % len(self._router.dims)]


class _ReinferProxy:
    def __init__(self, n_layers: int):
        self.n_layers = n_layers


class _QoSProxy:
    """Just enough QoS surface for the launcher's printouts: the
    registry (names/specs); scheduling itself lives in the workers."""

    def __init__(self, registry):
        self.registry = registry


class ClusterEngine:
    """Engine-shaped front over the router: strict submission-order
    FIFO service with the single-process refresh rule (see the module
    docstring for why that makes served bytes equal)."""

    def __init__(self, deployment: ClusterDeployment, router: Router):
        cfg = deployment.cfg
        self.deployment = deployment
        self.router = router
        self.log = router.log
        self.store = _StoreProxy(router, deployment.n_levels,
                                 cfg.store.budget_rows or None)
        self.reinfer = _ReinferProxy(deployment.n_levels - 1)
        self.staleness_bound = cfg.qos.staleness_bound
        registry = cfg.qos.tenant_registry()
        self.qos = _QoSProxy(registry) if registry is not None else None
        self._slos = ({t.name: t.staleness_slo for t in registry}
                      if registry is not None else {})
        self._queue: List = []
        self.last_refresh_stats: Dict = {}
        self.n_served = 0

    # -- engine surface --------------------------------------------------
    def submit(self, q) -> None:
        q.node_ids = np.asarray(q.node_ids, np.int64)
        self._queue.append(q)

    def mutate(self):
        return self.log

    def refresh(self) -> Dict:
        stats = self.router.commit_pending()
        if stats:
            self.last_refresh_stats = stats
        return stats

    def full_epoch(self, n_shards: Optional[int] = None) -> Dict:
        return self.router.full_epoch(n_shards)

    def _threshold(self, q) -> int:
        """The freshness bound this query serves under: its tenant's
        SLO with QoS, the global bound otherwise."""
        return int(self._slos.get(q.tenant, self.staleness_bound))

    def step(self) -> bool:
        """Serve ONE queued query end-to-end (refresh decision first —
        the single-process FIFO rule at this query's pin point)."""
        if not self._queue:
            return False
        q = self._queue.pop(0)
        if self.log.pending and (q.fresh
                                 or self.log.pending >= self._threshold(q)):
            self.refresh()
        q.out, q.served_version = self.router.lookup(
            q.node_ids, level=q.level, tenant=q.tenant, uid=q.uid)
        q.done = True
        self.n_served += 1
        return True

    def run(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.step():
                return

    def stats(self) -> Dict:
        return self.router.engine_stats()

    def memory_stats(self) -> Dict:
        return self.router.memory_stats()


__all__ = ["ClusterDeployment", "ClusterEngine", "WorkerWedged",
           "read_heartbeat"]
