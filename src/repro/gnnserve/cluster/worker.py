"""ShardWorker — one OS process owning a partition range of the serving
tier.

Each worker builds the FULL ``Session`` world from the shared
``DealConfig`` (two sessions built from equal configs are
bitwise-identical worlds — the repo-wide invariant every executor test
asserts), so shard "ownership" is a routing policy at the front door,
not a data-placement constraint: any worker CAN serve any row, the
router sends each id range to its owner for cache locality and QoS
isolation, and cross-shard consistency is the bitwise-equal-worlds
invariant rather than a distributed coherence protocol.  Per-shard
``ClusterSpec.overrides`` may tighten a worker's store budget or QoS
geometry — residency changes, served bytes don't (the
recompute-on-miss guarantee).

Determinism contract (what makes restart-replay *bitwise*):

  * the router alone decides when mutations fold: workers never refresh
    autonomously (their own mutation logs are empty between commits),
    so every worker applies the SAME mutation batches in the SAME
    order at the SAME epoch boundaries;
  * every ``commit`` carries a per-shard monotonic ``seq`` and is
    appended to the worker's write-ahead log (``shard<i>.wal``,
    JSON-lines) BEFORE it is applied; duplicate seqs ack idempotently
    (the router may re-send after a restart);
  * after a successful commit the worker checkpoints its world
    (``gnnserve.checkpoint.save_world`` -> ``shard<i>.ckpt.npz``) with
    ``committed_seq``;
  * a restarted worker restores the checkpoint and replays exactly the
    WAL entries with ``seq > committed_seq``, each as one refresh at
    its original batch boundary — landing bitwise-equal to a
    never-killed worker (content-addressed resampling would make ANY
    replay batching land on the same bytes once the final CSR matches;
    replaying at the original boundaries makes the epoch *counters*
    match too).

Liveness: the worker stamps ``shard<i>.hb`` with ``<unix-time> <stage>``
from its MAIN thread before every potentially-slow stage (build,
restore, replay, each op).  The deployment watches the file's mtime —
PR 8's wedge-detection harness extended to cluster subprocesses — so a
hung worker is killed with a stage-named diagnosis instead of a bare
timeout.

Protocol ops (see ``protocol`` for framing): status, lookup, commit,
full_epoch, checkpoint, digest, stats, engine_stats, memory_stats,
health, shutdown — plus ``_test_hang``, a deliberate main-thread wedge
for the harness tests.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import sys
import time
import traceback
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.gnnserve.cluster.protocol import recv_msg, send_msg

_HEX = "0123456789abcdef"


class Heartbeat:
    """Main-thread liveness stamps, same file format as the test
    harness's ``tests/helpers/_heartbeat.py`` (``<time> <stage>``): a
    timer thread would keep ticking through a wedge and hide it."""

    def __init__(self, path: Optional[str]):
        self.path = path

    def beat(self, stage: str) -> None:
        if not self.path:
            return
        try:
            with open(self.path, "w") as f:
                f.write(f"{time.time():.3f} {stage}\n")
        except OSError as exc:
            print(f"# heartbeat write failed: {exc}", file=sys.stderr)


def _wal_encode(entry: Dict) -> str:
    return json.dumps(entry, sort_keys=True)


def _rows_to_wire(rows: Optional[np.ndarray]):
    """float32 rows -> JSON lists.  Exact: float32 -> float64 is exact,
    json round-trips the float64, and the cast back truncates to the
    original float32 bit pattern."""
    if rows is None:
        return None
    return np.asarray(rows, np.float32).tolist()


def _rows_from_wire(data, d: Optional[int] = None
                    ) -> Optional[np.ndarray]:
    if data is None:
        return None
    arr = np.asarray(data, np.float32)
    if arr.size == 0 and d is not None:
        arr = arr.reshape(0, d)
    return arr


class WorkerCore:
    """The op dispatcher over one Session world — everything but the
    socket, so tests drive restart/replay/bitwise in-process."""

    def __init__(self, cfg, shard: int, n_shards: int, run_dir: str,
                 heartbeat: Optional[Heartbeat] = None):
        from repro.api.session import Session
        self.shard = int(shard)
        self.n_shards = int(n_shards)
        self.dir = run_dir
        self.hb = heartbeat or Heartbeat(None)
        self.cfg = self._shard_config(cfg)
        self.wal_path = os.path.join(run_dir, f"shard{shard}.wal")
        self.ckpt_path = os.path.join(run_dir, f"shard{shard}.ckpt.npz")
        self.session = None
        self._load_world()

    def _load_world(self) -> None:
        """(Re)build the engine at the last COMMITTED state: restore
        the checkpoint when one exists (fresh world otherwise), then
        replay the WAL tail past ``committed_seq``.  Both the startup
        path and the recovery path after a failed apply (which may have
        half-mutated the live engine) land here."""
        from repro.api.session import Session
        if self.session is not None:
            try:
                self.session.close()
            except Exception:
                pass                # a torn world may not close cleanly
        self.last_seq = 0
        self.replayed = 0
        self.restored = False
        self.last_refresh_stats: Dict = {}
        self.hb.beat("build")
        if os.path.exists(self.ckpt_path):
            from repro.gnnserve.checkpoint import restore_into_session
            self.session = Session.build(self.cfg)
            self.hb.beat("restore")
            meta = restore_into_session(self.session, self.ckpt_path)
            self.last_seq = int(meta["committed_seq"])
            self.restored = True
        else:
            self.session = Session.build(self.cfg)
            self.session.serve()
        self.engine = self.session.engine
        self.hb.beat("replay")
        self._replay_wal()

    def _shard_config(self, cfg):
        """A deep copy with this shard's overrides applied and the
        worker-inappropriate bits neutralized (the ROUTER owns the HTTP
        front door and the cluster spec itself — a worker recursively
        launching a cluster would fork-bomb)."""
        from repro.api.config import DealConfig
        cfg = DealConfig.from_dict(cfg.to_dict())
        cfg.telemetry.http_port = -1
        cfg.telemetry.snapshot_path = ""
        if hasattr(cfg, "cluster"):
            cfg.cluster.n_shards = 0
        for ov in getattr(cfg.cluster, "overrides", ()):
            if int(ov.get("shard", -1)) != self.shard:
                continue
            for k, v in ov.items():
                if k == "shard":
                    continue
                if k in ("budget_rows", "evict_policy", "admission"):
                    setattr(cfg.store, k, v)
                elif k in ("staleness_bound", "batch_slots",
                           "rows_per_step"):
                    setattr(cfg.qos, k, v)
        # folded into store/qos above; with n_shards zeroed, leftover
        # shard-indexed overrides would fail validation
        cfg.cluster.overrides = ()
        return cfg

    # -- WAL ------------------------------------------------------------
    def _wal_append(self, entry: Dict) -> None:
        """Durable BEFORE applied: a crash mid-apply replays the entry;
        a crash before the append means the router never got an ack and
        re-sends it with the same seq.  An apply that RAISES (rather
        than crashes) truncates the entry back out via ``_rollback`` —
        the WAL only ever ends at a committed boundary."""
        with open(self.wal_path, "a") as f:
            f.write(_wal_encode(entry) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def _replay_wal(self) -> None:
        if not os.path.exists(self.wal_path):
            return
        prev = None
        with open(self.wal_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                entry = json.loads(line)
                seq = int(entry["seq"])
                # the on-disk chain must be strictly increasing: a
                # duplicate seq means a torn entry escaped rollback —
                # replaying either copy could apply a batch the cluster
                # never committed, so refuse loudly instead
                if prev is not None and seq <= prev:
                    raise ValueError(
                        f"shard {self.shard}: WAL seq {seq} after "
                        f"{prev} — duplicate/out-of-order entry in "
                        f"{self.wal_path}")
                prev = seq
                if seq <= self.last_seq:
                    continue
                if seq != self.last_seq + 1:
                    raise ValueError(
                        f"shard {self.shard}: WAL gap — entry seq "
                        f"{seq} after committed {self.last_seq}")
                self.hb.beat(f"replay:seq{seq}")
                if entry["kind"] == "commit":
                    self._apply_commit(entry)
                elif entry["kind"] == "full_epoch":
                    self._apply_full_epoch(entry.get("n_shards"))
                else:
                    raise ValueError(
                        f"unknown WAL entry kind {entry['kind']!r}")
                self.last_seq = seq
                self.replayed += 1
        if self.replayed:
            # re-checkpoint so the NEXT restart skips this replay
            self._save_checkpoint()

    def _save_checkpoint(self) -> None:
        from repro.gnnserve.checkpoint import save_world
        tmp = self.ckpt_path + ".tmp"
        save_world(tmp, self.engine, committed_seq=self.last_seq)
        os.replace(tmp, self.ckpt_path)

    def _wal_size(self) -> int:
        try:
            return os.path.getsize(self.wal_path)
        except OSError:
            return 0

    def _rollback(self, wal_pos: int) -> None:
        """A failed apply must leave NO trace: truncate the WAL back
        past the torn entry (otherwise a restart replays it and a later
        commit appends a second entry with the same seq) and rebuild
        the world at the last committed state — the apply may have
        half-mutated the live engine before raising."""
        with open(self.wal_path, "r+") as f:
            f.truncate(wal_pos)
            f.flush()
            os.fsync(f.fileno())
        self.hb.beat("recover")
        self._load_world()

    # -- mutation fold --------------------------------------------------
    def _apply_commit(self, entry: Dict) -> Dict:
        eng = self.engine
        log = eng.mutate()
        for kind, s, d in entry.get("edge_ops", []):
            if kind == "add":
                log.add_edge(int(s), int(d))
            else:
                log.remove_edge(int(s), int(d))
        feat_ids = np.asarray(entry.get("feat_ids", []), np.int64)
        if feat_ids.size:
            log.update_features(
                feat_ids, _rows_from_wire(entry["feat_rows"]))
        n_new = int(entry.get("n_new_nodes", 0))
        if n_new:
            log.add_nodes(n_new,
                          _rows_from_wire(entry.get("new_node_rows")))
        stats = eng.refresh() if log.pending else dict(
            self.last_refresh_stats)
        if eng.qos is not None:
            # a router commit is a BARRIER freshness event: every
            # tenant's view advances to the committed epoch, so per-
            # shard view lag can never depend on per-shard traffic —
            # the determinism the replay contract needs
            eng.qos.advance_views(eng.qos.registry.names,
                                  eng.store.version, eng.ops_drained,
                                  refreshed=bool(feat_ids.size or n_new
                                                 or entry.get("edge_ops")))
        self.last_refresh_stats = stats
        return stats

    def _apply_full_epoch(self, n_shards: Optional[int]) -> Dict:
        return self.engine.full_epoch(n_shards or None)

    # -- op dispatch ----------------------------------------------------
    def dispatch(self, header: Dict, arrays: Dict[str, np.ndarray]
                 ) -> Tuple[Dict, Dict[str, np.ndarray]]:
        op = header.get("op", "?")
        self.hb.beat(f"op:{op}")
        fn = getattr(self, f"_op_{op}", None)
        if fn is None:
            raise ValueError(f"unknown op {op!r}")
        resp, resp_arrays = fn(header, arrays)
        resp.setdefault("ok", True)
        self.hb.beat("idle")
        return resp, resp_arrays

    def _op_status(self, header, arrays):
        st = self.engine.store
        return {"shard": self.shard, "n_shards": self.n_shards,
                "pid": os.getpid(), "n_nodes": int(st.n_nodes),
                "n_levels": int(st.n_levels),
                "dims": [st.level_dim(l) for l in range(st.n_levels)],
                "store_version": int(st.version),
                "last_seq": self.last_seq,
                "replayed": self.replayed,
                "restored": self.restored,
                "pending": int(self.engine.log.pending)}, {}

    def _op_lookup(self, header, arrays):
        from repro.gnnserve.engine import Query
        eng = self.engine
        q = Query(uid=int(header.get("uid", 0)),
                  node_ids=np.asarray(arrays["ids"], np.int64),
                  level=int(header.get("level", -1)),
                  tenant=header.get("tenant", "default"))
        eng.submit(q)
        eng.run()
        assert q.done, "worker engine left a query unserved"
        return {"served_version": int(q.served_version)}, {"rows": q.out}

    def _op_commit(self, header, arrays):
        seq = int(header["seq"])
        if seq <= self.last_seq:
            # idempotent re-send after a router reconnect: the entry is
            # already durable and applied (or will replay); ack as-is
            return {"seq": seq, "duplicate": True,
                    "store_version": int(self.engine.store.version),
                    "n_nodes": int(self.engine.store.n_nodes),
                    "stats": _sanitize(self.last_refresh_stats)}, {}
        if seq != self.last_seq + 1:
            raise ValueError(
                f"shard {self.shard}: commit seq {seq} breaks the "
                f"monotonic chain at {self.last_seq}")
        entry = {"seq": seq, "kind": "commit",
                 "edge_ops": [[k, int(s), int(d)]
                              for k, s, d in header.get("edge_ops", [])],
                 "feat_ids": [int(i) for i in
                              np.asarray(arrays.get(
                                  "feat_ids", np.empty(0, np.int64)))],
                 "feat_rows": _rows_to_wire(arrays.get("feat_rows")),
                 "n_new_nodes": int(header.get("n_new_nodes", 0)),
                 "new_node_rows": _rows_to_wire(
                     arrays.get("new_node_rows"))}
        if entry["feat_rows"] is None:
            entry["feat_rows"] = []
        wal_pos = self._wal_size()
        self._wal_append(entry)
        try:
            stats = self._apply_commit(entry)
        except Exception:
            self._rollback(wal_pos)
            raise
        self.last_seq = seq
        self._save_checkpoint()
        return {"seq": seq, "duplicate": False,
                "store_version": int(self.engine.store.version),
                "n_nodes": int(self.engine.store.n_nodes),
                "stats": _sanitize(stats)}, {}

    def _op_full_epoch(self, header, arrays):
        seq = int(header["seq"])
        if seq <= self.last_seq:
            return {"seq": seq, "duplicate": True,
                    "store_version": int(self.engine.store.version),
                    "stats": {}}, {}
        if seq != self.last_seq + 1:
            raise ValueError(
                f"shard {self.shard}: full_epoch seq {seq} breaks the "
                f"monotonic chain at {self.last_seq}")
        entry = {"seq": seq, "kind": "full_epoch",
                 "n_shards": header.get("n_shards")}
        wal_pos = self._wal_size()
        self._wal_append(entry)
        try:
            stats = self._apply_full_epoch(entry["n_shards"])
        except Exception:
            self._rollback(wal_pos)
            raise
        self.last_seq = seq
        self._save_checkpoint()
        return {"seq": seq, "duplicate": False,
                "store_version": int(self.engine.store.version),
                "n_nodes": int(self.engine.store.n_nodes),
                "stats": _sanitize(stats)}, {}

    def _op_checkpoint(self, header, arrays):
        self._save_checkpoint()
        return {"path": self.ckpt_path,
                "committed_seq": self.last_seq}, {}

    def _op_digest(self, header, arrays):
        """sha256 over every level's rows for ALL nodes (evicted rows
        rebuild through recompute-on-miss, so the digest is residency-
        independent) — the cluster-wide bitwise-equality probe."""
        st = self.engine.store
        ids = np.arange(st.n_nodes, dtype=np.int64)
        digests = {}
        for level in range(st.n_levels):
            h = hashlib.sha256()
            h.update(st.lookup(ids, level).tobytes())
            digests[f"level{level}"] = h.hexdigest()
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(st.bounds).tobytes())
        digests["bounds"] = h.hexdigest()
        return {"digests": digests, "store_version": int(st.version),
                "n_nodes": int(st.n_nodes)}, {}

    def _op_stats(self, header, arrays):
        return {"stats": _sanitize(self.session.stats())}, {}

    def _op_engine_stats(self, header, arrays):
        return {"stats": _sanitize(self.engine.stats()),
                "last_refresh": _sanitize(self.last_refresh_stats)}, {}

    def _op_memory_stats(self, header, arrays):
        return {"stats": _sanitize(self.engine.memory_stats())}, {}

    def _op_health(self, header, arrays):
        mon = self.engine.health
        summary = mon.summary() if mon is not None else {
            "n_alerts": 0, "alerts": [], "burn_rate": {},
            "wait_burn_rate": {}, "firing": []}
        summary["status"] = "alerting" if summary["firing"] else "ok"
        return {"health": _sanitize(summary)}, {}

    def _op_shutdown(self, header, arrays):
        return {"bye": True}, {}

    def _op__test_hang(self, header, arrays):
        """Deliberate main-thread wedge (never acks) — the target the
        heartbeat/wedge-detection harness tests shoot at."""
        self.hb.beat("op:_test_hang")
        time.sleep(float(header.get("seconds", 3600)))
        return {}, {}


def _sanitize(obj):
    from repro.obs.endpoint import json_sanitize
    return json_sanitize(obj)


def serve_loop(core: WorkerCore, sock: socket.socket) -> None:
    """Sequential accept loop: the router holds ONE persistent channel;
    probes (deployment readiness, tests) connect, ask, and disconnect.
    Single-threaded on purpose — the engine is single-threaded, and the
    main thread doing the work is what makes heartbeat stamps honest."""
    sock.settimeout(1.0)
    core.hb.beat("idle")
    while True:
        try:
            conn, _ = sock.accept()
        except socket.timeout:
            core.hb.beat("idle")
            continue
        # keep a timeout on the PERSISTENT router connection too: an
        # idle worker must wake to stamp heartbeats, or wedge detection
        # would false-positive on every healthy-but-quiet shard.  A
        # timeout while waiting for a frame to START is idleness; one
        # mid-frame (WorkerTimeout) means the sender died mid-send.
        conn.settimeout(1.0)
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            while True:
                try:
                    header, arrays = recv_msg(conn)
                except socket.timeout:
                    core.hb.beat("idle")
                    continue
                except Exception:
                    break               # client went away; next accept
                if header.get("op") == "shutdown":
                    send_msg(conn, {"ok": True, "bye": True})
                    core.hb.beat("shutdown")
                    return
                try:
                    resp, resp_arrays = core.dispatch(header, arrays)
                except Exception as exc:
                    resp = {"ok": False, "error": f"{exc}",
                            "traceback": traceback.format_exc()}
                    resp_arrays = {}
                    core.hb.beat("idle")
                try:
                    send_msg(conn, resp, resp_arrays)
                except Exception:
                    break
        finally:
            try:
                conn.close()
            except OSError:
                pass


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", required=True)
    ap.add_argument("--shard", type=int, required=True)
    ap.add_argument("--n-shards", type=int, required=True)
    ap.add_argument("--dir", required=True)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--heartbeat", default=None)
    args = ap.parse_args(argv)
    hb = Heartbeat(args.heartbeat)
    hb.beat("startup")
    from repro.api.config import DealConfig
    cfg = DealConfig.load(args.config)
    core = WorkerCore(cfg, args.shard, args.n_shards, args.dir,
                      heartbeat=hb)
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind((args.host, args.port))
    sock.listen(8)
    port = sock.getsockname()[1]
    # the port file doubles as the readiness marker: written AFTER the
    # world is built/restored/replayed and the socket listens
    port_path = os.path.join(args.dir, f"shard{args.shard}.port")
    tmp = port_path + ".tmp"
    with open(tmp, "w") as f:
        f.write(f"{port}\n")
    os.replace(tmp, port_path)
    try:
        serve_loop(core, sock)
    finally:
        sock.close()


if __name__ == "__main__":
    main()
