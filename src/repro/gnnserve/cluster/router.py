"""Router — the stateless-ish RPC front door of the cluster tier.

Queries route to owning shards along the store's existing 1-D
partitioning: ``bounds`` (the ``linspace`` node ranges the launch-time
world was sharded into) decide ownership, tail ids onboarded past the
launch extent clip to the LAST shard.  One client lookup whose ids span
several ranges scatter/gathers: per-owner sub-lookups fan out on a
thread pool (each worker serves its slice through its own
continuous-batching engine), the rows land back in client order.

Mutations never reach a worker one-by-one.  The router buffers them in
its own ``MutationLog`` — the same log clients already write through
``Session.apply_mutations()`` — and folds them with ONE ``commit``
broadcast carrying the whole drained batch and a per-shard monotonic
sequence number.  Workers WAL + apply + refresh the batch atomically,
which is what keeps every worker's world bitwise-equal: all shards fold
the same batches in the same order at the same epoch boundaries, and a
restarted worker replays exactly the committed batches it missed
(``worker.py``'s replay contract).  A commit that fails on only SOME
shards never drops the batch or reuses a seq: the router resyncs each
failed shard's seq from its status, requeues a batch that is durable
nowhere, and parks a partially-durable one in-flight until every shard
has folded it (``commit_pending``'s failure contract).

Stat merging keeps the single-process ``Session.stats()`` schema:
traffic counters SUM across shards, world-replicated values (versions,
epoch counters) assert equal and pass through, per-tenant attribution
sums reconcile exactly (each sub-query's segments sum against its own
e2e, so ``attributed_frac`` holds cluster-wide), and latency
percentiles take the worst shard.  ``RouterEndpoint`` serves the merged
tree plus an aggregated ``/healthz`` in the same shapes as
``obs.endpoint.TelemetryEndpoint``.
"""
from __future__ import annotations

import contextlib
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.gnnserve.cluster.protocol import (Channel, ProtocolError,
                                             WorkerError, WorkerTimeout)
from repro.gnnserve.mutations import MutationLog

# transport failures worth one reconnect-and-retry (every router op is
# safe to retry: lookups/stats are reads, commits are seq-idempotent);
# WorkerError is NOT here — the remote handler failed, retrying repeats it
_RETRYABLE = (ProtocolError, WorkerTimeout, OSError)


class _RWLock:
    """Shared/exclusive lock over the cluster epoch: lookups and stat
    scrapes read SHARED (they must all see one consistent epoch across
    shards), commits/full epochs write EXCLUSIVE.  Writers get priority
    so a commit is never starved by a stream of lookups."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    @contextlib.contextmanager
    def read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1
        try:
            yield
        finally:
            with self._cond:
                self._readers -= 1
                if not self._readers:
                    self._cond.notify_all()

    @contextlib.contextmanager
    def write(self):
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writer = True
        try:
            yield
        finally:
            with self._cond:
                self._writer = False
                self._cond.notify_all()


class Router:
    def __init__(self, channels: Sequence[Channel], bounds: np.ndarray,
                 dims: Sequence[int], *,
                 reconnect: Optional[Callable[[int], Channel]] = None):
        self.channels: List[Channel] = list(channels)
        self.n_shards = len(self.channels)
        self.bounds = np.asarray(bounds, np.int64)
        assert self.bounds.size == self.n_shards + 1
        self.dims = [int(d) for d in dims]
        self.n_nodes = int(self.bounds[-1])  # grows under onboarding
        self.reconnect = reconnect
        self.log = MutationLog()
        self.seq = [0] * self.n_shards
        self.n_lookups = 0
        self.n_subqueries = 0       # per-shard RPCs issued for lookups
        self.n_scatter = 0          # lookups that spanned >1 shard
        self.n_commits = 0
        self.n_retries = 0
        self._pool = ThreadPoolExecutor(
            max_workers=max(self.n_shards, 1),
            thread_name_prefix="deal-router")
        # epoch lock: lookups/scrapes shared, commits exclusive — a
        # lookup that scatters mid-commit would gather rows from
        # different epochs
        self._rw = _RWLock()
        # a sequenced op that is durable on SOME shard but unacked on
        # others parks here; it re-drives (same per-shard seq, workers
        # ack duplicates idempotently) before any new batch drains
        self._inflight: Optional[Dict] = None

    # -- routing --------------------------------------------------------
    def owner_of(self, ids: np.ndarray) -> np.ndarray:
        """Owning shard per id: the launch-time 1-D range it falls in;
        tail ids past the last bound belong to the LAST shard (tail
        partitions append past the main partitioning)."""
        return np.clip(
            np.searchsorted(self.bounds, np.asarray(ids, np.int64),
                            side="right") - 1,
            0, self.n_shards - 1)

    def _call(self, shard: int, op: str, arrays=None, **fields):
        """One RPC with a single reconnect-and-retry on transport
        failure (a killed worker restarts + replays before answering)."""
        try:
            return self.channels[shard].request(op, arrays, **fields)
        except _RETRYABLE:
            if self.reconnect is None:
                raise
            self.n_retries += 1
            self.channels[shard].close()
            self.channels[shard] = self.reconnect(shard)
            return self.channels[shard].request(op, arrays, **fields)

    def broadcast(self, op: str, arrays=None, **fields) -> List[Dict]:
        """The same op to every shard, in parallel; headers in shard
        order.  Holds the epoch read lock so a broadcast scrape never
        interleaves with a commit (per-shard stats stay one epoch)."""
        with self._rw.read():
            futs = [self._pool.submit(self._call, s, op, arrays,
                                      **fields)
                    for s in range(self.n_shards)]
            return [f.result()[0] for f in futs]

    # -- scatter/gather lookup ------------------------------------------
    def lookup(self, node_ids: np.ndarray, *, level: int = -1,
               tenant: str = "default", uid: int = 0):
        """Route ``node_ids`` to their owners, gather the rows back in
        client order.  Returns ``(rows, served_version)``."""
        ids = np.asarray(node_ids, np.int64)
        d = self.dims[level % len(self.dims)]
        with self._rw.read():
            if ids.size == 0:       # zero parts — nothing to scatter,
                                    # serve the current epoch directly
                st = self._call(0, "status")[0]
                return (np.empty((0, d), np.float32),
                        int(st["store_version"]))
            owners = self.owner_of(ids)
            out = np.empty((ids.size, d), np.float32)
            parts = [(int(s), np.flatnonzero(owners == s))
                     for s in np.unique(owners)]
            self.n_lookups += 1
            if len(parts) > 1:
                self.n_scatter += 1

            def _one(s, idx):
                resp, arrs = self._call(s, "lookup", {"ids": ids[idx]},
                                        level=level, tenant=tenant,
                                        uid=uid)
                return resp["served_version"], idx, arrs["rows"]

            # commits are excluded by the read lock, so one retry only
            # covers a shard that restarted/replayed mid-gather
            versions: set = set()
            for _ in range(2):
                futs = [self._pool.submit(_one, s, idx)
                        for s, idx in parts]
                versions = set()
                for f in futs:
                    version, idx, rows = f.result()
                    out[idx] = rows
                    versions.add(int(version))
                self.n_subqueries += len(parts)
                if len(versions) == 1:
                    return out, versions.pop()
            raise RuntimeError(
                f"shards served different epochs {sorted(versions)} "
                f"for one query — the commit barrier is broken")

    # -- mutation fold --------------------------------------------------
    def commit_pending(self) -> Dict:
        """Drain the router's mutation log and fold it on EVERY shard as
        one sequenced commit.  Returns shard 0's refresh stats (the
        worlds are replicas; their stats are equal).

        Failure contract: a batch is never silently dropped and a seq
        is never reused for a different batch.  If a shard's commit RPC
        fails, the router resyncs that shard's seq from its status; a
        batch that is positively durable NOWHERE requeues into the log,
        while one that landed on SOME shard parks in-flight and must
        complete everywhere (re-driven here, same seqs, duplicate acks)
        before the next batch drains."""
        with self._rw.write():
            self._drive_inflight()
            if not self.log.pending:
                return {}
            batch = self.log.drain()
            fields = {"edge_ops": [[k, int(s), int(d)]
                                   for k, s, d in batch.edge_ops],
                      "n_new_nodes": int(batch.n_new_nodes)}
            arrays = {"feat_ids": np.asarray(batch.feat_ids, np.int64),
                      "feat_rows": np.asarray(batch.feat_rows,
                                              np.float32)}
            if batch.new_node_rows is not None:
                arrays["new_node_rows"] = np.asarray(
                    batch.new_node_rows, np.float32)
            return self._sequenced("commit", fields, arrays,
                                   batch=batch)

    def full_epoch(self, n_shards: Optional[int] = None) -> Dict:
        """Sequenced re-partition epoch on every shard (pending
        mutations fold first, exactly like the single-process path)."""
        self.commit_pending()
        with self._rw.write():
            self._drive_inflight()
            return self._sequenced("full_epoch",
                                   {"n_shards": n_shards}, None)

    def _sequenced(self, op: str, fields: Dict, arrays,
                   batch=None) -> Dict:
        """One sequenced op to every shard, each shard's result handled
        INDIVIDUALLY — one failed future must not abandon the seq
        bookkeeping of the shards that committed.  Caller holds the
        write lock."""
        target = [s + 1 for s in self.seq]

        def _one(s):
            return self._call(s, op, arrays, seq=target[s], **fields)[0]

        futs = {s: self._pool.submit(_one, s)
                for s in range(self.n_shards)}
        resps: Dict[int, Dict] = {}
        failures: Dict[int, Exception] = {}
        for s, f in futs.items():
            try:
                resps[s] = f.result()
                self.seq[s] = int(resps[s]["seq"])
            except Exception as exc:     # noqa: BLE001 — per-shard
                failures[s] = exc
        if failures:
            # raises unless the resync shows every shard reached target
            self._resolve_failures(op, fields, arrays, target,
                                   failures, batch)
        if op == "commit":
            self.n_commits += 1
        versions = {int(r["store_version"]) for r in resps.values()}
        if len(versions) > 1:
            raise RuntimeError(
                f"{op} left shards at different epochs "
                f"{sorted(versions)}")
        if not resps:           # every ack was lost but resync proved
            return {}           # the op applied cluster-wide
        first = resps[min(resps)]
        self.n_nodes = int(first.get("n_nodes", self.n_nodes))
        return first.get("stats", {})

    def _resolve_failures(self, op: str, fields: Dict, arrays, target,
                          failures: Dict[int, Exception],
                          batch) -> None:
        """Resync each failed shard's seq from its status: an applied-
        but-unacked commit just advances our bookkeeping; anything
        still behind requeues (durable nowhere) or parks in-flight
        (durable somewhere — it MUST complete everywhere)."""
        unknown = []
        for s in failures:
            try:
                st = self._call(s, "status")[0]
            except Exception:            # noqa: BLE001 — state unknown
                unknown.append(s)
                continue
            if int(st["last_seq"]) >= target[s]:
                self.seq[s] = target[s]  # applied; the ack was lost
        behind = [s for s in range(self.n_shards)
                  if self.seq[s] < target[s]]
        if not behind:
            return
        cause = failures[behind[0]] if behind[0] in failures else \
            next(iter(failures.values()))
        applied_anywhere = any(self.seq[s] >= target[s]
                               for s in range(self.n_shards))
        if batch is not None and not applied_anywhere and not unknown:
            # positively durable nowhere: the mutations go back in the
            # log so the next commit re-drains them under fresh seqs
            self.log.requeue(batch)
            raise RuntimeError(
                f"{op} failed on shards {behind} before any shard "
                f"applied it; batch requeued "
                f"({self.log.pending} mutations pending)") from cause
        self._inflight = {"op": op, "fields": fields,
                          "arrays": arrays, "target": list(target)}
        raise RuntimeError(
            f"{op} is durable on some shards but failed on "
            f"{sorted(set(behind) | set(unknown))}; parked in-flight — "
            f"it will re-drive before the next commit") from cause

    def _drive_inflight(self) -> None:
        """Complete a parked sequenced op on every shard still behind
        its target seq (shards that already applied ack the duplicate
        idempotently).  Caller holds the write lock."""
        inf = self._inflight
        if inf is None:
            return
        op, target = inf["op"], inf["target"]
        behind = [s for s in range(self.n_shards)
                  if self.seq[s] < target[s]]
        failures: Dict[int, Exception] = {}

        def _one(s):
            return self._call(s, op, inf["arrays"], seq=target[s],
                              **inf["fields"])[0]

        futs = {s: self._pool.submit(_one, s) for s in behind}
        for s, f in futs.items():
            try:
                self.seq[s] = max(self.seq[s], int(f.result()["seq"]))
            except Exception as exc:     # noqa: BLE001 — per-shard
                failures[s] = exc
        still = [s for s in range(self.n_shards)
                 if self.seq[s] < target[s]]
        if still:
            raise RuntimeError(
                f"in-flight {op} still incomplete on shards "
                f"{still}") from next(iter(failures.values()), None)
        self._inflight = None
        if op == "commit":
            self.n_commits += 1

    # -- merged views ---------------------------------------------------
    def statuses(self) -> List[Dict]:
        return self.broadcast("status")

    def digests(self) -> List[Dict]:
        return self.broadcast("digest")

    def _client_counts(self, merged: Dict) -> Dict:
        """Workers count SUB-queries (one per shard a lookup touched);
        the client-facing count is the router's.  Keep both."""
        merged["n_served_subqueries"] = merged.get("n_served", 0)
        merged["n_served"] = self.n_lookups
        return merged

    def engine_stats(self) -> Dict:
        per_shard = [r["stats"] for r in self.broadcast("engine_stats")]
        return self._client_counts(
            merge_engine_stats(per_shard, pending=self.log.pending))

    def memory_stats(self) -> Dict:
        per_shard = [r["stats"] for r in self.broadcast("memory_stats")]
        return merge_memory_stats(per_shard)

    def session_stats(self) -> Dict:
        per_shard = [r["stats"] for r in self.broadcast("stats")]
        return self._client_counts(
            merge_session_stats(per_shard, pending=self.log.pending))

    def health(self) -> Dict:
        per_shard = [r["health"] for r in self.broadcast("health")]
        return merge_health(per_shard)

    def last_refresh_stats(self) -> Dict:
        return self.broadcast("engine_stats")[0]["last_refresh"]

    def router_stats(self) -> Dict:
        return {"n_shards": self.n_shards,
                "n_lookups": self.n_lookups,
                "n_subqueries": self.n_subqueries,
                "n_scatter": self.n_scatter,
                "n_commits": self.n_commits,
                "n_retries": self.n_retries,
                "seq": list(self.seq),
                "pending_mutations": int(self.log.pending),
                "inflight": (self._inflight["op"]
                             if self._inflight else None)}

    def shutdown(self) -> None:
        for s in range(self.n_shards):
            try:
                self.channels[s].request("shutdown")
            except Exception:
                pass                # already dead is fine at teardown
            self.channels[s].close()
        self._pool.shutdown(wait=False)


# ----------------------------------------------------------------------
# stat merging (single-process Session.stats() schema, cluster-wide)
# ----------------------------------------------------------------------

# engine/store counters that measure TRAFFIC (each worker saw only its
# slice — the cluster total is the sum)
_SUM_KEYS = frozenset((
    "n_served", "n_gather_steps", "store_n_lookups",
    "store_rows_gathered", "store_hits", "store_misses",
    "store_n_evictions", "store_rows_evicted", "store_n_recomputes",
    "store_n_recompute_spans", "store_rows_recomputed",
    "store_recompute_s", "store_resident_bytes"))

# per-tenant keys where the cluster-wide value is the WORST shard
# (percentiles/maxima/utilization), not the sum
_TENANT_MAX = ("_p50", "_p95", "_max", "quota_util", "view_version")
# per-tenant keys replicated by construction (same registry everywhere)
_TENANT_FIRST = ("staleness_slo",)


def _merge_tenants(per_shard: List[Dict]) -> Dict:
    out: Dict[str, Dict] = {}
    for shard in per_shard:
        for name, t in shard.items():
            if name not in out:
                out[name] = dict(t)
                continue
            m = out[name]
            for k, v in t.items():
                if any(k.endswith(s) or k == s for s in _TENANT_FIRST):
                    continue
                if any(k.endswith(s) or k == s for s in _TENANT_MAX):
                    m[k] = max(m[k], v)
                else:
                    m[k] = m.get(k, 0) + v
    return out


def merge_engine_stats(per_shard: List[Dict], *, pending: int = 0
                       ) -> Dict:
    """Merge per-shard ``EmbeddingServeEngine.stats()`` trees into one
    tree of the same shape."""
    assert per_shard
    versions = {int(s["store_version"]) for s in per_shard}
    if len(versions) > 1:           # a real error, not an assert: the
        raise RuntimeError(         # /stats endpoint must surface it
            f"shards report different store versions "  # under -O too
            f"{sorted(versions)}")
    out = dict(per_shard[0])        # replicated keys pass through
    for k in _SUM_KEYS:
        if k in out:
            out[k] = sum(s[k] for s in per_shard)
    hits = out.get("store_hits", 0)
    misses = out.get("store_misses", 0)
    out["store_hit_rate"] = hits / max(hits + misses, 1)
    if "store_budget_util" in out:  # worst shard (budgets may differ
        out["store_budget_util"] = max(    # under per-shard overrides)
            s["store_budget_util"] for s in per_shard)
    # workers hold no pending mutations between commits; the truth is
    # the router's buffer
    out["pending_mutations"] = int(pending)
    if "tenants" in out:
        out["tenants"] = _merge_tenants(
            [s.get("tenants", {}) for s in per_shard])
    return out


def merge_memory_stats(per_shard: List[Dict]) -> Dict:
    """Per-level residency summed across shards (the cluster's real
    footprint: every worker holds its own replica/budget)."""
    out: Dict[str, Dict] = {}
    for shard in per_shard:
        for level, m in shard.items():
            if level not in out:
                out[level] = dict(m)
            else:
                for k, v in m.items():
                    out[level][k] = out[level][k] + v
    for level, m in out.items():
        m["budget_util"] = (m["resident_rows"] / max(m["budget_rows"], 1)
                            if not level.endswith("level0") else 0.0)
    return out


def merge_attribution(per_shard: List[Dict]) -> Dict:
    """Per-tenant critical-path summaries merged across shards: counts
    and segment/e2e SUMS add (each sub-query's ledger closes against its
    own e2e, so the 5% ``attributed_frac`` reconciliation survives the
    merge), means re-derive, percentiles take the worst shard."""
    out: Dict[str, Dict] = {}
    for shard in per_shard:
        for name, t in shard.items():
            if name not in out:
                out[name] = json.loads(json.dumps(t))   # deep copy
                continue
            m = out[name]
            m["n_queries"] += t["n_queries"]
            e = m["e2e_ms"]
            e["sum"] += t["e2e_ms"]["sum"]
            for k in ("p50", "p95", "max"):
                e[k] = max(e[k], t["e2e_ms"][k])
            for s, v in t["segments_ms"].items():
                m["segments_ms"][s] += v
    for m in out.values():
        e2e = max(m["e2e_ms"]["sum"], 1e-12)
        m["e2e_ms"]["mean"] = m["e2e_ms"]["sum"] / max(m["n_queries"], 1)
        m["segments_frac"] = {s: v / e2e
                              for s, v in m["segments_ms"].items()}
        m["attributed_frac"] = sum(m["segments_ms"].values()) / e2e
    return out


def merge_health(per_shard: List[Dict]) -> Dict:
    """Aggregate per-shard ``HealthMonitor.summary()`` docs: alerts
    concatenate (tagged with their shard), burn rates take the worst
    shard, and the aggregate fires if ANY shard fires."""
    alerts, firing = [], set()
    burn: Dict[str, float] = {}
    wait_burn: Dict[str, float] = {}
    shards = []
    for i, h in enumerate(per_shard):
        shards.append({"shard": i,
                       "status": h.get("status",
                                       "alerting" if h.get("firing")
                                       else "ok"),
                       "n_alerts": h.get("n_alerts", 0),
                       "firing": list(h.get("firing", []))})
        for a in h.get("alerts", []):
            alerts.append({**a, "shard": i})
        for f in h.get("firing", []):
            firing.add(f"shard{i}:{f}")
        for k, v in h.get("burn_rate", {}).items():
            burn[k] = max(burn.get(k, 0.0), v)
        for k, v in h.get("wait_burn_rate", {}).items():
            wait_burn[k] = max(wait_burn.get(k, 0.0), v)
    out = {"n_alerts": len(alerts), "alerts": alerts,
           "burn_rate": burn, "wait_burn_rate": wait_burn,
           "firing": sorted(firing), "shards": shards}
    out["status"] = "alerting" if out["firing"] else "ok"
    return out


def merge_session_stats(per_shard: List[Dict], *, pending: int = 0
                        ) -> Dict:
    """Merge per-shard ``Session.stats()`` trees (the worker's full
    view) into the single-process schema."""
    assert per_shard
    engine_keys = set(per_shard[0]) - {"attribution", "health",
                                       "tenants", "metrics",
                                       "plan_cache", "refresh_cutover"}
    eng_in = []
    for s in per_shard:
        eng_in.append({k: s[k] for k in s
                       if k in engine_keys or k == "tenants"})
    out = merge_engine_stats(eng_in, pending=pending)
    # world-replicated subtrees pass through from shard 0; per-process
    # caches/metrics are process-local and stay per-shard
    if "refresh_cutover" in per_shard[0]:
        out["refresh_cutover"] = per_shard[0]["refresh_cutover"]
    if any("attribution" in s for s in per_shard):
        out["attribution"] = merge_attribution(
            [s["attribution"] for s in per_shard if "attribution" in s])
    if any("health" in s for s in per_shard):
        out["health"] = merge_health(
            [s["health"] for s in per_shard if "health" in s])
    return out


class RouterEndpoint:
    """HTTP front door over the merged cluster view — the shapes of
    ``obs.endpoint.TelemetryEndpoint`` with a ``shards`` breakdown.

    Routes (GET): ``/healthz`` (aggregated per-shard health; status is
    alerting if ANY shard alerts), ``/stats`` (merged Session.stats
    schema + ``cluster`` subtree), ``/shards`` (raw per-shard status).
    """

    def __init__(self, deployment, *, port: int = 0,
                 host: str = "127.0.0.1"):
        self.deployment = deployment
        self.host = host
        self.want_port = int(port)
        self.port: Optional[int] = None
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    def _health_doc(self) -> dict:
        from repro.obs.endpoint import json_sanitize
        return json_sanitize(self.deployment.router.health())

    def _stats_doc(self) -> dict:
        from repro.obs.endpoint import json_sanitize
        return json_sanitize(self.deployment.stats())

    def _shards_doc(self) -> dict:
        from repro.obs.endpoint import json_sanitize
        return json_sanitize(
            {"shards": self.deployment.router.statuses(),
             "router": self.deployment.router.router_stats()})

    def start(self) -> "RouterEndpoint":
        ep = self

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):
                pass

            def do_GET(self):
                try:
                    if self.path == "/healthz":
                        doc = ep._health_doc()
                    elif self.path == "/stats":
                        doc = ep._stats_doc()
                    elif self.path == "/shards":
                        doc = ep._shards_doc()
                    else:
                        self.send_error(404)
                        return
                    body = json.dumps(doc, sort_keys=True).encode()
                except Exception as exc:    # surface, don't wedge
                    self.send_error(500, str(exc))
                    return
                self.send_response(200)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

        self._server = ThreadingHTTPServer((self.host, self.want_port),
                                           _Handler)
        self._server.daemon_threads = True
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="deal-router-http",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


__all__ = ["Router", "RouterEndpoint", "merge_engine_stats",
           "merge_memory_stats", "merge_attribution", "merge_health",
           "merge_session_stats"]
