"""Multi-process serving tier: shard workers + an RPC front-door
router over the existing 1-D partitioning.

- ``protocol``   — length-prefixed JSON/binary framing (stdlib sockets)
- ``worker``     — ShardWorker: full-world process with WAL + replay
- ``router``     — scatter/gather routing, sequenced commits, stat
  merging, aggregated HTTP endpoint
- ``deployment`` — spawn/readiness/heartbeat-wedge lifecycle and the
  drive-compatible ``ClusterEngine`` facade

See ARCHITECTURE.md ("Cluster serving tier") for the process diagram
and the routing/replay invariants.
"""
from repro.gnnserve.cluster.deployment import (ClusterDeployment,
                                               ClusterEngine,
                                               WorkerWedged)
from repro.gnnserve.cluster.protocol import (Channel, ProtocolError,
                                             WorkerError, WorkerTimeout,
                                             recv_msg, send_msg)
from repro.gnnserve.cluster.router import (Router, RouterEndpoint,
                                           merge_attribution,
                                           merge_engine_stats,
                                           merge_health,
                                           merge_session_stats)
from repro.gnnserve.cluster.worker import Heartbeat, WorkerCore

__all__ = ["Channel", "ClusterDeployment", "ClusterEngine", "Heartbeat",
           "ProtocolError", "Router", "RouterEndpoint", "WorkerCore",
           "WorkerError", "WorkerTimeout", "WorkerWedged",
           "merge_attribution", "merge_engine_stats", "merge_health",
           "merge_session_stats", "recv_msg", "send_msg"]
