"""Versioned, partition-sharded embedding store with double-buffered swap.

The store holds the layerwise engine's output at every level: level 0 is
the raw feature matrix X, level l (1..L) is the INPUT of layer l+1 (i.e.
post-activation for inner layers) and level L is the final embedding —
exactly the tensors ``delta.DeltaReinference`` needs to restart compute
at any layer.  Rows are sharded into P contiguous partitions mirroring
``core.partition``'s 1-D node ranges, so a production deployment maps one
shard per host.

Writers never touch what readers see: ``begin_update`` opens a staging
overlay, ``write_rows`` copies-on-write only the shards it dirties, and
``commit`` swaps the dirty shards in atomically and bumps ``version``
(the double-buffered epoch swap).  ``lookup`` always reads the committed
front; ``lookup_staged`` reads through the overlay (read-your-writes for
the delta engine mid-refresh).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


class StoreSnapshot:
    """Immutable view of one committed epoch.  Shard arrays are shared by
    pointer with the store's front buffer at snapshot time; commits swap
    pointers (never write in place), so reads through a snapshot keep
    seeing one consistent epoch for free."""

    def __init__(self, store: "EmbeddingStore"):
        self._front = [list(shards) for shards in store._front]
        self.bounds = store.bounds
        self.version = store.version
        self._store = store

    def lookup(self, ids: np.ndarray, level: int = -1) -> np.ndarray:
        level = level % len(self._front)
        self._store.n_lookups += 1
        self._store.rows_gathered += int(np.asarray(ids).size)
        return _gather_rows(self._front[level], self.bounds, ids)


def _gather_rows(shards: List[np.ndarray], bounds: np.ndarray,
                 ids: np.ndarray) -> np.ndarray:
    ids = np.asarray(ids, np.int64)
    assert ids.size == 0 or (ids.min() >= 0 and ids.max() < bounds[-1]), \
        "node id out of range"      # a negative id would silently wrap
    out = np.empty((ids.size, shards[0].shape[1]), np.float32)
    owner = np.searchsorted(bounds, ids, side="right") - 1
    for s in np.unique(owner):
        sel = owner == s
        out[sel] = shards[s][ids[sel] - bounds[s]]
    return out


class EmbeddingStore:
    def __init__(self, levels: Sequence[np.ndarray], n_shards: int = 4):
        n = levels[0].shape[0]
        assert all(h.shape[0] == n for h in levels), "levels must cover all nodes"
        self.n_nodes = n
        self.n_shards = n_shards
        self.bounds = np.linspace(0, n, n_shards + 1).astype(np.int64)
        # front[level][shard] -> (rows, D_level) float32
        self._front: List[List[np.ndarray]] = [
            [np.ascontiguousarray(h[self.bounds[s]:self.bounds[s + 1]],
                                  dtype=np.float32)
             for s in range(n_shards)]
            for h in levels]
        # staging overlay: {(level, shard): array}; None when no update open
        self._staged: Optional[Dict[tuple, np.ndarray]] = None
        self.version = 0
        self.n_lookups = 0
        self.rows_gathered = 0
        self.n_swaps = 0

    @property
    def n_levels(self) -> int:
        return len(self._front)

    def level_dim(self, level: int) -> int:
        return self._front[level][0].shape[1]

    # -- read path ------------------------------------------------------
    def _owner(self, ids: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.bounds, ids, side="right") - 1

    def _gather(self, ids: np.ndarray, level: int, staged: bool) -> np.ndarray:
        shards = self._front[level]
        if staged and self._staged is not None:
            shards = [self._staged.get((level, s), shards[s])
                      for s in range(self.n_shards)]
        return _gather_rows(shards, self.bounds, ids)

    def lookup(self, ids: np.ndarray, level: int = -1) -> np.ndarray:
        """Committed (front-buffer) rows; what the serve engine reads."""
        level = level % self.n_levels
        self.n_lookups += 1
        self.rows_gathered += int(np.asarray(ids).size)
        return self._gather(ids, level, staged=False)

    def lookup_staged(self, ids: np.ndarray, level: int = -1) -> np.ndarray:
        """Read-through the open staging overlay (delta refresh only)."""
        return self._gather(ids, level % self.n_levels, staged=True)

    def snapshot(self) -> StoreSnapshot:
        """Pin the current committed epoch (cheap: pointer copies)."""
        return StoreSnapshot(self)

    # -- write path -----------------------------------------------------
    def begin_update(self) -> None:
        assert self._staged is None, "update already open"
        self._staged = {}

    def write_rows(self, level: int, ids: np.ndarray, rows: np.ndarray) -> None:
        assert self._staged is not None, "begin_update first"
        level = level % self.n_levels
        ids = np.asarray(ids, np.int64)
        owner = self._owner(ids)
        for s in np.unique(owner):
            key = (level, int(s))
            if key not in self._staged:          # copy-on-write per shard
                self._staged[key] = self._front[level][s].copy()
            sel = owner == s
            self._staged[key][ids[sel] - self.bounds[s]] = rows[sel]

    def commit(self) -> int:
        """Swap dirtied shards into the front buffer; readers see the new
        epoch atomically (per-shard pointer swap, no row copies)."""
        assert self._staged is not None, "no update open"
        for (level, s), shard in self._staged.items():
            self._front[level][s] = shard
        self._staged = None
        self.version += 1
        self.n_swaps += 1
        return self.version

    def abort(self) -> None:
        self._staged = None

    # -- diagnostics ----------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {"version": self.version, "n_lookups": self.n_lookups,
                "rows_gathered": self.rows_gathered, "n_swaps": self.n_swaps,
                "n_shards": self.n_shards, "n_levels": self.n_levels}


def store_from_inference(X: np.ndarray, level_outputs: Sequence[np.ndarray],
                         n_shards: int = 4) -> EmbeddingStore:
    """Build the store from a full epoch: X plus each layer's output as
    consumed by the next layer (see DeltaReinference.full_levels)."""
    return EmbeddingStore([np.asarray(X, np.float32)]
                          + [np.asarray(h, np.float32)
                             for h in level_outputs], n_shards=n_shards)
