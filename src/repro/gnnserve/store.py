"""Versioned, partition-sharded embedding store with double-buffered swap
and a per-level memory budget (heat/LRU shard eviction, recompute-on-miss).

The store holds the layerwise engine's output at every level: level 0 is
the raw feature matrix X, level l (1..L) is the INPUT of layer l+1 (i.e.
post-activation for inner layers) and level L is the final embedding —
exactly the tensors ``delta.DeltaReinference`` needs to restart compute
at any layer.  Rows are sharded into P contiguous partitions mirroring
``core.partition``'s 1-D node ranges, so a production deployment maps one
shard per host.

Writers never touch what readers see: ``begin_update`` opens a staging
overlay, ``write_rows`` copies-on-write only the shards it dirties, and
``commit`` swaps the dirty shards in atomically and bumps ``version``
(the double-buffered epoch swap).  ``lookup`` always reads the committed
front; ``lookup_staged`` reads through the overlay (read-your-writes for
the delta engine mid-refresh).

Memory model (the production constraint every full-graph system hits):
``budget_rows`` caps the resident rows of EVERY evictable level (1..L;
level 0 — the features — is pinned, it is the ground truth nothing can
rebuild).  Each (level, shard) keeps a row-level residency bitmap next
to its array; ``evict`` drops a whole shard's array and replaces the
bitmap with a fresh all-False one (snapshots holding the old array+bitmap
pair keep serving it — eviction never writes in place).  A ``lookup``
that touches non-resident rows no longer asserts: it routes the exact
missing row ids through the ``recompute`` hook (``delta.RecomputeOnMiss``
— level-l rows rebuilt from the lowest resident level through the bound
executor, bitwise-equal to a never-evicted store), re-admits them into
the shard, and charges the budget.  Victims are chosen by ``evict_policy``
— a REGISTERED policy name (``api.registry.EVICT_POLICIES``; built-ins
``"heat"``, exponentially-decayed access mass, and ``"lru"``, last-touch
tick, register themselves below), as is ``admission``.  Budget enforcement runs only at the END of a top-level gather /
commit, never mid-recursion, so a recompute can't evict rows it is about
to read.  Admission is scan-resistant by default (``admission=
"probation"``): rows admitted via recompute-on-miss contribute NO heat
until they are touched a second time, so a one-shot full scan cannot
displace the hot working set (``admission="full"`` restores the old
count-every-touch behavior).

Snapshot-vs-eviction ordering: ``pinned_snapshot(ids, level)`` admits any
missing rows FIRST (with enforcement suppressed), captures the shard
array+bitmap pointers, and only then lets the budget evict — so a
mid-query eviction (or a later epoch commit) can never tear a pinned
response.  A plain ``snapshot()`` pins whatever is resident; reading rows
it never pinned falls back to the store while the epoch still matches and
raises ``SnapshotMiss`` after the epoch has moved on (recompute against a
mutated graph could not reproduce the old epoch).

Incremental node onboarding (``onboarding="tail"``): ``append_tail``
adds brand-new nodes as ONE extra shard past the main 1-D partitioning
(features resident, upper levels written by the onboarding delta
refresh); the tail rides budgets/eviction like any shard until
``EmbeddingServeEngine.full_epoch`` folds it back in.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.api.registry import (ADMISSIONS, EVICT_POLICIES,
                                register_admission, register_evict_policy)


# ----------------------------------------------------------------------
# registered eviction / admission policies ("heat"/"lru" and
# "probation"/"full" are defaults, not special cases — third parties add
# names via api.registry and select them from StoreSpec)
# ----------------------------------------------------------------------

@register_evict_policy("heat")
def _heat_policy(store: "EmbeddingStore", level: int):
    """Evict the shard with the least exponentially-decayed access mass
    (ties: least-recent, then lowest id)."""
    return lambda s: (store._heat_now(level, s),
                      int(store._last[level, s]), s)


@register_evict_policy("lru")
def _lru_policy(store: "EmbeddingStore", level: int):
    """Evict the least-recently-touched shard."""
    return lambda s: (int(store._last[level, s]), s)


@register_admission("probation")
def _probation_admission(local: np.ndarray,
                         admitted: Optional[np.ndarray]) -> int:
    """Scan resistance: recompute-admitted rows are on probation — the
    admitting touch adds NO heat (any later touch is a hit and counts in
    full), so a one-shot scan leaves its shards stone-cold and the hot
    working set survives the eviction round."""
    if admitted is None or admitted.size == 0:
        return local.size
    return int((~np.isin(local, admitted)).sum())


@register_admission("full")
def _full_admission(local: np.ndarray,
                    admitted: Optional[np.ndarray]) -> int:
    """Count every touch, including the admitting one (the pre-probation
    behavior; scannable)."""
    return local.size


class EvictedRowMiss(RuntimeError):
    """A gather touched evicted rows and no ``recompute`` hook is bound."""


class SnapshotMiss(RuntimeError):
    """A snapshot read touched rows it never pinned, after the store's
    epoch moved on — the old epoch is not reconstructible."""


class StoreSnapshot:
    """Immutable view of one committed epoch.  Shard arrays AND residency
    bitmaps are shared by pointer with the store's front buffer at
    snapshot time; commits and evictions swap pointers (never write in
    place), so reads through a snapshot keep seeing one consistent epoch
    for free.  Rows admitted into a pinned shard later are same-epoch by
    construction (dirty rows always land in swapped shards), so the
    snapshot only ever GAINS rows."""

    def __init__(self, store: "EmbeddingStore"):
        self._front = [list(shards) for shards in store._front]
        self._mask = [list(masks) for masks in store._mask]
        self.bounds = store.bounds
        self.version = store.version
        self._store = store

    def lookup(self, ids: np.ndarray, level: int = -1) -> np.ndarray:
        level = level % len(self._front)
        ids = np.asarray(ids, np.int64)
        st = self._store
        st.n_lookups += 1
        st.rows_gathered += int(ids.size)
        _check_ids(ids, self.bounds)
        out = np.empty((ids.size, st.level_dim(level)), np.float32)
        missing = np.zeros(ids.size, bool)
        owner = np.searchsorted(self.bounds, ids, side="right") - 1
        for s in np.unique(owner):
            sel = owner == s
            local = ids[sel] - self.bounds[s]
            data, mask = self._front[level][s], self._mask[level][s]
            if data is None:
                missing |= sel
                continue
            have = mask[local]
            if have.all():
                out[sel] = data[local]
            else:
                got = np.zeros((local.size, out.shape[1]), np.float32)
                got[have] = data[local[have]]
                out[sel] = got
                miss_sel = sel.copy()
                miss_sel[sel] = ~have
                missing |= miss_sel
        if missing.any():
            if self.version != st.version:
                raise SnapshotMiss(
                    "snapshot read touched rows that were never pinned and "
                    "the store's epoch has advanced; pin the query's rows "
                    "with pinned_snapshot(ids, level) before the commit")
            # same epoch: serve the stragglers through the store (admits
            # them via recompute-on-miss and charges the budget)
            out[missing] = st._gather(ids[missing], level, staged=False)
        return out


def _check_ids(ids: np.ndarray, bounds: np.ndarray) -> None:
    assert ids.size == 0 or (ids.min() >= 0 and ids.max() < bounds[-1]), \
        "node id out of range"      # a negative id would silently wrap


class EmbeddingStore:
    def __init__(self, levels: Sequence[np.ndarray], n_shards: int = 4,
                 *, budget_rows: Optional[int] = None,
                 evict_policy: str = "heat", heat_decay: float = 0.98,
                 admission: str = "probation", onboarding: str = "none"):
        n = levels[0].shape[0]
        assert all(h.shape[0] == n for h in levels), "levels must cover all nodes"
        # eager registry resolution: a typo'd policy name fails at build
        # time with every registered name in the error
        self._victim_policy = EVICT_POLICIES.get(evict_policy)
        self._admit_policy = ADMISSIONS.get(admission)
        assert onboarding in ("none", "tail"), onboarding
        assert budget_rows is None or budget_rows >= 0
        self.n_nodes = n
        self.n_shards = n_shards
        self.bounds = np.linspace(0, n, n_shards + 1).astype(np.int64)
        self._shard_rows = np.diff(self.bounds)
        self._dims = [int(h.shape[1]) for h in levels]
        # front[level][shard] -> (rows, D_level) float32 | None (evicted)
        self._front: List[List[Optional[np.ndarray]]] = [
            [np.ascontiguousarray(h[self.bounds[s]:self.bounds[s + 1]],
                                  dtype=np.float32)
             for s in range(n_shards)]
            for h in levels]
        # residency bitmap per (level, shard); evict swaps in a NEW
        # all-False array so pinned snapshots keep the old pair
        self._mask: List[List[np.ndarray]] = [
            [np.ones(int(self._shard_rows[s]), bool)
             for s in range(n_shards)]
            for _ in levels]
        # bitmap popcounts, maintained incrementally: budget enforcement
        # runs after every top-level gather and must not rescan
        # O(n_levels * n_nodes) bitmap bytes each time
        self._res = np.tile(self._shard_rows, (len(levels), 1))
        # staging overlay: {(level, shard): array (+ bitmap)}; None when
        # no update open
        self._staged: Optional[Dict[tuple, np.ndarray]] = None
        self._staged_mask: Optional[Dict[tuple, np.ndarray]] = None
        # memory budget + shard heat (eviction policy inputs)
        self.budget_rows = budget_rows
        self.evict_policy = evict_policy
        self.heat_decay = heat_decay
        self.admission = admission
        self.onboarding = onboarding
        self.n_tail_shards = 0      # appended-but-not-yet-folded shards
        self._heat = np.zeros((len(levels), n_shards))
        self._last = np.zeros((len(levels), n_shards), np.int64)
        self._tick = 0
        self._gather_depth = 0
        self._recompute_depth = 0
        # recompute-on-miss hook: (level, sorted-unique global ids,
        # staged) -> (len(ids), D_level) rows, bitwise-equal to what a
        # never-evicted store would hold for that view
        self.recompute: Optional[Callable] = None
        self.version = 0
        self.n_lookups = 0
        self.rows_gathered = 0
        self.n_swaps = 0
        self.hits = 0               # rows served from resident shards
        self.misses = 0             # rows that had to be recomputed
        self.n_evictions = 0        # shards dropped
        self.rows_evicted = 0
        self.n_recomputes = 0       # hook invocations (nested included)
        self.n_recompute_spans = 0  # outermost invocations (timed ones)
        self.rows_recomputed = 0
        self.recompute_s = 0.0      # cumulative outermost wall time
        self._enforce_budget()      # a tight budget evicts at build time

    @property
    def n_levels(self) -> int:
        return len(self._front)

    def level_dim(self, level: int) -> int:
        return self._dims[level]

    # -- read path ------------------------------------------------------
    def _owner(self, ids: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.bounds, ids, side="right") - 1

    def _view_shard(self, level: int, s: int, staged: bool):
        key = (level, s)
        if staged and self._staged is not None and key in self._staged:
            return self._staged[key], self._staged_mask[key]
        return self._front[level][s], self._mask[level][s]

    def _materialize_staged(self, level: int, s: int):
        """Copy-on-write a shard into the open overlay (write or
        staged-miss admission; the front must stay untouched so an abort
        is a pure pointer drop)."""
        key = (level, s)
        if key not in self._staged:
            data = self._front[level][s]
            self._staged[key] = (data.copy() if data is not None else
                                 np.zeros((int(self._shard_rows[s]),
                                           self._dims[level]), np.float32))
            self._staged_mask[key] = self._mask[level][s].copy()
        return self._staged[key], self._staged_mask[key]

    def _ensure(self, level: int, s: int, local: np.ndarray, staged: bool):
        """Make ``local`` rows of (level, shard) resident in the given
        view, recomputing misses through the hook.  Returns
        (data, mask, admitted-local-ids-or-None)."""
        data, mask = self._view_shard(level, s, staged)
        have = mask[local] if data is not None else np.zeros(local.size, bool)
        n_hit = int(have.sum())
        self.hits += n_hit
        self.misses += local.size - n_hit
        if obs.enabled():
            obs.add("store.hits", n_hit)
            obs.add("store.misses", local.size - n_hit)
        if n_hit == local.size:
            return data, mask, None
        need = np.unique(local[~have])
        if self.recompute is None:
            raise EvictedRowMiss(
                f"level {level} shard {s}: {need.size} rows not resident "
                "and no recompute hook bound (store.recompute — see "
                "gnnserve.delta.RecomputeOnMiss)")
        assert level > 0, "level 0 (features) must never be evicted"
        t0 = time.perf_counter()
        self._recompute_depth += 1
        try:
            with obs.span("store.recompute") as rsp:
                rows = np.asarray(
                    self.recompute(level, need + self.bounds[s], staged),
                    np.float32)
                if rsp:
                    rsp.set(level=level, shard=s, rows=int(need.size))
        finally:
            self._recompute_depth -= 1
        if self._recompute_depth == 0:
            # outermost calls only: nested recursion (lower-level inputs
            # rebuilt on the way) is already inside this wall time —
            # per-recompute latency is recompute_s / n_recompute_spans
            self.recompute_s += time.perf_counter() - t0
            self.n_recompute_spans += 1
        self.n_recomputes += 1
        self.rows_recomputed += int(need.size)
        if obs.enabled():
            obs.add("store.recomputes")
            obs.add("store.rows_recomputed", need.size)
        if staged and self._staged is not None:
            # an overlay read must never leak in-progress values into the
            # committed front (an abort would leave them behind) — admit
            # into a copy-on-write staged shard instead
            data, mask = self._materialize_staged(level, s)
        else:
            if data is None:
                data = np.zeros((int(self._shard_rows[s]),
                                 self._dims[level]), np.float32)
                self._front[level][s] = data
            self._res[level, s] += need.size        # front admission
        data[need] = rows
        mask[need] = True
        return data, mask, need

    def _gather(self, ids: np.ndarray, level: int,
                staged: bool) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        _check_ids(ids, self.bounds)
        self._tick += 1
        out = np.empty((ids.size, self._dims[level]), np.float32)
        owner = self._owner(ids)
        self._gather_depth += 1
        try:
            with obs.span("store.gather") as gsp:
                for s in np.unique(owner):
                    sel = owner == s
                    local = ids[sel] - self.bounds[s]
                    data, mask, admitted = self._ensure(level, int(s),
                                                        local, staged)
                    out[sel] = data[local]
                    # the registered admission policy decides how much
                    # heat this touch contributes (_probation_admission)
                    w = (self._admit_policy(local, admitted)
                         if level > 0 and not staged else local.size)
                    self._heat[level, s] = self._heat_now(level, int(s)) + w
                    self._last[level, s] = self._tick
                if gsp:
                    gsp.set(rows=int(ids.size), level=level,
                            staged=staged)
        finally:
            self._gather_depth -= 1
        if self._gather_depth == 0:
            self._enforce_budget()
        return out

    def lookup(self, ids: np.ndarray, level: int = -1) -> np.ndarray:
        """Committed (front-buffer) rows; what the serve engine reads.
        Non-resident rows are rebuilt through the recompute hook."""
        level = level % self.n_levels
        self.n_lookups += 1
        self.rows_gathered += int(np.asarray(ids).size)
        return self._gather(ids, level, staged=False)

    def lookup_staged(self, ids: np.ndarray, level: int = -1) -> np.ndarray:
        """Read-through the open staging overlay (delta refresh only).
        Misses are admitted into copy-on-write staged shards, never the
        front — an abort discards them with the rest of the overlay."""
        return self._gather(ids, level % self.n_levels, staged=True)

    def snapshot(self) -> StoreSnapshot:
        """Pin the current committed epoch (cheap: pointer copies)."""
        return StoreSnapshot(self)

    def ensure_resident(self, ids: np.ndarray, level: int = -1) -> None:
        """Admit any non-resident rows of ``ids`` (recompute-on-miss)."""
        self._gather(np.asarray(ids, np.int64), level % self.n_levels,
                     staged=False)

    def pinned_snapshot(self, ids: np.ndarray, level: int = -1
                        ) -> StoreSnapshot:
        """Admit ``ids`` at ``level`` and pin the epoch in one step:
        budget enforcement is suppressed until AFTER the snapshot captures
        the shard pointers, so an eviction racing the pin can never drop
        rows the snapshot is about to serve."""
        self._gather_depth += 1
        try:
            self._gather(np.asarray(ids, np.int64),
                         level % self.n_levels, staged=False)
            snap = StoreSnapshot(self)
        finally:
            self._gather_depth -= 1
        self._enforce_budget()
        return snap

    # -- incremental node onboarding (tail partition) -------------------
    def append_tail(self, n_new: int,
                    feat_rows: Optional[np.ndarray] = None) -> np.ndarray:
        """Append a TAIL PARTITION of ``n_new`` brand-new nodes: one
        extra shard covering [n, n + n_new), so node additions serve via
        delta refresh instead of forcing an offline re-partition.

        Level 0 (features) becomes resident immediately — ``feat_rows``
        or zeros.  Levels 1..L start NON-resident: the onboarding delta
        refresh (which always carries the new ids in its resampled set)
        writes them through the staging overlay before any read, layer
        by layer.  The tail then behaves like any other shard — budget
        enforcement, eviction, recompute-on-miss — until a full epoch
        folds it into the main 1-D partitioning
        (``EmbeddingServeEngine.full_epoch``).  Returns the new ids."""
        assert self._staged is None, \
            "no update may be open across a tail append"
        assert n_new > 0
        # validate the features BEFORE touching any store state: a bad
        # shape must fail with the store untouched (the engine's
        # rollback assumes append_tail is all-or-nothing)
        feat = np.zeros((n_new, self._dims[0]), np.float32)
        if feat_rows is not None:
            feat_rows = np.asarray(feat_rows, np.float32)
            assert feat_rows.shape == (n_new, self._dims[0]), \
                (f"tail features must be ({n_new}, {self._dims[0]}), "
                 f"got {feat_rows.shape}")
            feat[:] = feat_rows
        n0 = self.n_nodes
        self.n_nodes = n0 + int(n_new)
        self.bounds = np.concatenate(
            [self.bounds, [self.n_nodes]]).astype(np.int64)
        self._shard_rows = np.diff(self.bounds)
        self._front[0].append(feat)
        self._mask[0].append(np.ones(n_new, bool))
        for level in range(1, self.n_levels):
            self._front[level].append(None)
            self._mask[level].append(np.zeros(n_new, bool))
        res_col = np.zeros((self.n_levels, 1), self._res.dtype)
        res_col[0, 0] = n_new
        self._res = np.concatenate([self._res, res_col], axis=1)
        self._heat = np.concatenate(
            [self._heat, np.zeros((self.n_levels, 1))], axis=1)
        self._last = np.concatenate(
            [self._last, np.full((self.n_levels, 1), self._tick,
                                 np.int64)], axis=1)
        self.n_shards += 1
        self.n_tail_shards += 1
        return np.arange(n0, self.n_nodes, dtype=np.int64)

    def pop_tail(self, n_new: int) -> None:
        """Inverse of ``append_tail`` — the engine's rollback when the
        onboarding refresh fails.  Only valid while the appended tail is
        still the LAST shard and no update is open."""
        assert self._staged is None, "abort the open update first"
        assert self.n_tail_shards > 0 and self._shard_rows[-1] == n_new, \
            "pop_tail must exactly undo the last append_tail"
        self.n_nodes -= int(n_new)
        self.bounds = self.bounds[:-1]
        self._shard_rows = np.diff(self.bounds)
        for level in range(self.n_levels):
            self._front[level].pop()
            self._mask[level].pop()
        self._res = self._res[:, :-1]
        self._heat = self._heat[:, :-1]
        self._last = self._last[:, :-1]
        self.n_shards -= 1
        self.n_tail_shards -= 1

    # -- eviction -------------------------------------------------------
    def _heat_now(self, level: int, s: int) -> float:
        return float(self._heat[level, s]
                     * self.heat_decay ** (self._tick - self._last[level, s]))

    def resident_rows(self, level: int) -> int:
        return int(self._res[level].sum())

    def evict(self, level: int, s: int) -> int:
        """Drop one shard's array; the residency bitmap is REPLACED with
        a fresh all-False one (snapshots keep the old array+bitmap pair).
        Level 0 is pinned.  Returns the number of rows evicted."""
        level = level % self.n_levels
        assert level > 0, "level 0 (features) is pinned"
        if self._front[level][s] is None:
            return 0
        n = int(self._res[level, s])
        with obs.span("store.evict") as sp:
            self._front[level][s] = None
            self._mask[level][s] = np.zeros(int(self._shard_rows[s]),
                                            bool)
            self._res[level, s] = 0
            self._heat[level, s] = 0.0
            if sp:
                sp.set(level=level, shard=s, rows=n)
                obs.add("store.evictions")
                obs.add("store.rows_evicted", n)
        self.n_evictions += 1
        self.rows_evicted += n
        return n

    def _victim_key(self, level: int):
        return self._victim_policy(self, level)

    def _enforce_budget(self) -> None:
        if self.budget_rows is None:
            return
        for level in range(1, self.n_levels):
            total = int(self._res[level].sum())
            while total > self.budget_rows:
                cand = [s for s in range(self.n_shards)
                        if self._res[level, s] > 0]
                victim = min(cand, key=self._victim_key(level))
                total -= self.evict(level, victim)

    # -- write path -----------------------------------------------------
    def begin_update(self) -> None:
        assert self._staged is None, "update already open"
        self._staged = {}
        self._staged_mask = {}

    def write_rows(self, level: int, ids: np.ndarray, rows: np.ndarray) -> None:
        assert self._staged is not None, "begin_update first"
        level = level % self.n_levels
        ids = np.asarray(ids, np.int64)
        owner = self._owner(ids)
        for s in np.unique(owner):
            data, mask = self._materialize_staged(level, int(s))
            sel = owner == s
            local = ids[sel] - self.bounds[s]
            data[local] = rows[sel]
            mask[local] = True

    def commit(self) -> int:
        """Swap dirtied shards into the front buffer; readers see the new
        epoch atomically (per-shard pointer swap, no row copies)."""
        assert self._staged is not None, "no update open"
        for (level, s), shard in self._staged.items():
            self._front[level][s] = shard
            self._mask[level][s] = self._staged_mask[(level, s)]
            # popcount only the swapped (dirty) shards
            self._res[level, s] = int(self._mask[level][s].sum())
        self._staged = None
        self._staged_mask = None
        self.version += 1
        self.n_swaps += 1
        self._enforce_budget()
        return self.version

    def abort(self) -> None:
        self._staged = None
        self._staged_mask = None

    # -- diagnostics ----------------------------------------------------
    def memory_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-level residency: rows resident, bytes resident, and budget
        utilization (1.0 == at budget; level 0 reports util 0, pinned)."""
        out = {}
        for level in range(self.n_levels):
            res = self.resident_rows(level)
            cap = (self.budget_rows if (self.budget_rows is not None
                                        and level > 0) else self.n_nodes)
            out[f"level{level}"] = {
                "resident_rows": res,
                "total_rows": self.n_nodes,
                "resident_bytes": res * self._dims[level] * 4,
                "budget_rows": cap,
                "budget_util": res / max(cap, 1) if level > 0 else 0.0,
            }
        return out

    def stats(self) -> Dict[str, float]:
        mem = self.memory_stats()
        evictable = [mem[f"level{l}"] for l in range(1, self.n_levels)]
        resident_bytes = sum(v["resident_bytes"] for v in mem.values())
        budget_total = sum(v["budget_rows"] for v in evictable)
        resident_ev = sum(v["resident_rows"] for v in evictable)
        return {"version": self.version, "n_lookups": self.n_lookups,
                "rows_gathered": self.rows_gathered, "n_swaps": self.n_swaps,
                "n_shards": self.n_shards, "n_levels": self.n_levels,
                "n_tail_shards": self.n_tail_shards,
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / max(self.hits + self.misses, 1),
                "n_evictions": self.n_evictions,
                "rows_evicted": self.rows_evicted,
                "n_recomputes": self.n_recomputes,
                "n_recompute_spans": self.n_recompute_spans,
                "rows_recomputed": self.rows_recomputed,
                "recompute_s": self.recompute_s,
                "resident_bytes": resident_bytes,
                "budget_rows": (-1 if self.budget_rows is None
                                else self.budget_rows),
                "budget_util": resident_ev / max(budget_total, 1)}

    # -- checkpoint -----------------------------------------------------
    def state_arrays(self, prefix: str = "") -> Dict[str, np.ndarray]:
        """The committed front as a flat ``{name: array}`` dict (npz-
        ready): bounds, per-(level, shard) data + residency bitmaps
        (evicted shards simply have no data entry), and the heat/LRU
        policy state, plus one JSON metadata blob.  No update may be
        open — the staging overlay is a writer-private transient."""
        assert self._staged is None, \
            "commit or abort the open update before checkpointing"
        meta = {"version": self.version, "n_nodes": int(self.n_nodes),
                "n_shards": self.n_shards,
                "n_tail_shards": self.n_tail_shards,
                "dims": self._dims,
                "budget_rows": (-1 if self.budget_rows is None
                                else int(self.budget_rows)),
                "evict_policy": self.evict_policy,
                "heat_decay": self.heat_decay,
                "admission": self.admission,
                "onboarding": self.onboarding,
                "tick": int(self._tick)}
        out = {f"{prefix}meta": np.frombuffer(
                   json.dumps(meta, sort_keys=True).encode(), np.uint8),
               f"{prefix}bounds": self.bounds,
               f"{prefix}heat": self._heat,
               f"{prefix}last": self._last}
        for level in range(self.n_levels):
            for s in range(self.n_shards):
                data = self._front[level][s]
                if data is not None:
                    out[f"{prefix}d{level}_{s}"] = data
                out[f"{prefix}m{level}_{s}"] = self._mask[level][s]
        return out

    @classmethod
    def from_state_arrays(cls, arrays, prefix: str = ""
                          ) -> "EmbeddingStore":
        """Inverse of ``state_arrays``: rebuild the store object field
        by field — residency (which shards are evicted, which rows are
        admitted) restores exactly, so a restored store serves bitwise
        the same rows as the one that was dumped.  The recompute hook is
        not serialized; re-attach it (``delta.attach_recompute``) on
        budgeted stores."""
        meta = json.loads(bytes(np.asarray(arrays[f"{prefix}meta"],
                                           np.uint8)).decode())
        st = cls.__new__(cls)
        st._victim_policy = EVICT_POLICIES.get(meta["evict_policy"])
        st._admit_policy = ADMISSIONS.get(meta["admission"])
        st.n_nodes = int(meta["n_nodes"])
        st.n_shards = int(meta["n_shards"])
        st.n_tail_shards = int(meta["n_tail_shards"])
        st.bounds = np.asarray(arrays[f"{prefix}bounds"], np.int64).copy()
        st._shard_rows = np.diff(st.bounds)
        st._dims = [int(d) for d in meta["dims"]]
        st._front = []
        st._mask = []
        for level in range(len(st._dims)):
            row_d, row_m = [], []
            for s in range(st.n_shards):
                key = f"{prefix}d{level}_{s}"
                row_d.append(np.asarray(arrays[key], np.float32).copy()
                             if key in arrays else None)
                row_m.append(np.asarray(arrays[f"{prefix}m{level}_{s}"],
                                        bool).copy())
            st._front.append(row_d)
            st._mask.append(row_m)
        st._res = np.array([[int(m.sum()) for m in st._mask[level]]
                            for level in range(len(st._dims))], np.int64)
        st._staged = None
        st._staged_mask = None
        st.budget_rows = (None if meta["budget_rows"] < 0
                          else int(meta["budget_rows"]))
        st.evict_policy = meta["evict_policy"]
        st.heat_decay = float(meta["heat_decay"])
        st.admission = meta["admission"]
        st.onboarding = meta["onboarding"]
        st._heat = np.asarray(arrays[f"{prefix}heat"], np.float64).copy()
        st._last = np.asarray(arrays[f"{prefix}last"], np.int64).copy()
        st._tick = int(meta["tick"])
        st._gather_depth = 0
        st._recompute_depth = 0
        st.recompute = None
        st.version = int(meta["version"])
        st.n_lookups = 0
        st.rows_gathered = 0
        st.n_swaps = 0
        st.hits = 0
        st.misses = 0
        st.n_evictions = 0
        st.rows_evicted = 0
        st.n_recomputes = 0
        st.n_recompute_spans = 0
        st.rows_recomputed = 0
        st.recompute_s = 0.0
        return st

    def dump(self, path) -> None:
        """Write the committed front to one ``.npz`` checkpoint.  The
        restart story every scale-out deployment needs: ``load`` (or
        ``Session.from_checkpoint``) rebuilds this exact epoch without
        re-running the inference that produced it."""
        arrays = self.state_arrays()
        with open(path, "wb") as f:
            np.savez_compressed(f, **arrays)

    @classmethod
    def load(cls, path) -> "EmbeddingStore":
        """Rebuild a dumped store (see ``dump``)."""
        with np.load(path) as z:
            return cls.from_state_arrays(z)


def store_from_inference(X: np.ndarray, level_outputs: Sequence[np.ndarray],
                         n_shards: int = 4, *,
                         budget_rows: Optional[int] = None,
                         evict_policy: str = "heat",
                         admission: str = "probation",
                         onboarding: str = "none") -> EmbeddingStore:
    """Build the store from a full epoch: X plus each layer's output as
    consumed by the next layer (see DeltaReinference.full_levels)."""
    return EmbeddingStore([np.asarray(X, np.float32)]
                          + [np.asarray(h, np.float32)
                             for h in level_outputs], n_shards=n_shards,
                          budget_rows=budget_rows,
                          evict_policy=evict_policy, admission=admission,
                          onboarding=onboarding)
