"""Continuous-batching embedding lookup engine with bounded staleness.

Modeled on ``serve.engine``'s fixed-slot pattern: B slots each hold one
in-flight query; every ``step`` assembles one fixed-size gather batch
(``rows_per_step`` rows, round-robin across active slots) and issues a
single sharded ``store.lookup`` — new queries are admitted into free
slots while others are mid-gather, so the gather pipe never drains.

Freshness contract: the engine tracks a ``staleness_bound`` — the max
number of pending graph/feature mutations a served row may pre-date.
When the mutation log exceeds the bound (or a query demands
``fresh=True``), the engine drains the log, splices the CSR overlay,
and runs delta re-inference BEFORE the next gather; the store's
double-buffered commit makes the epoch flip invisible to readers.
Node additions cannot be expressed as a row delta (they re-partition
the store); the engine refuses them and defers to an offline
re-partition epoch (ROADMAP open item: incremental node onboarding).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.graph import Graph
from repro.gnnserve.delta import DeltaReinference
from repro.gnnserve.mutations import MutationLog, apply_edge_mutations
from repro.gnnserve.store import EmbeddingStore, SnapshotMiss


@dataclasses.dataclass
class Query:
    uid: int
    node_ids: np.ndarray            # (n,) int64
    level: int = -1                 # which store level to read
    fresh: bool = False             # force a refresh before serving
    out: Optional[np.ndarray] = None
    served_version: int = -1
    done: bool = False
    # epoch snapshot pinned at first gather: a refresh committing while
    # this query is mid-gather must not tear the response across epochs
    snap: Optional[object] = dataclasses.field(default=None, repr=False)


class EmbeddingServeEngine:
    def __init__(self, store: EmbeddingStore, reinfer: DeltaReinference,
                 graph: Graph, *, batch_slots: int = 4,
                 rows_per_step: int = 256, staleness_bound: int = 64):
        self.store = store
        self.reinfer = reinfer
        self.graph = graph
        self.log = MutationLog()
        self.B = batch_slots
        self.rows_per_step = rows_per_step
        self.staleness_bound = staleness_bound
        self.slot_q: List[Optional[Query]] = [None] * batch_slots
        self.cursor = np.zeros(batch_slots, np.int64)
        self.queue: List[Query] = []
        self.n_gather_steps = 0
        self.n_refreshes = 0
        self.n_full_epochs = 0
        self.n_served = 0
        self.last_refresh_stats: Dict = {}

    # -- ingress --------------------------------------------------------
    def submit(self, q: Query) -> None:
        self.queue.append(q)

    def mutate(self) -> MutationLog:
        """The writable mutation log (add_edges / remove_edges /
        update_features / add_nodes)."""
        return self.log

    # -- freshness ------------------------------------------------------
    @property
    def staleness(self) -> int:
        return self.log.pending

    def refresh(self) -> Dict:
        """Drain the log and fold it into the store via delta
        re-inference (full epoch when nodes were added)."""
        if self.log.has_node_adds:      # check BEFORE draining: rejecting
            raise NotImplementedError(  # must not discard pending edits
                "node additions re-partition the store; run a full epoch "
                "(see ROADMAP open items: incremental node onboarding)")
        batch = self.log.drain()
        try:
            graph = apply_edge_mutations(self.graph, batch)
            stats = self.reinfer.refresh(
                self.store, graph, batch.feat_ids, batch.feat_rows,
                batch.affected_dsts())
        except Exception:
            # a bad batch must not silently discard the good mutations
            # drained alongside it — put everything back (in original op
            # order) and re-raise (the engine is single-threaded, so no
            # interleaved writes)
            self.log.requeue(batch)
            raise
        self.graph = graph
        self.n_refreshes += 1
        self.last_refresh_stats = stats
        return stats

    # -- serve loop -----------------------------------------------------
    def _admit(self) -> None:
        for i in range(self.B):
            if self.slot_q[i] is None and self.queue:
                q = self.queue.pop(0)
                q.node_ids = np.asarray(q.node_ids, np.int64)
                q.out = np.empty(
                    (q.node_ids.size,
                     self.store.level_dim(q.level % self.store.n_levels)),
                    np.float32)
                self.slot_q[i] = q
                self.cursor[i] = 0

    def step(self) -> bool:
        """Admit, maybe refresh, then one batched gather. Returns False
        when idle."""
        self._admit()
        active = [i for i in range(self.B) if self.slot_q[i] is not None]
        if not active:
            return False
        needs_fresh = any(self.slot_q[i].fresh and self.cursor[i] == 0
                          for i in active)
        if self.log.pending and (needs_fresh
                                 or self.log.pending >= self.staleness_bound):
            self.refresh()

        # round-robin a fixed row budget across active slots; fuse chunks
        # that share (epoch, level) into one sharded gather
        per_key: Dict[tuple, List] = {}
        budget = self.rows_per_step
        share = max(1, budget // len(active))
        for i in active:
            q = self.slot_q[i]
            take = min(share, q.node_ids.size - self.cursor[i])
            if take <= 0:
                continue
            if q.snap is None:
                # pin the query to the CURRENT epoch: rows gathered after
                # a mid-query refresh still come from this snapshot, so
                # one response never mixes epochs.  Pinning admits every
                # row the query will read FIRST (recompute-on-miss) and
                # only then lets the budget evict — a mid-query eviction
                # can drop the store's pointer but never the snapshot's
                q.snap = self.store.pinned_snapshot(q.node_ids, q.level)
                q.served_version = q.snap.version
            lo = self.cursor[i]
            per_key.setdefault(
                (q.snap.version, q.level % self.store.n_levels), []).append(
                (i, lo, lo + take))
            self.cursor[i] += take
        for (_, level), chunks in per_key.items():
            snap = self.slot_q[chunks[0][0]].snap
            ids = np.concatenate([self.slot_q[i].node_ids[lo:hi]
                                  for i, lo, hi in chunks])
            try:
                rows = snap.lookup(ids, level)        # one sharded gather
            except SnapshotMiss:
                # same-version queries can still pin DIFFERENT shard
                # arrays (an eviction + re-admission between their pins);
                # after an epoch flip the shared snapshot can't serve the
                # other queries' rows — each query's own snapshot can,
                # by the pinning guarantee
                rows = np.concatenate([
                    self.slot_q[i].snap.lookup(
                        self.slot_q[i].node_ids[lo:hi], level)
                    for i, lo, hi in chunks])
            off = 0
            for i, lo, hi in chunks:
                self.slot_q[i].out[lo:hi] = rows[off:off + (hi - lo)]
                off += hi - lo
        self.n_gather_steps += 1

        for i in active:
            q = self.slot_q[i]
            if self.cursor[i] >= q.node_ids.size:
                q.done = True
                q.snap = None       # release the pinned epoch's shards
                self.n_served += 1
                self.slot_q[i] = None
        return True

    def run(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                return

    def stats(self) -> Dict[str, float]:
        """Serve counters plus the store's (``store_`` prefix) — which now
        carry the memory model: hits/misses, evictions, recompute counts,
        resident bytes and budget utilization."""
        return {"n_served": self.n_served,
                "n_gather_steps": self.n_gather_steps,
                "n_refreshes": self.n_refreshes,
                "store_version": self.store.version,
                "pending_mutations": self.log.pending,
                **{f"store_{k}": v for k, v in self.store.stats().items()}}

    def memory_stats(self) -> Dict:
        """Per-level residency/budget breakdown (see
        ``EmbeddingStore.memory_stats``)."""
        return self.store.memory_stats()
