"""Continuous-batching embedding lookup engine with bounded staleness.

Modeled on ``serve.engine``'s fixed-slot pattern: B slots each hold one
in-flight query; every ``step`` assembles one fixed-size gather batch
(``rows_per_step`` rows, round-robin across active slots) and issues a
single sharded ``store.lookup`` — new queries are admitted into free
slots while others are mid-gather, so the gather pipe never drains.

Freshness contract: the engine tracks a ``staleness_bound`` — the max
number of pending graph/feature mutations a served row may pre-date.
When the mutation log exceeds the bound (or a query demands
``fresh=True``), the engine drains the log, splices the CSR overlay,
and runs delta re-inference BEFORE the next gather; the store's
double-buffered commit makes the epoch flip invisible to readers.
Node additions onboard incrementally on ``onboarding="tail"`` stores
(a tail partition appended past the main 1-D partitioning); on
``onboarding="none"`` stores they refuse and defer to ``full_epoch()``
(the re-partition event).

Multi-tenant QoS (``tenants=TenantRegistry(...)``): the global bound
and FIFO queue are replaced by ``gnnserve.qos`` — per-tenant freshness
SLOs with deadline-driven refresh planning (lagged per-tenant epoch
views), weighted-fair slot quotas with preemptive reclaim, and a
deficit-round-robin row budget with token buckets.  Queries carry a
``tenant`` tag; with ``tenants=None`` the engine behaves exactly as
before (single implicit tenant at ``staleness_bound``).

Refresh is a SCHEDULED workload under QoS when ``refresh_chunk_rows``
is set: instead of running the whole delta frontier inline inside one
serve step (head-of-line blocking every tenant behind a large
mutation batch), the engine opens a ``RefreshJob`` and advances it ONE
row chunk per step, interleaved with tenant gathers.  Chunk compute is
charged to the lowest-priority tenants' DRR credit as it lands; only
the tenants whose SLO (or ``fresh=True``) demanded the refresh wait
for it — everyone else keeps gathering at their pinned views, and the
committed bits are chunk-invariant (see ``DeltaReinference.
begin_refresh``), so chunking never changes what any tenant reads.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro import obs
from repro.core.graph import Graph
from repro.gnnserve.delta import (DeltaReinference, RefreshJob,
                                  attach_recompute)
from repro.gnnserve.mutations import (MutationBatch, MutationLog,
                                      apply_edge_mutations, grow_graph)
from repro.gnnserve.qos import QoSScheduler, TenantRegistry
from repro.gnnserve.store import (EmbeddingStore, SnapshotMiss,
                                  store_from_inference)


@dataclasses.dataclass
class Query:
    uid: int
    node_ids: np.ndarray            # (n,) int64
    level: int = -1                 # which store level to read
    fresh: bool = False             # force a refresh before serving
    tenant: str = "default"         # QoS tenant tag (ignored w/o QoS)
    out: Optional[np.ndarray] = None
    served_version: int = -1
    done: bool = False
    # epoch snapshot pinned at first gather: a refresh committing while
    # this query is mid-gather must not tear the response across epochs
    snap: Optional[object] = dataclasses.field(default=None, repr=False)
    # QoS bookkeeping: per-query cursor (survives preemption), queue-wait
    # and observed-staleness samples
    cursor: int = 0
    submit_step: int = -1
    first_gather_step: int = -1
    observed_staleness: int = -1
    # wall-clock submit stamp (telemetry only; -1 when disabled) —
    # queue-wait histograms read it at first pin
    submit_ns: int = -1
    # critical-path ledger (telemetry only; None when disabled): per-
    # segment ns accumulated by the engine's hooks, closed by
    # ``_finish_attrib`` into the session's AttributionCollector
    attrib: Optional[Dict] = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class _RefreshRec:
    """Engine-side record of one in-flight (or inline) refresh: the
    drained batch for rollback/requeue, the delta job, the post-splice
    graph to swap in at commit, and the onboarding extent."""
    batch: MutationBatch
    job: RefreshJob
    graph: Graph
    n_new: int
    n_nodes_before: int         # store extent before any tail append
    charged: int = 0            # rows_gemm already charged per chunk


class EmbeddingServeEngine:
    def __init__(self, store: EmbeddingStore, reinfer: DeltaReinference,
                 graph: Graph, *, batch_slots: int = 4,
                 rows_per_step: int = 256, staleness_bound: int = 64,
                 tenants: Optional[TenantRegistry] = None,
                 refresh_charge: float = 1.0,
                 refresh_chunk_rows: int = 0):
        self.store = store
        self.reinfer = reinfer
        self.graph = graph
        self.log = MutationLog()
        self.B = batch_slots
        self.rows_per_step = rows_per_step
        self.staleness_bound = staleness_bound
        self.slot_q: List[Optional[Query]] = [None] * batch_slots
        self.cursor = np.zeros(batch_slots, np.int64)
        self.queue: List[Query] = []
        self.n_gather_steps = 0
        self.n_refreshes = 0
        self.n_full_epochs = 0
        self.n_onboarded = 0        # nodes added via tail onboarding
        self.n_served = 0
        self.ops_drained = 0        # mutation ops folded into the store
        self.last_refresh_stats: Dict = {}
        # preemptible chunked refresh (QoS scheduling only; the FIFO
        # path keeps its inline refresh): 0 = inline, >0 = rows per
        # chunk, one chunk advanced per _step_qos
        self.refresh_chunk_rows = int(refresh_chunk_rows)
        self.n_refresh_chunks = 0
        self._rjob: Optional[_RefreshRec] = None
        # serving-tier health (telemetry only): built lazily on the
        # first submit with telemetry enabled, so the disabled path pays
        # nothing.  ``health_opts`` is overridable (Session wires
        # TelemetrySpec's window/budget/threshold through it) as long as
        # it happens before the first submit.
        self.attrib = None              # obs.health.AttributionCollector
        self.health = None              # obs.health.HealthMonitor
        self.health_opts: Dict = {}
        self.qos: Optional[QoSScheduler] = None
        if tenants is not None:
            self.qos = QoSScheduler(tenants, batch_slots=batch_slots,
                                    rows_per_step=rows_per_step,
                                    refresh_charge=refresh_charge)
            for name in tenants.names:      # views start at the current
                st = self.qos.state(name)   # epoch, nothing unobserved
                st.view_version = store.version
                st.ops_at_view = 0
            self.qos.record_epoch(store.version, 0, store.snapshot())

    # -- ingress --------------------------------------------------------
    def submit(self, q: Query) -> None:
        if obs.enabled():
            q.submit_ns = obs.current().now_ns()
            obs.add("serve.submitted")
            self._obs_init()
            q.attrib = {"t_enq": q.submit_ns, "t_slot": -1, "wait": 0,
                        "pin": 0, "recompute": 0, "gather": 0,
                        "refresh_wait": 0, "slot": 0}
        if self.qos is not None:
            q.node_ids = np.asarray(q.node_ids, np.int64)
            self.qos.route(q)
        else:
            self.queue.append(q)

    def mutate(self) -> MutationLog:
        """The writable mutation log (add_edges / remove_edges /
        update_features / add_nodes)."""
        return self.log

    # -- freshness ------------------------------------------------------
    @property
    def staleness(self) -> int:
        return self.log.pending

    def refresh(self) -> Dict:
        """Drain the log and fold it into the store via delta
        re-inference.  Node additions onboard incrementally when the
        store was built with ``onboarding="tail"`` (a tail partition is
        appended and the new ids ride this refresh's resampled set) —
        QoS engines included: tenants whose views lag the append keep
        their pre-append epoch snapshot, and tail ids resolve only for
        views at/after the append version (see ``_pin_qos``).  On
        ``onboarding="none"`` stores node additions refuse here and
        fold via ``full_epoch()``."""
        self._drain_refresh_job()   # an in-flight chunked job commits
        self._check_onboarding()    # first, THEN any newly pending ops
        return self._refresh()

    def _check_onboarding(self) -> None:
        # check BEFORE draining: rejecting must not discard pending edits
        if self.log.has_node_adds and self.store.onboarding != "tail":
            raise NotImplementedError(
                "node additions re-partition the store; build it "
                "with onboarding=\"tail\" (StoreSpec.onboarding) "
                "for incremental onboarding, or call full_epoch() "
                "(the re-partition event, which folds them)")

    def _observe_wait(self, q: Query) -> None:
        """Queue-wait sample at first pin (submit -> first gather)."""
        if q.submit_ns >= 0 and obs.enabled():
            wait_ms = (obs.current().now_ns() - q.submit_ns) / 1e6
            obs.observe("serve.queue_wait_ms", wait_ms)
            if self.qos is not None:
                obs.observe(f"qos.tenant.{q.tenant}.wait_ms", wait_ms)
            if self.health is not None:
                self.health.on_wait(q.tenant, wait_ms)

    # -- serving-tier health (telemetry only) ---------------------------
    def _obs_init(self) -> None:
        """Lazily build the attribution collector + health monitor on
        the first submit with telemetry enabled."""
        if self.attrib is not None:
            return
        from repro.obs.health import AttributionCollector, HealthMonitor
        self.attrib = AttributionCollector()
        slos = ({s.name: s.staleness_slo for s in self.qos.registry}
                if self.qos is not None
                else {"default": self.staleness_bound})
        self.health = HealthMonitor(slos, **self.health_opts)

    def _timed_pin(self, q: Query, pin) -> None:
        """Run ``pin()`` charging its wall time to the query's ``pin``
        segment, with the store's recompute-on-miss share split out into
        ``recompute`` (the store keeps a cumulative recompute clock; the
        delta across the pin is this query's admission recompute)."""
        a = q.attrib
        if a is None:
            pin()
            return
        tel = obs.current()
        t0 = tel.now_ns()
        rc0 = self.store.recompute_s
        pin()
        rc = int((self.store.recompute_s - rc0) * 1e9)
        a["recompute"] += rc
        a["pin"] += max(tel.now_ns() - t0 - rc, 0)

    def _charge_refresh_wait(self, active: List[int], dur: int) -> None:
        """Refresh interference: work that ran between this step's
        admissions and gathers delays every query holding a slot, so
        the full duration lands on each one's ``refresh_wait``."""
        if dur <= 0:
            return
        for i in active:
            a = self.slot_q[i].attrib
            if a is not None:
                a["refresh_wait"] += dur

    def _charge_gather(self, chunks: List, dur: int) -> None:
        """Apportion one fused gather's wall time across the queries
        that rode it, by their row share."""
        tot = sum(hi - lo for _, lo, hi in chunks)
        if tot <= 0:
            return
        for i, lo, hi in chunks:
            a = self.slot_q[i].attrib
            if a is not None:
                a["gather"] += dur * (hi - lo) // tot

    def _finish_attrib(self, q: Query) -> None:
        """Close the query's critical-path ledger: stop the in-slot
        clock, derive ``sched_wait`` as the unexplained in-slot
        remainder, fold the segments into the per-tenant collector, and
        record one ``serve.query`` trace event spanning submit -> done
        (rendered on its own Perfetto track; the report CLI's top-k
        critical-path table reads these events)."""
        a, q.attrib = q.attrib, None
        tel = obs.current()
        if not tel.enabled or self.attrib is None:
            return
        now = tel.now_ns()
        if a["t_slot"] >= 0:
            a["slot"] += now - a["t_slot"]
        e2e = max(now - q.submit_ns, 0)
        comp = a["pin"] + a["recompute"] + a["gather"] + a["refresh_wait"]
        segs = {"queue_wait": a["wait"], "pin": a["pin"],
                "recompute": a["recompute"], "gather": a["gather"],
                "refresh_wait": a["refresh_wait"],
                "sched_wait": max(a["slot"] - comp, 0)}
        self.attrib.record(uid=q.uid, tenant=q.tenant, e2e_ns=e2e,
                           segments_ns=segs,
                           served_version=q.served_version)
        attrs = {"uid": int(q.uid), "tenant": q.tenant,
                 "served_version": int(q.served_version),
                 "_track": "queries"}
        for k, v in segs.items():
            attrs[f"{k}_ms"] = round(v / 1e6, 4)
        tel.tracer.record("serve.query", q.submit_ns, e2e, 0, attrs)

    def _refresh(self) -> Dict:
        """The gate-free refresh body: ``full_epoch`` calls it directly
        so pending node adds fold there even on ``onboarding="none"``
        stores (a full epoch IS the re-partition event)."""
        with obs.span("serve.refresh") as rsp:
            stats = self._refresh_body()
            if rsp:
                rsp.set(rows_gemm=int(stats.get("rows_gemm", 0)),
                        n_onboarded=int(stats.get("n_onboarded", 0)))
        return stats

    def _refresh_body(self) -> Dict:
        rec = self._open_refresh(chunk_rows=0)
        try:
            while not rec.job.done:
                rec.job.step()
        except Exception:
            self._rollback_refresh(rec)
            raise
        return self._finish_refresh(rec)

    def _open_refresh(self, *, chunk_rows: int) -> _RefreshRec:
        """Drain the log and open the delta job: onboarding structures,
        CSR splice, resample + frontier + staging overlay (the job
        prologue).  Nothing is reader-visible until the job commits."""
        batch = self.log.drain()
        n_new = batch.n_new_nodes
        new_ids = np.empty(0, np.int64)
        graph0 = self.graph
        n_before = self.store.n_nodes
        extended = tailed = False
        try:
            if n_new:
                # onboard: empty CSR rows + grown layer graphs + tail
                # shard, all BEFORE the edge splice so ops touching new
                # ids are legal
                new_ids = np.arange(graph0.n_nodes,
                                    graph0.n_nodes + n_new,
                                    dtype=np.int64)
                graph0 = grow_graph(graph0, n_new)
                self.reinfer.extend_nodes(n_new)
                extended = True
                self.store.append_tail(n_new, batch.new_node_rows)
                tailed = True
            graph = apply_edge_mutations(graph0, batch)
            resampled = batch.affected_dsts()
            if n_new:
                # the new ids ALWAYS resample: that is what draws their
                # fanout rows and pushes them through every frontier
                # level, so their tail shard commits fully written
                resampled = np.union1d(resampled, new_ids)
            job = self.reinfer.begin_refresh(
                self.store, graph, batch.feat_ids, batch.feat_rows,
                resampled, chunk_rows=chunk_rows)
        except Exception:
            # a bad batch must not silently discard the good mutations
            # drained alongside it — roll back exactly the onboarding
            # structures that were built and put everything back (in
            # original op order), then re-raise (the engine is
            # single-threaded, so no interleaved writes)
            if tailed:
                self.store.pop_tail(n_new)
            if extended:
                self.reinfer.shrink_nodes(n_new)
            self.log.requeue(batch)
            raise
        return _RefreshRec(batch=batch, job=job, graph=graph,
                           n_new=n_new, n_nodes_before=n_before)

    def _rollback_refresh(self, rec: _RefreshRec) -> None:
        """Unwind a refresh whose job aborted mid-chunk (the job itself
        already rolled the store + layer-graph resamples back)."""
        if rec.n_new:
            self.store.pop_tail(rec.n_new)
            self.reinfer.shrink_nodes(rec.n_new)
        self.log.requeue(rec.batch)

    def _finish_refresh(self, rec: _RefreshRec) -> Dict:
        stats = rec.job.finish()
        self.graph = rec.graph
        self.ops_drained += rec.batch.n_ops
        self.n_refreshes += 1
        self.n_onboarded += rec.n_new
        stats["n_onboarded"] = rec.n_new
        self.last_refresh_stats = stats
        if self.qos is not None:
            # the new epoch becomes pinnable for per-tenant views, and
            # its compute cost lands on batch-tenant row budgets first
            self.qos.record_epoch(self.store.version, self.ops_drained,
                                  self.store.snapshot())
            remaining = int(stats["rows_gemm"]) - rec.charged
            if remaining > 0:   # chunked jobs already charged per chunk
                self.qos.charge_refresh(remaining)
        return stats

    # -- preemptible chunked refresh (QoS) ------------------------------
    def _open_refresh_job(self, due) -> None:
        """Open a chunked refresh the QoS loop advances one chunk per
        step.  ``due`` tenants become the job's waiters: their views
        advance when it commits, and until then their new pins defer —
        everyone else keeps gathering at their pinned views between
        chunks."""
        assert self._rjob is None
        self._check_onboarding()
        self._rjob = self._open_refresh(chunk_rows=self.refresh_chunk_rows)
        self.qos.refresh_waiters.update(due)
        if obs.enabled():
            obs.add("qos.refresh_jobs")

    def _advance_refresh_job(self) -> None:
        """Run one chunk of the in-flight job; commit + advance waiter
        views when the last chunk lands."""
        rec = self._rjob
        if not rec.job.done:
            try:
                info = rec.job.step()
            except Exception:
                self._rjob = None
                self.qos.refresh_waiters.clear()
                self._rollback_refresh(rec)
                raise
            self.n_refresh_chunks += 1
            if info["rows_gemm"]:
                # charge as the work lands, not at commit: the DRR
                # credit of the batch tenants absorbs each chunk in the
                # very step it ran, so their next grants shrink NOW
                self.qos.charge_refresh(info["rows_gemm"])
                rec.charged += int(info["rows_gemm"])
        if rec.job.done:
            with obs.span("serve.refresh") as rsp:
                stats = self._finish_refresh(rec)
                if rsp:
                    rsp.set(rows_gemm=int(stats.get("rows_gemm", 0)),
                            n_onboarded=int(stats.get("n_onboarded", 0)),
                            n_chunks=int(stats.get("n_chunks", 0)))
            waiters = sorted(self.qos.refresh_waiters)
            self.qos.refresh_waiters.clear()
            self._rjob = None
            self.qos.advance_views(waiters, self.store.version,
                                   self.ops_drained, refreshed=True)

    def _drain_refresh_job(self) -> None:
        """Complete any in-flight chunked refresh synchronously (public
        ``refresh``/``full_epoch`` entry points must not observe a
        half-applied job)."""
        while self._rjob is not None:
            self._advance_refresh_job()

    def _refresh_holds(self, q: Query) -> bool:
        """While a chunked refresh is in flight, must this query's PIN
        wait for the commit?  Three reasons: (1) its tenant demanded the
        refresh (serving it the old epoch would violate the very SLO
        that triggered the job); (2) it reads tail ids appended by the
        job (unreadable until the commit makes them resolvable); (3) on
        a budgeted store, pinning rows in the job's frontier could
        recompute through mid-flight layer-graph rows (wrong
        neighborhoods before commit).  Pinned queries are never held —
        their snapshots are immutable."""
        rec = self._rjob
        if q.served_version == -2:      # parked by _restart_on_current
            return True
        if q.tenant in self.qos.refresh_waiters:
            return True
        if q.node_ids.size == 0:
            return False
        if int(q.node_ids.max()) >= rec.n_nodes_before:
            return True
        hold = rec.job.hold_rows
        if self.store.recompute is not None and hold.size:
            pos = np.clip(np.searchsorted(hold, q.node_ids),
                          0, hold.size - 1)
            if (hold[pos] == q.node_ids).any():
                return True
        return False

    def full_epoch(self, n_shards: Optional[int] = None) -> Dict:
        """Re-partition epoch: refresh any pending mutations, then
        rebuild the store from a full pass over the CURRENT features —
        folding every onboarded tail partition back into the main 1-D
        partitioning (``n_shards`` defaults to the pre-tail count).
        Contents are bitwise-unchanged (the delta-refresh invariant:
        store rows == a full epoch on the same layer graphs through the
        same executor); the version advances so pinned snapshots of the
        old store keep serving their epoch untouched.  Pending node
        additions fold here REGARDLESS of ``store.onboarding`` — this is
        the re-partition event ``refresh`` defers them to."""
        self._drain_refresh_job()
        if self.log.pending:
            self._refresh()
        st = self.store
        X = st.lookup(np.arange(st.n_nodes, dtype=np.int64), 0)
        levels = self.reinfer.full_levels(X)
        new = store_from_inference(
            X, levels[1:],
            n_shards=n_shards or (st.n_shards - st.n_tail_shards),
            budget_rows=st.budget_rows, evict_policy=st.evict_policy,
            admission=st.admission, onboarding=st.onboarding)
        new.version = st.version + 1
        if st.recompute is not None:
            attach_recompute(new, self.reinfer)
        # poison the swapped-out store: its version would otherwise stay
        # frozen, so an old snapshot's same-version fallback could
        # recompute "its" epoch through layer graphs that LATER
        # refreshes mutate — advance it so such reads SnapshotMiss
        # loudly instead of silently serving cross-epoch bits
        st.version = new.version
        st.recompute = None
        self.store = new
        self.n_full_epochs += 1
        if self.qos is not None:
            self.qos.record_epoch(new.version, self.ops_drained,
                                  new.snapshot())
        return {"version": new.version, "n_shards": new.n_shards,
                "rows_gemm": st.n_nodes * self.reinfer.n_layers}

    # -- serve loop -----------------------------------------------------
    def _admit(self) -> None:
        now = -1
        for i in range(self.B):
            if self.slot_q[i] is None and self.queue:
                q = self.queue.pop(0)
                q.node_ids = np.asarray(q.node_ids, np.int64)
                q.out = np.empty(
                    (q.node_ids.size,
                     self.store.level_dim(q.level % self.store.n_levels)),
                    np.float32)
                self.slot_q[i] = q
                self.cursor[i] = 0
                if q.attrib is not None:
                    if now < 0:
                        now = obs.current().now_ns()
                    q.attrib["wait"] += now - q.attrib["t_enq"]
                    q.attrib["t_slot"] = now

    def step(self) -> bool:
        """Admit, maybe refresh, then one batched gather. Returns False
        when idle.  With QoS, admission/refresh/row-split are delegated
        to the per-tenant scheduler (``_step_qos``)."""
        with obs.span("serve.step") as sp:
            r = (self._step_qos() if self.qos is not None
                 else self._step_fifo())
            if sp:
                sp.set(progressed=r, qos=self.qos is not None)
        if r and self.health is not None:
            # cumulative counters; the monitor diffs them per step
            self.health.on_step(
                pending=self.log.pending,
                evictions=self.store.n_evictions,
                route_local=self.reinfer.n_local_cutovers,
                route_dist=self.reinfer.n_dist_layers)
        return r

    def _step_fifo(self) -> bool:
        self._admit()
        active = [i for i in range(self.B) if self.slot_q[i] is not None]
        if not active:
            return False
        needs_fresh = any(self.slot_q[i].fresh and self.cursor[i] == 0
                          for i in active)
        if self.log.pending and (needs_fresh
                                 or self.log.pending >= self.staleness_bound):
            rt0 = obs.current().now_ns() if obs.enabled() else -1
            self.refresh()
            if rt0 >= 0:
                self._charge_refresh_wait(
                    active, obs.current().now_ns() - rt0)

        # round-robin a fixed row budget across active slots; fuse chunks
        # that share (epoch, level) into one sharded gather
        per_key: Dict[tuple, List] = {}
        budget = self.rows_per_step
        share = max(1, budget // len(active))
        for i in active:
            q = self.slot_q[i]
            take = min(share, q.node_ids.size - self.cursor[i])
            if take <= 0:
                continue
            if q.snap is None:
                # pin the query to the CURRENT epoch: rows gathered after
                # a mid-query refresh still come from this snapshot, so
                # one response never mixes epochs.  Pinning admits every
                # row the query will read FIRST (recompute-on-miss) and
                # only then lets the budget evict — a mid-query eviction
                # can drop the store's pointer but never the snapshot's
                def _pin(q=q):
                    q.snap = self.store.pinned_snapshot(q.node_ids,
                                                        q.level)
                self._timed_pin(q, _pin)
                q.served_version = q.snap.version
                if self.health is not None:
                    self.health.on_staleness(q.tenant, self.log.pending)
                self._observe_wait(q)
            lo = self.cursor[i]
            per_key.setdefault(
                (q.snap.version, q.level % self.store.n_levels), []).append(
                (i, lo, lo + take))
            self.cursor[i] += take
        for (_, level), chunks in per_key.items():
            snap = self.slot_q[chunks[0][0]].snap
            ids = np.concatenate([self.slot_q[i].node_ids[lo:hi]
                                  for i, lo, hi in chunks])
            tg0 = (obs.current().now_ns()
                   if any(self.slot_q[i].attrib is not None
                          for i, _, _ in chunks) else -1)
            gsp = obs.span("serve.gather")
            if gsp:
                gsp.set(rows=int(ids.size), level=level,
                        n_queries=len(chunks))
            with gsp:
                try:
                    rows = snap.lookup(ids, level)    # one sharded gather
                except SnapshotMiss:
                    # same-version queries can still pin DIFFERENT shard
                    # arrays (an eviction + re-admission between their
                    # pins); after an epoch flip the shared snapshot
                    # can't serve the other queries' rows — each query's
                    # own snapshot can, by the pinning guarantee
                    rows = np.concatenate([
                        self.slot_q[i].snap.lookup(
                            self.slot_q[i].node_ids[lo:hi], level)
                        for i, lo, hi in chunks])
            off = 0
            for i, lo, hi in chunks:
                self.slot_q[i].out[lo:hi] = rows[off:off + (hi - lo)]
                off += hi - lo
            if tg0 >= 0:
                self._charge_gather(chunks, obs.current().now_ns() - tg0)
        self.n_gather_steps += 1

        for i in active:
            q = self.slot_q[i]
            if self.cursor[i] >= q.node_ids.size:
                q.done = True
                q.snap = None       # release the pinned epoch's shards
                if q.attrib is not None:
                    self._finish_attrib(q)
                self.n_served += 1
                self.slot_q[i] = None
        return True

    # -- QoS serve loop -------------------------------------------------
    def _pin_qos(self, q: Query) -> None:
        """Pin a query to its TENANT's freshness view: the current epoch
        (admit-then-pin, eviction-safe) when the view is current, or the
        tenant's lagged epoch snapshot — a loose-SLO tenant keeps
        reading older bits while a strict tenant refreshes next to it."""
        st = self.qos.state(q.tenant)
        stale = self.qos.unobserved_of(q.tenant, self.log.pending,
                                       self.ops_drained)

        def _pin():
            nonlocal stale
            if st.view_version == self.store.version:
                q.snap = self.store.pinned_snapshot(q.node_ids, q.level)
                q.served_version = st.view_version
            else:
                snap = self.qos.epoch_snapshot(st.view_version)
                if q.node_ids.size and \
                        int(q.node_ids.max()) >= int(snap.bounds[-1]):
                    # the lagged view predates a tail append: tail ids
                    # resolve only for views at/after the append version,
                    # so this query serves on the CURRENT epoch instead —
                    # fresher than its SLO requires, never staler, and
                    # the tenant's other queries keep their pre-append
                    # bits
                    q.snap = self.store.pinned_snapshot(q.node_ids,
                                                        q.level)
                    q.served_version = self.store.version
                    stale = self.log.pending
                    self.qos.on_view_restart(q.tenant)
                else:
                    q.snap = snap
                    q.served_version = st.view_version

        self._timed_pin(q, _pin)
        self.qos.on_pin(q, stale)
        if self.health is not None:
            self.health.on_staleness(q.tenant, stale)
        self._observe_wait(q)

    def _restart_on_current(self, q: Query) -> None:
        """A lagged view hit rows the old epoch can't serve any more
        (evicted on a budgeted store): restart the query on the CURRENT
        epoch — fresher than its SLO requires, never staler, never
        torn.  Rows regathered after the restart are charged to the
        tenant again (rows_served / tokens / DRR credit): they are real
        gather work, and the fair-share accounting follows the work."""
        if self._rjob is not None:
            # mid-job, "current" is the PRE-commit epoch — restarting on
            # it now would diverge from the inline schedule (and may be
            # unsafe: tail ids / recompute through mid-flight graph
            # rows).  Park the query; it re-pins after the commit.
            # served_version=-2 marks it held so it does not re-pin
            # (and re-miss) every step until then.
            q.snap = None
            q.served_version = -2
            q.cursor = 0
            self.qos.on_defer(q.tenant)
            return
        q.snap = self.store.pinned_snapshot(q.node_ids, q.level)
        q.served_version = self.store.version
        q.cursor = 0
        self.qos.on_view_restart(q.tenant)

    def _step_qos(self) -> bool:
        qos = self.qos
        qos.step_no += 1
        # admission: guaranteed quotas reclaim borrowed slots
        # (preempted queries pause with cursor+snapshot intact), idle
        # quota is lent out work-conserving
        preempt, admit = qos.plan_admission(self.slot_q)
        now = (obs.current().now_ns()
               if (preempt or admit) and obs.enabled() else -1)
        for i in preempt:
            q = self.slot_q[i]
            if obs.enabled():
                obs.add("qos.preemptions")
                obs.add(f"qos.tenant.{q.tenant}.preemptions")
            if q.attrib is not None and now >= 0:
                # pause the in-slot clock; queue time resumes accruing
                if q.attrib["t_slot"] >= 0:
                    q.attrib["slot"] += now - q.attrib["t_slot"]
                    q.attrib["t_slot"] = -1
                q.attrib["t_enq"] = now
            qos.requeue_front(q)
            self.slot_q[i] = None
        for i, q in admit:
            if q.out is None:
                q.out = np.empty(
                    (q.node_ids.size,
                     self.store.level_dim(q.level % self.store.n_levels)),
                    np.float32)
                q.cursor = 0
            if q.attrib is not None and now >= 0:
                q.attrib["wait"] += now - q.attrib["t_enq"]
                q.attrib["t_slot"] = now
            self.slot_q[i] = q
        active = [i for i in range(self.B) if self.slot_q[i] is not None]
        if not active and self._rjob is None:
            return False

        # deadline-driven refresh planning: coalesce the mutation log up
        # to the tightest ACTIVE tenant SLO; only due tenants' views
        # advance (the rest keep their older epoch)
        due = qos.due_tenants(self.slot_q, self.log.pending,
                              self.ops_drained)
        rt0 = (obs.current().now_ns()
               if (self._rjob is not None or due) and obs.enabled()
               else -1)
        if self._rjob is not None:
            # a chunked refresh is in flight: newly-due tenants join its
            # waiters (their pins defer until the commit), and exactly
            # one chunk advances this step, between tenant gathers
            if due:
                qos.refresh_waiters.update(due)
            self._advance_refresh_job()
            if self._rjob is None and self.log.pending:
                # committed — but mutations that arrived DURING the job
                # were frozen out of its inputs, so a tenant they made
                # due is still stale at the committed version.  Open the
                # follow-up job now (its frontier is one job's worth of
                # mutations, so it commits fast) so those pins keep
                # deferring instead of landing on an SLO-violating epoch
                due = qos.due_tenants(self.slot_q, self.log.pending,
                                      self.ops_drained)
                if due:
                    self._open_refresh_job(due)
        elif due:
            refreshed = bool(self.log.pending)
            if refreshed and self.refresh_chunk_rows > 0:
                self._open_refresh_job(due)
                self._advance_refresh_job()  # first chunk rides this step
            else:
                if refreshed:
                    self.refresh()
                qos.advance_views(due, self.store.version,
                                  self.ops_drained, refreshed=refreshed)
        if rt0 >= 0:
            # refresh interference: the chunk (or inline refresh) that
            # ran this step delayed every query already holding a slot
            self._charge_refresh_wait(active,
                                      obs.current().now_ns() - rt0)
        if not active:
            return True            # the job progressed; nothing to gather

        # weighted-fair row budget (DRR + token buckets), then one fused
        # sharded gather per (epoch, level).  Unpinned queries held by
        # the in-flight refresh (waiter tenants, job-appended tail ids,
        # job-frontier rows on a recompute store) sit out this step's
        # allocation — their slots stay claimed, their rows wait for the
        # commit.
        ready = []
        for i in active:
            q = self.slot_q[i]
            if (self._rjob is not None and q.snap is None
                    and self._refresh_holds(q)):
                qos.on_defer(q.tenant)
            else:
                ready.append(i)
        need = {i: self.slot_q[i].node_ids.size - self.slot_q[i].cursor
                for i in ready}
        grants = qos.allocate([(i, self.slot_q[i].tenant, need[i])
                               for i in ready], self.rows_per_step)
        per_key: Dict[tuple, List] = {}
        for i in ready:
            q = self.slot_q[i]
            take = min(grants.get(i, 0), need[i])
            if take <= 0:
                continue
            if q.snap is None:
                self._pin_qos(q)
            lo = q.cursor
            per_key.setdefault(
                (q.served_version, q.level % self.store.n_levels),
                []).append((i, lo, lo + take))
            q.cursor += take
            qos.on_rows(q.tenant, take)
        for (_, level), chunks in per_key.items():
            snap = self.slot_q[chunks[0][0]].snap
            ids = np.concatenate([self.slot_q[i].node_ids[lo:hi]
                                  for i, lo, hi in chunks])
            tg0 = (obs.current().now_ns()
                   if any(self.slot_q[i].attrib is not None
                          for i, _, _ in chunks) else -1)
            gsp = obs.span("serve.gather")
            if gsp:
                gsp.set(rows=int(ids.size), level=level,
                        n_queries=len(chunks))
            with gsp:
                try:
                    rows = snap.lookup(ids, level)
                except SnapshotMiss:
                    rows = None
            if rows is not None:
                off = 0
                for i, lo, hi in chunks:
                    self.slot_q[i].out[lo:hi] = rows[off:off + (hi - lo)]
                    off += hi - lo
            else:
                # same-version queries can pin different shard arrays
                # (see the non-QoS path) — fall back per query; a query
                # whose LAGGED view can't serve its rows restarts on the
                # current epoch
                for i, lo, hi in chunks:
                    q = self.slot_q[i]
                    try:
                        q.out[lo:hi] = q.snap.lookup(
                            q.node_ids[lo:hi], level)
                    except SnapshotMiss:
                        self._restart_on_current(q)
            if tg0 >= 0:
                self._charge_gather(chunks, obs.current().now_ns() - tg0)
        self.n_gather_steps += 1
        qos.account_slots(self.slot_q)

        for i in active:
            q = self.slot_q[i]
            if q.cursor >= q.node_ids.size:
                q.done = True
                q.snap = None       # release the pinned epoch's shards
                qos.on_done(q)
                if q.attrib is not None:
                    self._finish_attrib(q)
                self.n_served += 1
                self.slot_q[i] = None
        return True

    def run(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            queued = (self.qos.queued() if self.qos is not None
                      else len(self.queue))
            if not self.step() and not queued:
                return

    def stats(self) -> Dict[str, float]:
        """Serve counters plus the store's (``store_`` prefix) — which now
        carry the memory model: hits/misses, evictions, recompute counts,
        resident bytes and budget utilization.  With QoS, ``tenants``
        nests per-tenant p50/p95 queue wait, rows served, observed
        staleness vs SLO, refresh charges, and quota utilization."""
        out = {"n_served": self.n_served,
               "n_gather_steps": self.n_gather_steps,
               "n_refreshes": self.n_refreshes,
               "n_refresh_chunks": self.n_refresh_chunks,
               "n_full_epochs": self.n_full_epochs,
               "n_onboarded": self.n_onboarded,
               "store_version": self.store.version,
               "pending_mutations": self.log.pending,
               **{f"store_{k}": v for k, v in self.store.stats().items()}}
        if self.qos is not None:
            out["tenants"] = self.qos.stats()
        return out

    def memory_stats(self) -> Dict:
        """Per-level residency/budget breakdown (see
        ``EmbeddingStore.memory_stats``)."""
        return self.store.memory_stats()
