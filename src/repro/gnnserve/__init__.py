"""gnnserve — online embedding serving on top of DEAL's layerwise engine.

Architecture overview
=====================

The offline pipeline (graph -> layer-wise sampling -> partition ->
``DistributedLayerwise``) produces embeddings for ALL nodes.  gnnserve
turns that batch artifact into an online service that stays fresh as the
graph mutates, without re-running full epochs:

  ``store``      Versioned, partition-sharded embedding store holding
                 EVERY level of the layerwise computation (features,
                 each layer's input, final embedding).  Double-buffered:
                 writers stage copy-on-write shards, ``commit`` swaps
                 them in atomically (the epoch flip readers never see).
                 Memory-budgeted: ``budget_rows`` caps residency per
                 level; cold shards are evicted (heat/LRU) and misses
                 rebuild exactly the missing rows through the delta
                 engine (``RecomputeOnMiss``), bitwise-equal to a
                 never-evicted store (docs/ARCHITECTURE.md: "The store's
                 memory model").

  ``mutations``  Edge/node mutation log + CSR delta overlay over
                 ``core.graph.Graph``.  ``apply_edge_mutations`` splices
                 only the affected CSR rows — O(changed rows), not O(E).

  ``delta``      Incremental re-inference.  Edge churn deterministically
                 re-samples the affected layer-graph rows; the k-hop
                 forward-affected frontier is computed in closed form
                 from reversed fanout matrices (the forward twin of
                 ``core.sharing``'s backward dependency walk), and ONLY
                 those rows re-run through the pluggable executor layer
                 (``core.ops``: ref / pallas / dist with a per-partition
                 frontier split on the mesh) — bitwise-identical to a
                 from-scratch epoch through the same executor.

  ``engine``     Continuous-batching lookup engine (the fixed-slot
                 pattern of ``serve.engine``): B slots, one fused
                 sharded gather per step, and a staleness bound on
                 pending mutations that triggers delta refresh inline.

  ``qos``        Multi-tenant QoS scheduling: tenants declared with
                 priority / slot quota / token-bucket rate / per-tenant
                 staleness SLO replace the engine's single global bound
                 and FIFO queue.  Slots and the per-step row budget are
                 split deficit-weighted-fair (work-conserving, with
                 preemptive quota reclaim and a K-step starvation
                 bound); refresh planning is deadline-driven off the
                 tightest ACTIVE tenant SLO, with lagged per-tenant
                 epoch views — each tenant's reads are bitwise-equal to
                 a single-tenant engine run at that tenant's SLO
                 (content-addressed resampling makes refresh batching
                 invariant).

Dataflow:  queries ->  engine.step -> store.lookup (front buffer)
           mutations -> MutationLog -> [staleness bound trips]
                     -> apply_edge_mutations -> resample_rows
                     -> forward_frontier -> row-subset re-inference
                     -> store.commit (buffer swap, version += 1)

Node additions onboard INCREMENTALLY on stores built with
``onboarding="tail"``: a tail partition appends past the main 1-D
partitioning, the new ids ride the next refresh's resampled set, and
``engine.full_epoch()`` folds tails back in (bitwise-unchanged).

Entry points (all thin clients of ``repro.api`` — DealConfig +
Session): ``launch/serve_embeddings.py`` (CLI service loop),
``examples/embedding_service.py`` (demo), and
``benchmarks/bench_incremental.py`` (delta vs full-recompute study).
"""
from repro.gnnserve.delta import (DeltaReinference, RecomputeOnMiss,
                                  RefreshJob, attach_recompute,
                                  build_reverse_index, forward_frontier,
                                  resample_rows, splice_reverse_index)
from repro.gnnserve.engine import EmbeddingServeEngine, Query
from repro.gnnserve.mutations import (MutationBatch, MutationLog,
                                      apply_edge_mutations, grow_graph)
from repro.gnnserve.qos import (QoSScheduler, TenantRegistry, TenantSpec,
                                parse_tenants)
from repro.gnnserve.store import (EmbeddingStore, EvictedRowMiss,
                                  SnapshotMiss, StoreSnapshot,
                                  store_from_inference)

__all__ = ["DeltaReinference", "RecomputeOnMiss", "RefreshJob",
           "attach_recompute",
           "build_reverse_index", "forward_frontier",
           "resample_rows", "splice_reverse_index",
           "EmbeddingServeEngine", "Query",
           "MutationBatch", "MutationLog", "apply_edge_mutations",
           "grow_graph",
           "QoSScheduler", "TenantRegistry", "TenantSpec", "parse_tenants",
           "EmbeddingStore", "EvictedRowMiss", "SnapshotMiss",
           "StoreSnapshot", "store_from_inference"]
