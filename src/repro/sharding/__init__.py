from repro.sharding.specs import (batch_specs, cache_specs, logical_axes,
                                  param_specs, shard_if_divisible)

__all__ = ["batch_specs", "cache_specs", "logical_axes", "param_specs",
           "shard_if_divisible"]
