"""Version-portability shims for the shard_map / mesh JAX surface.

The repo is written against the modern spelling (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh`` with ``axis_types``).  Other runtimes
disagree on every piece: 0.4.x keeps shard_map in ``jax.experimental``
and spells the replication check ``check_rep`` (as do some newer
top-level versions), and ``jax.make_mesh``/``AxisType`` appear mid-0.4.
Every call site routes through these two wrappers so one code path runs
everywhere.
"""
from __future__ import annotations

import inspect

import jax
import numpy as np


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where available (either flag spelling), else the
    experimental one."""
    if hasattr(jax, "shard_map"):
        # pick the spelling from the signature rather than retrying on
        # TypeError, which would misattribute unrelated TypeErrors
        params = inspect.signature(jax.shard_map).parameters
        flag = "check_vma" if "check_vma" in params else "check_rep"
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **{flag: check_vma})
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types when the runtime has them,
    degrading to a plain device-grid ``Mesh`` on older versions."""
    try:
        auto = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=auto)
    except (AttributeError, TypeError):
        pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    n = int(np.prod(axis_shapes))
    devs = np.asarray(jax.devices()[:n]).reshape(axis_shapes)
    return jax.sharding.Mesh(devs, axis_names)
