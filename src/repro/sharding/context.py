"""Ambient sharding context: lets model code drop divisibility-guarded
``with_sharding_constraint``s without threading a mesh through every call.

Launchers (dryrun / train / serve) wrap tracing in ``sharding_context(mesh)``;
smoke tests and single-device runs never set it, so ``constrain`` is a no-op
there.  This is what anchors GSPMD propagation through the scan/transpose
heavy attention and SSD paths (without it, XLA replicates the batch).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import logical_axes, shard_if_divisible

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_shard_ctx",
                                                      default=None)


@contextlib.contextmanager
def sharding_context(mesh):
    token = _CTX.set({"mesh": mesh, "axes": logical_axes(mesh)})
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh():
    ctx = _CTX.get()
    return None if ctx is None else ctx["mesh"]


def _resolve(ctx, name: Optional[str]) -> Optional[Tuple[str, ...]]:
    if name is None:
        return None
    if name in ctx["axes"]:
        return ctx["axes"][name]
    mesh = ctx["mesh"]
    return (name,) if name in mesh.axis_names else None


def constrain(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """constrain(x, "dp", None, "tp") — logical names dp/fsdp/tp or raw mesh
    axis names; missing trailing dims are unconstrained; every entry is
    divisibility-guarded."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh = ctx["mesh"]
    spec = []
    for i in range(x.ndim):
        name = dims[i] if i < len(dims) else None
        axes = _resolve(ctx, name)
        spec.append(shard_if_divisible(mesh, x.shape[i], axes)
                    if axes else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
