"""Logical-axis -> PartitionSpec rules for both production meshes.

The mapping is the TPU realization of DEAL's collaborative partition:
tokens/batch ("graph rows") shard over ``data`` (P) and features/heads/
experts ("feature columns") shard over ``model`` (M); on the 2-pod mesh the
``pod`` axis joins the data-parallel group and the FSDP group.

Every rule is divisibility-guarded: a dimension that does not divide evenly
over its assigned mesh axes is left unsharded (e.g. whisper's 51865 vocab).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


def logical_axes(mesh) -> Dict[str, Tuple[str, ...]]:
    """dp / fsdp / tp mesh-axis groups for a production mesh."""
    names = mesh.axis_names
    if "pod" in names:
        return {"dp": ("pod", "data"), "fsdp": ("pod", "data"),
                "tp": ("model",)}
    return {"dp": ("data",), "fsdp": ("data",), "tp": ("model",)}


def _axis_size(mesh, axes: Tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def shard_if_divisible(mesh, dim: int, axes: Optional[Tuple[str, ...]]):
    """Return the axes (for a PartitionSpec entry) iff dim divides evenly."""
    if axes is None:
        return None
    if dim % _axis_size(mesh, axes) == 0:
        return axes if len(axes) > 1 else axes[0]
    return None


# ----------------------------------------------------------------------
# parameters
# ----------------------------------------------------------------------

_COL_PARALLEL = {  # (in, out) -> (fsdp, tp): contract dim fsdp, out dim tp
    "wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_xz", "w_bc", "w_dt",
    "wq_a", "wq_b", "wkv_a", "wkv_b", "a_q", "a_k", "a_v", "router",
    "projector", "shared_w_gate", "shared_w_up", "lm_head",
}
_ROW_PARALLEL = {  # (in, out) -> (tp, fsdp): contract dim tp, out dim fsdp
    "wo", "w_down", "w_out", "shared_w_down",
}
_MOE_EXPERT = {"w_gate", "w_up", "w_down"}  # with a leading E dim
_LORA_B = {"b_q", "b_k", "b_v"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _in_moe(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and e.key == "moe"
               for e in path)


def param_specs(cfg: ModelConfig, abstract: Any, mesh):
    """PartitionSpec pytree matching ``abstract_params(cfg)``.

    With REPRO_TUNING=serve_tp the FSDP dim is left unsharded (weights
    replicated over `data`, sharded over `model` only) — the serving
    profile for models whose tp-sharded weights fit one chip: decode then
    pays tiny activation psums instead of per-layer param all-gathers
    (§Perf H4)."""
    from repro import tuning
    ax = logical_axes(mesh)
    fsdp, tp = ax["fsdp"], ax["tp"]
    if tuning.on("serve_tp"):
        fsdp = None

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        lead = (None,) * max(nd - 2, 0)
        if name == "embed":
            return P(shard_if_divisible(mesh, shape[0], tp),
                     shard_if_divisible(mesh, shape[1], fsdp))
        if name in _MOE_EXPERT and _in_moe(path) and nd >= 3:
            lead = (None,) * (nd - 3)
            e, d1, d2 = shape[-3:]
            if name == "w_down":   # (E, F, D)
                return P(*lead, shard_if_divisible(mesh, e, tp), None,
                         shard_if_divisible(mesh, d2, fsdp))
            return P(*lead, shard_if_divisible(mesh, e, tp),
                     shard_if_divisible(mesh, d1, fsdp), None)
        if name in _COL_PARALLEL and nd >= 2:
            return P(*lead, shard_if_divisible(mesh, shape[-2], fsdp),
                     shard_if_divisible(mesh, shape[-1], tp))
        if name in _ROW_PARALLEL and nd >= 2:
            return P(*lead, shard_if_divisible(mesh, shape[-2], tp),
                     shard_if_divisible(mesh, shape[-1], fsdp))
        if name in _LORA_B and nd >= 2:
            return P(*lead, None, shard_if_divisible(mesh, shape[-1], tp))
        if name == "conv" and nd >= 2:
            return P(*lead, None, shard_if_divisible(mesh, shape[-1], tp))
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(rule, abstract)


# ----------------------------------------------------------------------
# caches & batches
# ----------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, abstract_cache: Any, mesh,
                shape: InputShape):
    """KV/state cache PartitionSpecs.  batch==1 -> shard the sequence."""
    ax = logical_axes(mesh)
    dp, tp = ax["dp"], ax["tp"]
    seq_shard = shape.global_batch == 1

    def rule(path, leaf):
        name = _leaf_name(path)
        shape_ = leaf.shape
        nd = len(shape_)
        if name in ("k", "v", "cross_k", "cross_v"):
            from repro import tuning
            lead = (None,) * (nd - 4)
            b, s, k, hd = shape_[-4:]
            if seq_shard:
                return P(*lead, None,
                         shard_if_divisible(mesh, s, ("data",)), None,
                         shard_if_divisible(mesh, hd, tp))
            # H4-iter2 (gqa_cache_seq): shard the cache SEQUENCE over
            # `model` so decode scores stay shard-local (partial softmax);
            # baseline shards head_dim, which psums (B,H,S) scores/layer.
            if tuning.on("gqa_cache_seq"):
                return P(*lead, shard_if_divisible(mesh, b, dp),
                         shard_if_divisible(mesh, s, tp), None, None)
            return P(*lead, shard_if_divisible(mesh, b, dp), None, None,
                     shard_if_divisible(mesh, hd, tp))
        if name in ("c_kv", "k_rope", "first_c_kv", "first_k_rope"):
            from repro import tuning
            lead = (None,) * (nd - 3)
            b, s, r = shape_[-3:]
            if seq_shard:
                return P(*lead, None,
                         shard_if_divisible(mesh, s, ("data",)),
                         shard_if_divisible(mesh, r, tp))
            # H1 (mla_cache_seq): shard the cache SEQUENCE over `model`.
            # Baseline shards the latent r over tp, which makes absorbed-MLA
            # scores psum a (B,H,S) tensor per layer; sequence sharding
            # keeps scores local and only psums the (B,H,r) attention
            # output + softmax partials (context parallelism over tp).
            if tuning.on("mla_cache_seq"):
                return P(*lead, shard_if_divisible(mesh, b, dp),
                         shard_if_divisible(mesh, s, tp), None)
            return P(*lead, shard_if_divisible(mesh, b, dp), None,
                     shard_if_divisible(mesh, r, tp))
        if name == "conv":          # SSM conv window (..., B, W, C)
            lead = (None,) * (nd - 3)
            b, w, c = shape_[-3:]
            return P(*lead, shard_if_divisible(mesh, b, dp), None,
                     shard_if_divisible(mesh, c, tp))
        if name == "state":         # SSM state (..., B, H, N, Pdim)
            lead = (None,) * (nd - 4)
            b, h, n, pd = shape_[-4:]
            return P(*lead, shard_if_divisible(mesh, b, dp),
                     shard_if_divisible(mesh, h, tp), None, None)
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


def batch_specs(cfg: ModelConfig, batch_abstract: Any, mesh,
                shape: InputShape):
    ax = logical_axes(mesh)
    dp = ax["dp"]

    def rule(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        b = leaf.shape[0]
        return P(shard_if_divisible(mesh, b, dp), *((None,) * (nd - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_abstract)
