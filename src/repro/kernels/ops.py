"""Dispatching wrappers for the Pallas kernels.

On TPU the kernels run compiled (interpret=False); everywhere else they run
in interpret mode (correct, slow) or fall back to the jnp oracle — the
backend is detected once.  This is the layer models/benchmarks import.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gat_attention import gat_attention as _gat_attention
from repro.kernels.gather_spmm import gather_spmm as _gather_spmm
from repro.kernels.sddmm import sddmm as _sddmm
from repro.kernels.spmm import spmm as _spmm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spmm(h, w, nbr, mask, use_kernel: bool = False, **kw):
    if _on_tpu():
        return _spmm(h, w, nbr, mask, interpret=False, **kw)
    if use_kernel:
        return _spmm(h, w, nbr, mask, interpret=True, **kw)
    return ref.spmm_ref(h, w, nbr, mask)


def gather_spmm(h, table, w, nbr, mask, use_kernel: bool = False, **kw):
    if _on_tpu():
        return _gather_spmm(h, table, w, nbr, mask, interpret=False, **kw)
    if use_kernel:
        return _gather_spmm(h, table, w, nbr, mask, interpret=True, **kw)
    return ref.gather_spmm_ref(h, table, w, nbr, mask)


def gat_attention(q, k, nbr, mask, heads: int = 1, use_kernel: bool = False,
                  **kw):
    if _on_tpu():
        return _gat_attention(q, k, nbr, mask, heads=heads, interpret=False,
                              **kw)
    if use_kernel:
        return _gat_attention(q, k, nbr, mask, heads=heads, interpret=True,
                              **kw)
    return ref.gat_attention_ref(q, k, nbr, mask, heads)


def sddmm(q, k, nbr, mask, use_kernel: bool = False, **kw):
    if _on_tpu():
        return _sddmm(q, k, nbr, mask, interpret=False, **kw)
    if use_kernel:
        return _sddmm(q, k, nbr, mask, interpret=True, **kw)
    return ref.sddmm_ref(q, k, nbr, mask)


def flash_attention(q, k, v, causal: bool = True, use_kernel: bool = False,
                    **kw):
    if _on_tpu():
        return _flash(q, k, v, causal=causal, interpret=False, **kw)
    if use_kernel:
        return _flash(q, k, v, causal=causal, interpret=True, **kw)
    return ref.flash_attention_ref(q, k, v, causal=causal)
