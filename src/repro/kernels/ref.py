"""Pure-jnp reference primitives — the ONE canonical definition.

These serve double duty: they are the allclose targets for the Pallas
kernels AND the math behind ``core.ops.RefExecutor`` (the single-host
oracle engine).  ``core.primitives`` re-exports them under the ``ref_*``
names, so the oracle cannot drift between the kernel tests and the
inference engines.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gemm_ref(h, w):
    """out = h @ w, accumulated in f32, cast back to h.dtype."""
    return jnp.dot(h, w, preferred_element_type=jnp.float32).astype(h.dtype)


def spmm_ref(h, w, nbr, mask):
    """out[i] = sum_f w[i,f] * mask[i,f] * h[nbr[i,f]].  h:(N,D) nbr:(N,F)."""
    vals = jnp.take(h, nbr.reshape(-1), axis=0).astype(jnp.float32)
    vals = vals.reshape(nbr.shape + (h.shape[-1],))
    coef = (w * mask).astype(jnp.float32)[..., None]
    return (vals * coef).sum(axis=1).astype(h.dtype)


def sddmm_ref(q, k, nbr, mask):
    """e[i,f] = <q[i], k[nbr[i,f]]> * mask[i,f].  q,k:(N,D)."""
    vals = jnp.take(k, nbr.reshape(-1), axis=0).reshape(
        nbr.shape + (k.shape[-1],)).astype(jnp.float32)
    out = (q[:, None, :].astype(jnp.float32) * vals).sum(-1)
    return (out * mask).astype(jnp.float32)


def gather_spmm_ref(h, table, w, nbr, mask):
    """out[i] = sum_f w[i,f] * mask[i,f] * h[table[nbr[i,f]]].

    The fused-gather SPMM oracle: ``nbr`` carries UNTRANSLATED ids (global
    node ids, loader-order ids, ...) and ``table`` maps them onto rows of
    ``h`` — the indirection the Deal §3.5 fusion pushes into layer-1's
    gather instead of materializing ``h[table]``.  Resolving the ids and
    calling ``spmm_ref`` is bitwise-identical to gathering from a
    materialized reorder, because the per-row reductions see the same
    values in the same order.  Masked slots may map anywhere in-range:
    their coefficient is exactly 0.0 and adding 0.0 is exact.
    """
    idx = jnp.take(jnp.asarray(table), nbr.reshape(-1)).reshape(nbr.shape)
    return spmm_ref(h, w, idx, mask)


def gat_attention_ref(q, k, nbr, mask, heads: int):
    """Fused GAT edge attention oracle: per-head scaled dot scores +
    masked edge softmax in one pass — alpha (N, F, heads) f32.

    Matches ``gnn_models.gat_head_scores`` -> ``masked_softmax``
    op-for-op (same f32 dot, same /sqrt(dh), same -1e30 fill, same
    softmax), so the fused Pallas kernel and the unfused two-op spec
    path verify against the same math.
    """
    N, D = q.shape
    dh = D // heads
    qh = q.reshape(N, heads, dh).astype(jnp.float32)
    kh = k.reshape(-1, heads, dh).astype(jnp.float32)
    kn = jnp.take(kh, nbr.reshape(-1), axis=0).reshape(
        nbr.shape + (heads, dh))
    s = jnp.einsum("nhd,nfhd->nfh", qh, kn) / jnp.sqrt(jnp.float32(dh))
    m = mask[:, :, None]
    p = jax.nn.softmax(jnp.where(m, s, -1e30), axis=1)
    return p * m


def flash_attention_ref(q, k, v, *, causal=True):
    """q:(BH,Sq,hd) k,v:(BH,Skv,hd) — plain softmax attention, f32."""
    BH, Sq, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bsd->bqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        m = jnp.arange(k.shape[1])[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqs,bsd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
