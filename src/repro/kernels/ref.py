"""Pure-jnp reference primitives — the ONE canonical definition.

These serve double duty: they are the allclose targets for the Pallas
kernels AND the math behind ``core.ops.RefExecutor`` (the single-host
oracle engine).  ``core.primitives`` re-exports them under the ``ref_*``
names, so the oracle cannot drift between the kernel tests and the
inference engines.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gemm_ref(h, w):
    """out = h @ w, accumulated in f32, cast back to h.dtype."""
    return jnp.dot(h, w, preferred_element_type=jnp.float32).astype(h.dtype)


def spmm_ref(h, w, nbr, mask):
    """out[i] = sum_f w[i,f] * mask[i,f] * h[nbr[i,f]].  h:(N,D) nbr:(N,F)."""
    vals = jnp.take(h, nbr.reshape(-1), axis=0).astype(jnp.float32)
    vals = vals.reshape(nbr.shape + (h.shape[-1],))
    coef = (w * mask).astype(jnp.float32)[..., None]
    return (vals * coef).sum(axis=1).astype(h.dtype)


def sddmm_ref(q, k, nbr, mask):
    """e[i,f] = <q[i], k[nbr[i,f]]> * mask[i,f].  q,k:(N,D)."""
    vals = jnp.take(k, nbr.reshape(-1), axis=0).reshape(
        nbr.shape + (k.shape[-1],)).astype(jnp.float32)
    out = (q[:, None, :].astype(jnp.float32) * vals).sum(-1)
    return (out * mask).astype(jnp.float32)


def flash_attention_ref(q, k, v, *, causal=True):
    """q:(BH,Sq,hd) k,v:(BH,Skv,hd) — plain softmax attention, f32."""
    BH, Sq, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bsd->bqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        m = jnp.arange(k.shape[1])[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqs,bsd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
