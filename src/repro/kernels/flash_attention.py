"""Pallas TPU kernel: flash attention (online softmax, VMEM-tiled).

Grid (batch*heads, q_blocks); each step holds one (bq, hd) query tile and
streams (bk, hd) key/value tiles through VMEM with the usual running
(m, l, acc) rescaling.  Block sizes default to MXU-aligned 128 multiples.
This is the TPU twin of models/attention.flash_attention_jnp (the jnp
version drives the production models; tests assert the two agree and both
match ref.flash_attention_ref).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int,
                  block_k: int, seq_kv: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    nk = seq_kv // block_k

    def body(ik, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(ik * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ik * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                   # (bq, bk)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kv_pos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kv_pos <= q_pos, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd).  Sq % block_q == 0 etc."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    scale = 1.0 / math.sqrt(hd)
    grid = (BH, Sq // block_q)
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          seq_kv=Skv, causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Skv, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Skv, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
