"""Pallas TPU kernel: fused index-gather + SPMM (Deal §3.5, Fig 13).

The plain ``spmm`` kernel assumes its neighbor ids index the feature
table directly.  Real pipelines rarely have that luxury: the feature
loader leaves rows in file order (§3.5 feature preparation) and delta
refresh gathers a compacted universe of rows (``gnnserve.delta``), so
both paths historically materialized a reordered copy — ``rows[table]``
in ``feature_prep.fused_load``, a dense ``searchsorted`` remap of every
neighbor matrix in ``delta``.  This kernel consumes the feature table
AND the row-index table directly:

    out[i] = sum_f w[i,f] * mask[i,f] * h[table[nbr[i,f]]]

i.e. the reorder disappears into layer-1's gather: one extra scalar
load per edge (the table entry) replaces an (N, D) HBM round-trip.
``nbr``/``w`` tiles are staged per node block; ``h`` and ``table`` stay
HBM-resident (memory_space ANY) and are gathered per edge — on real TPU
these become scalar-prefetch-driven DMAs.  Validated with
interpret=True against ``ref.gather_spmm_ref`` (which is itself bitwise
equal to ``spmm_ref`` over a materialized reorder).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spmm import auto_block_n


def _gather_spmm_kernel(nbr_ref, w_ref, table_ref, h_ref, o_ref, *,
                        block_d: int, fanout: int, block_n: int):
    j = pl.program_id(1)
    d0 = j * block_d

    def body(i, acc):
        r = i // fanout
        f = i % fanout
        gid = nbr_ref[r, f]
        idx = table_ref[pl.dslice(gid, 1)][0]        # fused indirection
        coef = w_ref[r, f].astype(jnp.float32)
        row = h_ref[pl.dslice(idx, 1), pl.dslice(d0, block_d)]   # (1, bd)
        return acc.at[r].add(coef * row[0].astype(jnp.float32))

    acc = jnp.zeros((block_n, block_d), jnp.float32)
    acc = jax.lax.fori_loop(0, block_n * fanout, body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret"))
def gather_spmm(h, table, w, nbr, mask, *, block_n: int = None,
                block_d: int = 128, interpret: bool = True):
    """out[i] = sum_f w[i,f]*mask[i,f]*h[table[nbr[i,f]]].

    h: (U, D) source-row table in ARBITRARY order; table: (N,) int map
    from the id space ``nbr`` uses onto h's rows; w/mask/nbr: (R, F).
    Same R/U decoupling as ``spmm`` (row-subset mode), with the id
    translation fused into the gather.  R % block_n == 0,
    D % block_d == 0; masked slots may map anywhere in-range (their
    coefficient is 0.0 exactly).
    """
    U, D = h.shape
    R, F = nbr.shape
    if block_n is None:
        block_n = auto_block_n(R)
    assert R % block_n == 0 and D % block_d == 0, (R, D, block_n, block_d)
    wm = (w * mask).astype(h.dtype)
    grid = (R // block_n, D // block_d)
    return pl.pallas_call(
        functools.partial(_gather_spmm_kernel, block_d=block_d, fanout=F,
                          block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, D), h.dtype),
        interpret=interpret,
    )(nbr, wm, jnp.asarray(table, jnp.int32), h)
