"""Pallas TPU kernel: fused SDDMM + masked edge softmax (GAT attention).

The unfused GAT spec runs one SDDMM kernel call PER HEAD (a Python loop
round-tripping each (N, F) score slice through HBM), stacks the slices,
scales, and then runs a separate masked-softmax op.  This kernel
produces the normalized attention alpha (N, F, heads) in ONE pass per
node block: gather each edge's k row once, compute ALL heads' scaled
dot scores into VMEM registers, and normalize over the fanout axis
before anything is written back — the score tensor never exists in HBM.

q/nbr/mask tiles are staged per node block; k stays HBM-resident
(memory_space ANY) and is gathered per edge.  The math is op-for-op
``ref.gat_attention_ref`` (same f32 dots, same /sqrt(dh), same -1e30
masked fill and softmax), so fused and unfused paths verify against the
same oracle.  Validated with interpret=True; compiled on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spmm import auto_block_n


def _gat_attention_kernel(nbr_ref, mask_ref, q_ref, k_ref, o_ref, *,
                          fanout: int, block_n: int, heads: int):
    D = q_ref.shape[1]
    dh = D // heads

    def body(i, acc):
        r = i // fanout
        f = i % fanout
        idx = nbr_ref[r, f]
        krow = k_ref[pl.dslice(idx, 1), :][0].astype(jnp.float32)  # (D,)
        qrow = q_ref[r].astype(jnp.float32)
        dots = jnp.sum(qrow.reshape(heads, dh) * krow.reshape(heads, dh),
                       axis=1)                                     # (H,)
        return acc.at[r, f].set(dots)

    acc = jnp.zeros((block_n, fanout, heads), jnp.float32)
    acc = jax.lax.fori_loop(0, block_n * fanout, body, acc)
    s = acc / jnp.sqrt(jnp.float32(dh))
    m = (mask_ref[...] > 0)[:, :, None]                # (bn, F, 1)
    p = jax.nn.softmax(jnp.where(m, s, -1e30), axis=1)
    o_ref[...] = p * m


@functools.partial(jax.jit, static_argnames=("heads", "block_n",
                                             "interpret"))
def gat_attention(q, k, nbr, mask, *, heads: int = 1, block_n: int = None,
                  interpret: bool = True):
    """alpha[i,f,h] = edge_softmax_f(<q_h[i], k_h[nbr[i,f]]>/sqrt(dh)).

    q: (N, D) head-major; k: (U, D) source table (U and N decouple for
    row-subset execution); nbr, mask: (N, F).  Returns the NORMALIZED
    per-head attention (N, F, heads) f32 — scores and softmax fused, no
    HBM round-trip of the score tensor.  N % block_n == 0,
    D % heads == 0.
    """
    N, D = q.shape
    F = nbr.shape[1]
    assert D % heads == 0, (D, heads)
    if block_n is None:
        block_n = auto_block_n(N)
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    return pl.pallas_call(
        functools.partial(_gat_attention_kernel, fanout=F, block_n=block_n,
                          heads=heads),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i: (i, 0)),
            pl.BlockSpec((block_n, F), lambda i: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_n, F, heads), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, F, heads), jnp.float32),
        interpret=interpret,
    )(nbr, mask.astype(q.dtype), q, k)
