"""Pallas TPU kernel: fanout-gather SPMM (the layer-graph aggregation).

The layer graphs of DEAL's all-node inference are fixed-fanout neighbor
matrices, so SPMM becomes "gather F rows per node, weighted-sum" — a
regular access pattern we tile as (node-block x feature-block) with the
neighbor/weight tiles staged in VMEM and the (potentially huge) feature
table left in HBM-resident memory, gathered row-by-row.

BlockSpecs: nbr/w blocked (bn, F) per node tile; out (bn, bd) per
(node, feature) tile; h un-blocked (memory_space ANY).  On real TPU the
row gathers become scalar-prefetch-driven DMAs; in this repo the kernel is
validated with interpret=True against ref.spmm_ref (tests sweep shapes and
dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def auto_block_n(n: int) -> int:
    """Largest power-of-two node-block (<=64) that tiles n exactly.

    The kernels grid over n // block_n, so block_n must divide n; callers
    pad n to a multiple of 8 first (f32 sublane tile), which this floors
    to.  Shared by spmm / sddmm / gather_spmm / gat_attention as the
    default when no tuned block table overrides it.
    """
    for bn in (64, 32, 16, 8):
        if n % bn == 0:
            return bn
    for bn in (4, 2, 1):
        if n % bn == 0:
            return bn
    return 1


def _spmm_kernel(nbr_ref, w_ref, h_ref, o_ref, *, block_d: int,
                 fanout: int, block_n: int):
    j = pl.program_id(1)
    d0 = j * block_d

    def body(i, acc):
        r = i // fanout
        f = i % fanout
        idx = nbr_ref[r, f]
        coef = w_ref[r, f].astype(jnp.float32)
        row = h_ref[pl.dslice(idx, 1), pl.dslice(d0, block_d)]   # (1, bd)
        return acc.at[r].add(coef * row[0].astype(jnp.float32))

    acc = jnp.zeros((block_n, block_d), jnp.float32)
    acc = jax.lax.fori_loop(0, block_n * fanout, body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret"))
def spmm(h, w, nbr, mask, *, block_n: int = None, block_d: int = 128,
         interpret: bool = True):
    """out[i] = sum_f w[i,f]*mask[i,f]*h[nbr[i,f]].

    h: (N, D) source-row table; w/mask/nbr: (R, F).  The output has R rows
    — R and N are decoupled so the layer-op executors can gather from a
    universe table while producing only the target rows (row-subset mode).
    R % block_n == 0 (block_n=None picks the largest divisor <=64),
    D % block_d == 0.
    """
    N, D = h.shape
    R, F = nbr.shape
    if block_n is None:
        block_n = auto_block_n(R)
    assert R % block_n == 0 and D % block_d == 0, (R, D, block_n, block_d)
    wm = (w * mask).astype(h.dtype)
    grid = (R // block_n, D // block_d)
    return pl.pallas_call(
        functools.partial(_spmm_kernel, block_d=block_d, fanout=F,
                          block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, D), h.dtype),
        interpret=interpret,
    )(nbr, wm, h)
