"""Pallas TPU kernel: SDDMM over fanout neighbor matrices (GAT scoring).

e[i, f] = <q[i], k[nbr[i, f]]> — sampled dense-dense products where the
sparsity pattern is the fixed-fanout layer graph.  q is tiled (bn, D) in
VMEM; k stays HBM-resident and is gathered per edge; the (bn, F) score tile
is produced per grid step.  Validated in interpret mode vs ref.sddmm_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spmm import auto_block_n


def _sddmm_kernel(nbr_ref, mask_ref, q_ref, k_ref, o_ref, *, fanout: int,
                  block_n: int):
    def body(i, acc):
        r = i // fanout
        f = i % fanout
        idx = nbr_ref[r, f]
        row = k_ref[pl.dslice(idx, 1), :]  # (1, D)
        dot = jnp.sum(q_ref[r].astype(jnp.float32)
                      * row[0].astype(jnp.float32))
        return acc.at[r, f].set(dot * mask_ref[r, f].astype(jnp.float32))

    acc = jnp.zeros((block_n, fanout), jnp.float32)
    acc = jax.lax.fori_loop(0, block_n * fanout, body, acc)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sddmm(q, k, nbr, mask, *, block_n: int = None, interpret: bool = True):
    """q: (N, D); k: (U, D) source table; nbr, mask: (N, F) with ids into
    k's rows (U and N decouple for row-subset execution).  Returns (N, F)
    f32 scores.  block_n=None picks the largest divisor of N <=64 —
    the old fixed block_n=8 launched 8x more grid steps than needed on
    typical pow2-padded row counts."""
    N, D = q.shape
    F = nbr.shape[1]
    if block_n is None:
        block_n = auto_block_n(N)
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    return pl.pallas_call(
        functools.partial(_sddmm_kernel, fanout=F, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i: (i, 0)),
            pl.BlockSpec((block_n, F), lambda i: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_n, F), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, F), jnp.float32),
        interpret=interpret,
    )(nbr, mask.astype(q.dtype), q, k)
