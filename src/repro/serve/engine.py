"""Batched serving engine: fixed-slot continuous batching.

Each of B cache slots holds one request.  Per-slot positions (the (B,)
``pos`` vector of serve_step) let slots sit at different sequence lengths —
new requests are admitted into free slots while others keep decoding, the
continuous-batching pattern.  Admission replays the prompt through decode
steps (correctness-first; the vectorized prefill path is exercised by
examples/serve_llm.py and the dry-run).

Protocol per slot: ``pending`` is the token to feed next at ``next_pos``;
feeding it yields the logits that sample the following token.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.serve.step import serve_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_seq: int = 256, sample: str = "greedy", seed: int = 0):
        assert cfg.family not in ("audio", "vlm"), \
            "engine demo drives text decoders"
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.S = max_seq
        self.rng = np.random.default_rng(seed)
        self.sample = sample
        self.cache = transformer.init_cache(cfg, batch_slots, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.pending = np.zeros(batch_slots, np.int32)
        self.next_pos = np.zeros(batch_slots, np.int64)
        self._decode = jax.jit(lambda p, c, b: serve_step(cfg, p, c, b))
        self.queue: List[Request] = []
        self.n_decode_steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _step_tokens(self, token_vec: np.ndarray, pos_vec: np.ndarray):
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"token": jnp.asarray(token_vec[:, None]),
             "pos": jnp.asarray(pos_vec.astype(np.int32))})
        self.n_decode_steps += 1
        return np.asarray(logits)[:, 0]

    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            # replay prompt[:-1]; positions of other slots stay put (their
            # writes land at their own next_pos and are re-written on their
            # next real step, beyond their valid cache_len — harmless).
            for t, tok in enumerate(req.prompt[:-1]):
                token = self.pending.copy()
                token[slot] = tok
                pos = self.next_pos.copy()
                pos[slot] = t
                self._step_tokens(token, pos)
            self.slot_req[slot] = req
            self.pending[slot] = int(req.prompt[-1])
            self.next_pos[slot] = len(req.prompt) - 1

    def _pick(self, logits: np.ndarray) -> int:
        if self.sample == "greedy":
            return int(logits.argmax())
        logits = logits.astype(np.float64)
        p = np.exp(logits - logits.max())
        p /= p.sum()
        return int(self.rng.choice(logits.shape[-1], p=p))

    def step(self) -> bool:
        """One lock-step decode over all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        logits = self._step_tokens(self.pending.copy(),
                                   self.next_pos.copy())
        for i in active:
            r = self.slot_req[i]
            nxt = self._pick(logits[i])
            r.out_tokens.append(nxt)
            self.pending[i] = nxt
            self.next_pos[i] += 1
            if (len(r.out_tokens) >= r.max_new_tokens
                    or (r.eos_id is not None and nxt == r.eos_id)
                    or self.next_pos[i] >= self.S - 1):
                r.done = True
                self.slot_req[i] = None
                self.pending[i] = 0
                self.next_pos[i] = 0
        return True

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                return
