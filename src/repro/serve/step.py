"""prefill_step / serve_step — the inference entry points.

prefill: full-sequence forward, returns last-position logits + filled cache
(never materializes (B, S, V)).
serve_step (decode): ONE new token against a seq_len cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer


def prefill_step(cfg: ModelConfig, params, batch: Dict[str, Any]):
    hidden, _, cache = transformer.forward(
        cfg, params, batch, mode="prefill", return_cache=True,
        return_hidden=True, remat=False)
    last = hidden[:, -1:]
    head = (params["embed"].T.astype(jnp.dtype(cfg.dtype))
            if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", last, head).astype(jnp.float32)
    return logits, cache


def serve_step(cfg: ModelConfig, params, cache, batch: Dict[str, Any]):
    """batch = {"token": (B,1) int32, "pos": () int32}."""
    return transformer.decode_step(cfg, params, cache, batch)


def make_prefill_step(cfg: ModelConfig):
    return functools.partial(prefill_step, cfg)


def make_serve_step(cfg: ModelConfig):
    return functools.partial(serve_step, cfg)
