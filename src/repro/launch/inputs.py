"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the abstract batch for the step kind;
``step_arguments(cfg, shape, mesh, opt_cfg)`` returns (step_fn, abstract
args, in_shardings, donate) ready for jit().lower().
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer
from repro.serve.step import prefill_step, serve_step
from repro.sharding.specs import batch_specs, cache_specs, param_specs
from repro.train.optimizer import AdamWConfig, abstract_opt_state
from repro.train.step import train_step

F = jax.ShapeDtypeStruct


def _tok(*shape):
    return F(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract batch dict for this (arch, shape)."""
    B, S = shape.global_batch, shape.seq_len
    bf16 = jnp.bfloat16
    if shape.kind == "decode":
        return {"token": _tok(B, 1), "pos": F((), jnp.int32)}
    if cfg.family == "audio":
        enc = cfg.n_frontend_tokens
        d = {"frames": F((B, enc, cfg.frontend_dim), bf16),
             "tokens": _tok(B, S)}
        if shape.kind == "train":
            d["labels"] = _tok(B, S)
        return d
    if cfg.family == "vlm":
        n_img = cfg.n_frontend_tokens
        d = {"patches": F((B, n_img, cfg.frontend_dim), bf16),
             "tokens": _tok(B, S - n_img)}
        if shape.kind == "train":
            d["labels"] = _tok(B, S - n_img)
        return d
    d = {"tokens": _tok(B, S)}
    if shape.kind == "train":
        d["labels"] = _tok(B, S)
    return d


def _shardify(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _logits_spec(mesh, shape: InputShape, cfg) -> P:
    from repro.sharding.specs import logical_axes, shard_if_divisible
    ax = logical_axes(mesh)
    return P(shard_if_divisible(mesh, shape.global_batch, ax["dp"]), None,
             shard_if_divisible(mesh, cfg.vocab_size, ax["tp"]))


_METRIC_KEYS = ("grad_norm", "lr", "loss", "aux_loss", "total_loss")


def step_arguments(cfg: ModelConfig, shape: InputShape, mesh,
                   opt_cfg: AdamWConfig | None = None
                   ) -> Tuple[Any, tuple, Any, Any, tuple]:
    """Build (step_fn, abstract_args, in_shardings, out_shardings, donate)."""
    opt_cfg = opt_cfg or AdamWConfig(
        state_dtype="bfloat16" if cfg.param_count() > 1e11 else "float32")
    params_abs = transformer.abstract_params(cfg)
    pspec = param_specs(cfg, params_abs, mesh)
    batch_abs = input_specs(cfg, shape)
    bspec = batch_specs(cfg, batch_abs, mesh, shape)

    if shape.kind == "train":
        opt_abs = abstract_opt_state(params_abs, opt_cfg)
        ospec = type(opt_abs)(step=P(), m=pspec, v=pspec)
        fn = functools.partial(train_step, cfg, opt_cfg)
        args = (params_abs, opt_abs, batch_abs)
        shardings = (_shardify(mesh, pspec), _shardify(mesh, ospec),
                     _shardify(mesh, bspec))
        metrics_shard = {k: NamedSharding(mesh, P()) for k in _METRIC_KEYS}
        out_shardings = (shardings[0], shardings[1], metrics_shard)
        return fn, args, shardings, out_shardings, (0, 1)

    enc_len = cfg.n_frontend_tokens if cfg.family == "audio" else None
    cache_abs = transformer.abstract_cache(cfg, shape.global_batch,
                                           shape.seq_len, enc_len)
    cspec = cache_specs(cfg, cache_abs, mesh, shape)
    lspec = NamedSharding(mesh, _logits_spec(mesh, shape, cfg))

    if shape.kind == "prefill":
        fn = functools.partial(prefill_step, cfg)
        args = (params_abs, batch_abs)
        shardings = (_shardify(mesh, pspec), _shardify(mesh, bspec))
        out_shardings = (lspec, _shardify(mesh, cspec))
        return fn, args, shardings, out_shardings, ()

    # decode
    fn = functools.partial(serve_step, cfg)
    args = (params_abs, cache_abs, batch_abs)
    shardings = (_shardify(mesh, pspec), _shardify(mesh, cspec),
                 _shardify(mesh, bspec))
    out_shardings = (lspec, _shardify(mesh, cspec))
    return fn, args, shardings, out_shardings, (1,)
