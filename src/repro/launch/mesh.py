"""Production meshes.  Functions only — importing this never touches jax
device state; callers (dryrun) are responsible for the 512-device env."""
from __future__ import annotations

from repro.sharding.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over host devices (tests / benchmarks subprocesses)."""
    return make_mesh((n_data, n_model), ("data", "model"))
