"""DEAL end-to-end GNN inference launcher (the paper's pipeline, Fig 2).

Stages: edge list -> distributed CSR construction -> layer-wise 1-hop
sampling -> 1-D + feature collaborative partition -> distributed
layer-by-layer inference for ALL nodes.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.infer_gnn \
      --dataset ogbn-products --model gcn --p 4 --m 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.graph import csr_from_edges_distributed, make_dataset
from repro.core.gnn_models import init_gat, init_gcn
from repro.core.layerwise import LOCAL_ENGINES, DistributedLayerwise
from repro.core.sampler import sample_layer_graphs
from repro.launch.mesh import make_host_mesh


def run(dataset: str, model: str = "gcn", p: int = 2, m: int = 1,
        fanout: int = 8, n_layers: int = 3, d_feature: int = 64,
        seed: int = 0, distributed: bool = True, executor: str = "dist"):
    """``executor`` selects the backend: "dist" (mesh, needs p*m
    devices), "ref" (single-host jnp oracle) or "pallas" (the Pallas
    kernels, compiled on TPU / interpret elsewhere)."""
    if executor == "dist" and (not distributed or p * m <= 1):
        executor = "ref"                # no mesh to run on — jnp oracle
    t0 = time.time()
    src, dst, n = make_dataset(dataset, seed=seed)
    g, cstats = csr_from_edges_distributed(src, dst, n, n_workers=p)
    t_build = time.time() - t0
    print(f"[construct] {n} nodes, {g.n_edges} edges in {t_build:.2f}s "
          f"(exchange {cstats['exchanged_bytes']/1e6:.1f} MB)")

    t1 = time.time()
    lgs = sample_layer_graphs(g, fanout=fanout, n_layers=n_layers,
                              seed=seed)
    print(f"[sample] {n_layers} layer graphs, fanout {fanout} "
          f"in {time.time()-t1:.2f}s")

    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d_feature), dtype=np.float32)
    dims = [d_feature] * (n_layers + 1)
    key = jax.random.PRNGKey(seed)
    params = (init_gcn(key, dims) if model == "gcn"
              else init_gat(key, dims, heads=1))

    t2 = time.time()
    if executor == "dist":
        if len(jax.devices()) < p * m:
            raise SystemExit(
                f"need {p*m} devices; run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={p*m}")
        mesh = make_host_mesh(p, m)
        eng = DistributedLayerwise(mesh, lgs, model, params)
        H = np.asarray(eng.infer(X))
    else:
        H = np.asarray(LOCAL_ENGINES[model](lgs, X, params,
                                            executor=executor))
    t_inf = time.time() - t2
    assert not np.isnan(H).any()
    print(f"[infer] embeddings {H.shape} for ALL nodes in {t_inf:.2f}s "
          f"({g.n_edges/max(t_inf,1e-9)/1e6:.2f} M edges/s, "
          f"executor={executor})")
    return H


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--model", default="gcn", choices=["gcn", "gat", "sage"])
    ap.add_argument("--p", type=int, default=2, help="graph partitions")
    ap.add_argument("--m", type=int, default=1, help="feature partitions")
    ap.add_argument("--fanout", type=int, default=8)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--executor", default="dist",
                    choices=["ref", "pallas", "dist"],
                    help="backend: dist mesh / ref jnp / pallas kernels")
    args = ap.parse_args()
    run(args.dataset, args.model, args.p, args.m, fanout=args.fanout,
        n_layers=args.layers, distributed=not args.local,
        executor=args.executor)


if __name__ == "__main__":
    main()
