"""DEAL end-to-end GNN inference launcher (the paper's pipeline, Fig 2).

A THIN CLIENT of the public API: argparse -> ``DealConfig`` ->
``api.Session`` (which owns construction, sampling, partitioning,
executor selection).  Every run is reproducible from one JSON artifact:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.infer_gnn \
      --dataset ogbn-products --model gcn --p 4 --m 2

  # dump the effective config, then reproduce the run from it alone
  python -m repro.launch.infer_gnn --model gat --dump-config run.json
  python -m repro.launch.infer_gnn --config run.json
"""
from __future__ import annotations

import argparse

from repro.api import (ConfigError, DealConfig, ExecutorSpec, GraphSpec,
                       ModelSpec, PartitionSpec, Session)


def _run_session(cfg: DealConfig):
    try:
        s = Session.build(cfg)
    except ConfigError as e:
        raise SystemExit(str(e))
    cs = s.construct_stats
    print(f"[construct] {s.n_nodes} nodes, {s.graph.n_edges} edges in "
          f"{s.timings['construct_s']:.2f}s "
          f"(exchange {cs['exchanged_bytes']/1e6:.1f} MB)")
    print(f"[sample] {cfg.model.n_layers} layer graphs, "
          f"fanout {cfg.graph.fanout} in {s.timings['sample_s']:.2f}s")
    H = s.infer_all()
    t_inf = s.timings["infer_s"]
    print(f"[infer] embeddings {H.shape} for ALL nodes in {t_inf:.2f}s "
          f"({s.graph.n_edges/max(t_inf,1e-9)/1e6:.2f} M edges/s, "
          f"executor={s.executor.name})")
    return H


def run(dataset: str, model: str = "gcn", p: int = 2, m: int = 1,
        fanout: int = 8, n_layers: int = 3, d_feature: int = 64,
        seed: int = 0, distributed: bool = True, executor: str = "dist",
        scale: float = 1.0):
    """DEPRECATED shim — the pre-API entry point, kept for callers.
    Builds the equivalent ``DealConfig`` and delegates to ``Session``;
    outputs are bitwise-unchanged (tests/test_api.py proves it).
    ``executor`` selects the backend: "dist" (mesh, needs p*m devices;
    falls back to "ref" when the mesh is trivial), "ref" (single-host
    jnp oracle) or "pallas" (the Pallas kernels)."""
    if executor == "dist" and not distributed:
        executor = "ref"                # no mesh to run on — jnp oracle
    cfg = DealConfig(
        graph=GraphSpec(dataset=dataset, scale=scale, fanout=fanout,
                        seed=seed, n_construct_workers=p),
        model=ModelSpec(name=model, n_layers=n_layers,
                        d_feature=d_feature),
        partition=PartitionSpec(p=p, m=m),
        executor=ExecutorSpec(name=executor))
    return _run_session(cfg)


def config_from_args(args) -> DealConfig:
    executor = "ref" if (args.executor == "dist" and args.local) \
        else args.executor
    return DealConfig(
        graph=GraphSpec(dataset=args.dataset, scale=args.scale,
                        fanout=args.fanout, seed=args.seed,
                        n_construct_workers=args.p),
        model=ModelSpec(name=args.model, n_layers=args.layers,
                        d_feature=args.d_feature),
        partition=PartitionSpec(p=args.p, m=args.m),
        executor=ExecutorSpec(name=executor))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="CFG.json",
                    help="load the full DealConfig from a JSON artifact "
                         "(overrides every pipeline flag)")
    ap.add_argument("--dump-config", default=None, metavar="OUT.json",
                    help="write the effective DealConfig ('-' = stdout) "
                         "and exit without running")
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--model", default="gcn")
    ap.add_argument("--p", type=int, default=2, help="graph partitions")
    ap.add_argument("--m", type=int, default=1, help="feature partitions")
    ap.add_argument("--fanout", type=int, default=8)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--d-feature", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="scale the dataset's node count (CI smoke)")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--executor", default="dist",
                    help="backend: dist mesh / ref jnp / pallas kernels "
                         "(or any registered executor)")
    args = ap.parse_args()
    try:
        cfg = (DealConfig.load(args.config) if args.config
               else config_from_args(args))
        cfg.validate()
    except ConfigError as e:
        raise SystemExit(str(e))
    if args.dump_config:
        if args.dump_config == "-":
            print(cfg.to_json())
        else:
            cfg.dump(args.dump_config)
            print(f"[config] wrote {args.dump_config}")
        return
    _run_session(cfg)


if __name__ == "__main__":
    main()
