"""Serving launcher: batched requests through the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine


def run(arch: str, *, n_requests: int = 6, max_new: int = 16,
        batch_slots: int = 4, max_seq: int = 128, seed: int = 0,
        params=None, cfg=None):
    cfg = cfg or get_config(arch).reduced()
    params = (params if params is not None
              else transformer.init_params(cfg, jax.random.PRNGKey(seed)))
    eng = ServeEngine(cfg, params, batch_slots=batch_slots, max_seq=max_seq)
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        plen = int(rng.integers(3, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        r = Request(uid=uid, prompt=prompt, max_new_tokens=max_new)
        reqs.append(r)
        eng.submit(r)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"served {n_requests} requests, {total_new} tokens, "
          f"{eng.n_decode_steps} decode steps, {dt:.1f}s "
          f"({total_new/max(dt,1e-9):.1f} tok/s)")
    for r in reqs:
        assert r.done and len(r.out_tokens) > 0
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> "
              f"{r.out_tokens[:8]}{'...' if len(r.out_tokens) > 8 else ''}")
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    run(args.arch, n_requests=args.requests, max_new=args.max_new,
        batch_slots=args.slots)


if __name__ == "__main__":
    main()
