"""Online embedding service launcher (gnnserve end-to-end).

A THIN CLIENT of the public API: argparse -> ``DealConfig`` ->
``api.Session.serve()`` (which owns the offline epoch, the versioned
store with budget/eviction/onboarding, recompute-on-miss wiring, and
the continuous-batching engine with optional multi-tenant QoS).  The
driver loop here only generates traffic and prints stats.

  PYTHONPATH=src python -m repro.launch.serve_embeddings \
      --dataset ogbn-products --model gcn --ticks 50 \
      --mutations-per-tick 8 --staleness-bound 64

  # one JSON artifact reproduces the whole pipeline
  PYTHONPATH=src python -m repro.launch.serve_embeddings \
      --config configs/examples/smoke.json --ticks 5

``--executor dist`` runs the epoch AND every delta refresh through the
distributed executor (per-partition frontier split on a p x m mesh);
needs p*m devices, e.g.  XLA_FLAGS=--xla_force_host_platform_device_count=8.

``--budget-rows R --evict-policy {lru,heat}`` caps each evictable store
level at R resident rows (recompute-on-miss rebuilds evicted rows,
bitwise-equal to an unbudgeted store).

``--onboarding tail --nodes-per-tick K`` onboards K brand-new nodes per
tick through the tail-partition path: added nodes serve via delta
refresh (no re-partition) and fold into the main partitioning at the
next full epoch.

``--tenants "name:priority:slot_quota:rate:slo,..."`` turns on
multi-tenant QoS scheduling (``gnnserve.qos``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import obs
from repro.api import (ClusterSpec, ConfigError, DealConfig,
                       ExecutorSpec, GraphSpec, ModelSpec, PartitionSpec,
                       QoSSpec, RefreshSpec, Session, StoreSpec,
                       tenants_from_string)
from repro.gnnserve import EmbeddingServeEngine, Query, TenantRegistry


def _tenant_dicts(tenants: TenantRegistry):
    return tuple({"name": t.name, "priority": t.priority,
                  "slot_quota": t.slot_quota, "rate": t.rate,
                  "staleness_slo": t.staleness_slo} for t in tenants)


def _serve_session(cfg: DealConfig) -> Session:
    try:
        s = Session.build(cfg)
        eng = s.serve()
    except ConfigError as e:
        raise SystemExit(str(e))
    st = cfg.store
    print(f"[epoch0] {s.n_nodes} nodes x {cfg.model.n_layers} layers in "
          f"{s.timings['epoch_s']:.2f}s")
    if st.budget_rows:
        print(f"[budget] {st.budget_rows}/{s.n_nodes} rows per level "
              f"resident ({st.evict_policy} eviction, recompute-on-miss)")
    if st.onboarding == "tail":
        print("[onboard] node additions append a tail partition "
              "(delta-refresh served, folded at the next full epoch)")
    if eng.qos is not None:
        print("[qos] tenants: " + ", ".join(
            f"{t.name}(prio={t.priority:g} quota={t.slot_quota} "
            f"rate={t.rate:g} slo={t.staleness_slo})"
            for t in eng.qos.registry))
    if s.cluster is not None:
        print(f"[cluster] {cfg.cluster.n_shards} shard workers behind "
              f"the router (ready in {s.cluster.ready_wait_s:.2f}s, "
              f"run dir {s.cluster.run_dir})")
    return s


def build_service(dataset: str, model: str, *, fanout: int = 8,
                  n_layers: int = 3, d_feature: int = 64, n_shards: int = 4,
                  staleness_bound: int = 64, seed: int = 0,
                  executor: str = "ref", p: int = 4, m: int = 2,
                  budget_rows: int = 0, evict_policy: str = "heat",
                  scale: float = 1.0,
                  tenants: TenantRegistry = None) -> EmbeddingServeEngine:
    """DEPRECATED shim — the pre-API entry point, kept for callers.
    Builds the equivalent ``DealConfig`` and delegates to
    ``Session.serve()``; the engine it returns serves bitwise the same
    rows as the pre-API wiring (tests/test_api.py proves it)."""
    cfg = DealConfig(
        graph=GraphSpec(dataset=dataset, scale=scale, fanout=fanout,
                        seed=seed, n_construct_workers=4),
        model=ModelSpec(name=model, n_layers=n_layers,
                        d_feature=d_feature),
        partition=PartitionSpec(p=p, m=m),
        executor=ExecutorSpec(name=executor, fallback_to_ref=False),
        store=StoreSpec(n_shards=n_shards, budget_rows=budget_rows,
                        evict_policy=evict_policy),
        qos=QoSSpec(staleness_bound=staleness_bound,
                    tenants=_tenant_dicts(tenants) if tenants else ()))
    return _serve_session(cfg).engine


def drive(eng: EmbeddingServeEngine, *, ticks: int = 50,
          queries_per_tick: int = 4, rows_per_query: int = 128,
          mutations_per_tick: int = 8, nodes_per_tick: int = 0,
          seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    names = eng.qos.registry.names if eng.qos is not None else [None]
    uid = 0
    t0 = time.time()
    for tick in range(ticks):
        with obs.span("serve.tick") as tsp:
            n = eng.store.n_nodes       # grows under tail onboarding
            for j in range(queries_per_tick):
                # with QoS: first tenant gets interactive-sized queries,
                # the rest get 8x scans (the batch/analytics side)
                name = names[j % len(names)]
                rows = (rows_per_query if name in (None, names[0])
                        else 8 * rows_per_query)
                q = Query(uid=uid, node_ids=rng.integers(0, n, rows))
                if name is not None:
                    q.tenant = name
                eng.submit(q)
                uid += 1
            if mutations_per_tick:
                k = mutations_per_tick
                eng.mutate().add_edges(rng.integers(0, n, k),
                                       rng.integers(0, n, k))
            if nodes_per_tick:
                d = eng.store.level_dim(0)
                # ids are assigned at refresh time, AFTER earlier
                # pending adds — offset by them so each tick wires its
                # OWN nodes
                start = n + eng.log.pending_node_adds
                eng.mutate().add_nodes(
                    nodes_per_tick,
                    rng.standard_normal((nodes_per_tick, d),
                                        dtype=np.float32))
                eng.mutate().add_edges(
                    rng.integers(0, n, nodes_per_tick),
                    np.arange(start, start + nodes_per_tick))
            eng.step()
            if tsp:
                tsp.set(tick=tick)
    with obs.span("serve.drain"):
        eng.run()                   # drain
    dt = time.time() - t0
    n = eng.store.n_nodes
    s = eng.stats()
    refresh = eng.last_refresh_stats
    print(f"[serve] {s['n_served']} queries in {dt:.2f}s "
          f"({s['n_served']/max(dt,1e-9):.0f} q/s), "
          f"{s['n_gather_steps']} gather steps, "
          f"{s['n_refreshes']} delta refreshes "
          f"-> store v{s['store_version']}")
    if refresh:
        print(f"[fresh] last refresh frontier {refresh['frontier_sizes']} "
              f"of {n} rows, {refresh['rows_gemm']} gemm rows "
              f"(full epoch = {n * eng.reinfer.n_layers})")
    if s["n_onboarded"]:
        print(f"[onboard] {s['n_onboarded']} nodes added via "
              f"{s['store_n_tail_shards']} tail partition(s) "
              f"(store grew to {n} rows, no re-partition)")
    bound = ("per-tenant SLOs, tightest "
             + str(min(t.staleness_slo for t in eng.qos.registry))
             if eng.qos is not None else f"bound {eng.staleness_bound}")
    print(f"[stale] pending mutations at exit: {s['pending_mutations']} "
          f"({bound})")
    if eng.qos is not None:
        for name, t in s["tenants"].items():
            print(f"[qos] {name}: served {t['n_served']} "
                  f"({t['rows_served']} rows), wait p50/p95 "
                  f"{t['wait_p50_steps']:.0f}/{t['wait_p95_steps']:.1f} "
                  f"steps, staleness max {t['staleness_max']:.0f} "
                  f"(slo {t['staleness_slo']:.0f}, "
                  f"{t['slo_violations']} violations), "
                  f"refresh charge {t['refresh_rows_charged']:.0f} rows, "
                  f"quota util {t['quota_util']:.2f}, "
                  f"{t['n_preemptions']} preemptions")
    if eng.store.budget_rows is not None:
        mem = eng.memory_stats()
        per_level = " ".join(
            f"L{i}:{v['resident_bytes']/2**20:.2f}MB"
            for i, v in enumerate(mem.values()))
        print(f"[mem] resident {per_level} | util "
              f"{s['store_budget_util']:.2f} | hit-rate "
              f"{s['store_hit_rate']:.3f} ({s['store_misses']} misses, "
              f"{s['store_n_evictions']} evictions, "
              f"{s['store_rows_recomputed']} rows recomputed in "
              f"{s['store_recompute_s']*1e3:.0f}ms)")


def config_from_args(args) -> DealConfig:
    return DealConfig(
        graph=GraphSpec(dataset=args.dataset, scale=args.scale,
                        fanout=args.fanout, seed=args.seed,
                        n_construct_workers=4),
        model=ModelSpec(name=args.model, n_layers=args.layers,
                        d_feature=args.d_feature),
        partition=PartitionSpec(p=args.p, m=args.m),
        executor=ExecutorSpec(name=args.executor, fallback_to_ref=False),
        store=StoreSpec(n_shards=args.n_shards,
                        budget_rows=args.budget_rows,
                        evict_policy=args.evict_policy,
                        onboarding=args.onboarding),
        qos=QoSSpec(staleness_bound=args.staleness_bound,
                    tenants=(tenants_from_string(args.tenants)
                             if args.tenants else ())),
        refresh=RefreshSpec(chunk_rows=args.chunk_rows),
        cluster=ClusterSpec(n_shards=args.cluster_shards))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="CFG.json",
                    help="load the full DealConfig from a JSON artifact "
                         "(overrides every pipeline flag)")
    ap.add_argument("--dump-config", default=None, metavar="OUT.json",
                    help="write the effective DealConfig ('-' = stdout) "
                         "and exit without running")
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--model", default="gcn")
    ap.add_argument("--fanout", type=int, default=8)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--d-feature", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-shards", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=50)
    ap.add_argument("--queries-per-tick", type=int, default=4)
    ap.add_argument("--mutations-per-tick", type=int, default=8)
    ap.add_argument("--nodes-per-tick", type=int, default=0,
                    help="onboard this many NEW nodes per tick "
                         "(needs --onboarding tail)")
    ap.add_argument("--staleness-bound", type=int, default=64)
    ap.add_argument("--executor", default="ref",
                    help="delta-refresh backend: ref / pallas / dist "
                         "(dist needs p*m devices) or any registered "
                         "executor")
    ap.add_argument("--p", type=int, default=4, help="graph partitions")
    ap.add_argument("--m", type=int, default=2, help="feature partitions")
    ap.add_argument("--budget-rows", type=int, default=0,
                    help="resident-row cap per evictable level (0 = "
                         "unbudgeted); misses recompute via the delta "
                         "engine")
    ap.add_argument("--evict-policy", default="heat",
                    help="victim selection for over-budget levels "
                         "(heat / lru or any registered policy)")
    ap.add_argument("--onboarding", default="none",
                    choices=["none", "tail"],
                    help="tail: node additions append a tail partition "
                         "served via delta refresh")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="scale the dataset's node count (CI smoke)")
    ap.add_argument("--chunk-rows", type=int, default=0,
                    help="preemptible refresh under QoS: split the delta "
                         "frontier into chunks of this many rows and "
                         "interleave them with tenant gathers (0 = "
                         "inline refresh); bitwise-invariant")
    ap.add_argument("--tenants", default=None,
                    help="multi-tenant QoS: 'name:priority:slot_quota:"
                         "rate:slo,...' (rate 0 = unlimited rows/step); "
                         "replaces the global --staleness-bound")
    ap.add_argument("--trace", default=None, metavar="TRACE.json",
                    help="enable telemetry and write a Chrome/Perfetto "
                         "trace of the whole run (construct -> epoch -> "
                         "serve loop) on exit; load at ui.perfetto.dev")
    ap.add_argument("--cluster-shards", type=int, default=0,
                    help="serve through the multi-process cluster tier: "
                         "spawn this many shard-worker processes behind "
                         "the RPC router (0 = single-process)")
    ap.add_argument("--kill-shard", type=int, default=-1,
                    help="cluster failure drill: SIGKILL this shard "
                         "halfway through the drive, restart it, and "
                         "assert it rejoins bitwise-equal via "
                         "checkpoint + WAL replay")
    args = ap.parse_args()
    try:
        cfg = (DealConfig.load(args.config) if args.config
               else config_from_args(args))
        cfg.validate()
    except ConfigError as e:
        raise SystemExit(str(e))
    if args.dump_config:
        if args.dump_config == "-":
            print(cfg.to_json())
        else:
            cfg.dump(args.dump_config)
            print(f"[config] wrote {args.dump_config}")
        return
    if args.nodes_per_tick and cfg.store.onboarding != "tail":
        raise SystemExit("--nodes-per-tick needs --onboarding tail "
                         "(or store.onboarding=\"tail\" in --config)")
    if args.trace:
        cfg.telemetry.enabled = True
    if args.cluster_shards:
        cfg.cluster.n_shards = args.cluster_shards
    if args.kill_shard >= 0 and cfg.cluster.n_shards <= 0:
        raise SystemExit("--kill-shard needs a cluster (--cluster-shards"
                         " or cluster.n_shards in --config)")
    s = _serve_session(cfg)
    drive_kw = dict(queries_per_tick=args.queries_per_tick,
                    mutations_per_tick=args.mutations_per_tick,
                    nodes_per_tick=args.nodes_per_tick)
    if args.kill_shard >= 0:
        # failure drill: kill one worker MID-STREAM, restart it, and
        # prove the rejoin is bitwise (per-level store digests match a
        # never-killed shard) before finishing the drive
        head = max(1, args.ticks // 2)
        drive(s.engine, ticks=head, **drive_kw)
        dep = s.cluster
        dep.kill_worker(args.kill_shard)
        dep.restart_worker(args.kill_shard)
        digs = dep.router.digests()
        if any(d["digests"] != digs[0]["digests"] for d in digs[1:]):
            raise SystemExit(f"shard {args.kill_shard} did NOT rejoin "
                             "bitwise-equal after checkpoint + WAL "
                             "replay")
        print(f"[cluster] killed shard {args.kill_shard} at tick {head}"
              f"; restart replayed its WAL segment and rejoined "
              f"bitwise-equal ({len(digs)} shard digests match)")
        drive(s.engine, ticks=args.ticks - head, **drive_kw)
    else:
        drive(s.engine, ticks=args.ticks, **drive_kw)
    if args.trace:
        doc = s.dump_trace(args.trace)
        tr = s.telemetry.tracer
        lo, hi = tr.window_ns()
        print(f"[trace] wrote {args.trace}: "
              f"{len(doc['traceEvents'])} events, "
              f"coverage {tr.coverage():.2f} over "
              f"{(hi - lo) / 1e6:.0f}ms")


if __name__ == "__main__":
    main()
