"""Online embedding service launcher (gnnserve end-to-end).

Builds the offline pipeline (CSR -> layer graphs -> full epoch), stands
up the versioned store + continuous-batching engine, then drives a
synthetic open-loop workload that interleaves lookup queries with graph
mutations, printing serve/freshness stats.

  PYTHONPATH=src python -m repro.launch.serve_embeddings \
      --dataset ogbn-products --model gcn --ticks 50 \
      --mutations-per-tick 8 --staleness-bound 64

``--executor dist`` runs the epoch AND every delta refresh through the
distributed executor (per-partition frontier split on a p x m mesh);
needs p*m devices, e.g.  XLA_FLAGS=--xla_force_host_platform_device_count=8.

``--budget-rows R --evict-policy {lru,heat}`` caps each evictable store
level at R resident rows: cold shards are dropped and lookups that miss
rebuild exactly the missing rows through the delta engine
(recompute-on-miss), bitwise-equal to an unbudgeted store.

``--tenants "name:priority:slot_quota:rate:slo,..."`` turns on
multi-tenant QoS scheduling (``gnnserve.qos``): per-tenant freshness
SLOs with deadline-driven refresh planning, weighted-fair slot quotas
(preemptive reclaim) and a DRR row budget with token buckets.  The
driver then splits traffic across the declared tenants — small
interactive queries on the first tenant, large scans on the rest — and
prints the per-tenant QoS table.
"""
from __future__ import annotations

import argparse
import copy
import time

import jax
import numpy as np

from repro.core.gnn_models import init_gat, init_gcn, init_sage
from repro.core.graph import csr_from_edges_distributed, make_dataset
from repro.core.sampler import sample_layer_graphs
from repro.gnnserve import (DeltaReinference, EmbeddingServeEngine, Query,
                            TenantRegistry, attach_recompute, parse_tenants,
                            store_from_inference)


def build_service(dataset: str, model: str, *, fanout: int = 8,
                  n_layers: int = 3, d_feature: int = 64, n_shards: int = 4,
                  staleness_bound: int = 64, seed: int = 0,
                  executor: str = "ref", p: int = 4, m: int = 2,
                  budget_rows: int = 0, evict_policy: str = "heat",
                  scale: float = 1.0,
                  tenants: TenantRegistry = None) -> EmbeddingServeEngine:
    src, dst, n = make_dataset(dataset, seed=seed, scale=scale)
    g, _ = csr_from_edges_distributed(src, dst, n, n_workers=4)
    lgs = sample_layer_graphs(g, fanout=fanout, n_layers=n_layers, seed=seed)
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d_feature), dtype=np.float32)
    key = jax.random.PRNGKey(seed)
    dims = [d_feature] * (n_layers + 1)
    params = {"gcn": lambda: init_gcn(key, dims),
              "sage": lambda: init_sage(key, dims),
              "gat": lambda: init_gat(key, dims, heads=1)}[model]()

    if executor == "dist":
        from repro.core.ops import DistExecutor
        from repro.launch.mesh import make_host_mesh
        if len(jax.devices()) < p * m:
            raise SystemExit(
                f"--executor dist needs {p*m} devices; run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={p*m}")
        if n % p != 0:
            raise SystemExit(f"--p {p} must divide the node count {n}")
        if m & (m - 1) != 0:
            raise SystemExit(f"--m {m} must be a power of two "
                             "(row-subset pad buckets)")
        executor = DistExecutor(make_host_mesh(p, m))

    t0 = time.time()
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], model, params,
                          executor=executor)
    levels = ri.full_levels(X)
    print(f"[epoch0] {n} nodes x {n_layers} layers in {time.time()-t0:.2f}s")
    store = store_from_inference(X, levels[1:], n_shards=n_shards,
                                 budget_rows=budget_rows or None,
                                 evict_policy=evict_policy)
    if budget_rows:
        attach_recompute(store, ri)
        print(f"[budget] {budget_rows}/{n} rows per level resident "
              f"({evict_policy} eviction, recompute-on-miss)")
    if tenants is not None:
        print("[qos] tenants: " + ", ".join(
            f"{t.name}(prio={t.priority:g} quota={t.slot_quota} "
            f"rate={t.rate:g} slo={t.staleness_slo})" for t in tenants))
    return EmbeddingServeEngine(store, ri, g,
                                staleness_bound=staleness_bound,
                                tenants=tenants)


def drive(eng: EmbeddingServeEngine, *, ticks: int = 50,
          queries_per_tick: int = 4, rows_per_query: int = 128,
          mutations_per_tick: int = 8, seed: int = 0) -> None:
    n = eng.store.n_nodes
    rng = np.random.default_rng(seed)
    names = eng.qos.registry.names if eng.qos is not None else [None]
    uid = 0
    t0 = time.time()
    for tick in range(ticks):
        for j in range(queries_per_tick):
            # with QoS: first tenant gets interactive-sized queries,
            # the rest get 8x scans (the batch/analytics side)
            name = names[j % len(names)]
            rows = (rows_per_query if name in (None, names[0])
                    else 8 * rows_per_query)
            q = Query(uid=uid, node_ids=rng.integers(0, n, rows))
            if name is not None:
                q.tenant = name
            eng.submit(q)
            uid += 1
        if mutations_per_tick:
            k = mutations_per_tick
            eng.mutate().add_edges(rng.integers(0, n, k),
                                   rng.integers(0, n, k))
        eng.step()
    eng.run()                       # drain
    dt = time.time() - t0
    s = eng.stats()
    refresh = eng.last_refresh_stats
    print(f"[serve] {s['n_served']} queries in {dt:.2f}s "
          f"({s['n_served']/max(dt,1e-9):.0f} q/s), "
          f"{s['n_gather_steps']} gather steps, "
          f"{s['n_refreshes']} delta refreshes "
          f"-> store v{s['store_version']}")
    if refresh:
        print(f"[fresh] last refresh frontier {refresh['frontier_sizes']} "
              f"of {n} rows, {refresh['rows_gemm']} gemm rows "
              f"(full epoch = {n * eng.reinfer.n_layers})")
    bound = ("per-tenant SLOs, tightest "
             + str(min(t.staleness_slo for t in eng.qos.registry))
             if eng.qos is not None else f"bound {eng.staleness_bound}")
    print(f"[stale] pending mutations at exit: {s['pending_mutations']} "
          f"({bound})")
    if eng.qos is not None:
        for name, t in s["tenants"].items():
            print(f"[qos] {name}: served {t['n_served']} "
                  f"({t['rows_served']} rows), wait p50/p95 "
                  f"{t['wait_p50_steps']:.0f}/{t['wait_p95_steps']:.1f} "
                  f"steps, staleness max {t['staleness_max']:.0f} "
                  f"(slo {t['staleness_slo']:.0f}, "
                  f"{t['slo_violations']} violations), "
                  f"refresh charge {t['refresh_rows_charged']:.0f} rows, "
                  f"quota util {t['quota_util']:.2f}, "
                  f"{t['n_preemptions']} preemptions")
    if eng.store.budget_rows is not None:
        mem = eng.memory_stats()
        per_level = " ".join(
            f"L{i}:{v['resident_bytes']/2**20:.2f}MB"
            for i, v in enumerate(mem.values()))
        print(f"[mem] resident {per_level} | util "
              f"{s['store_budget_util']:.2f} | hit-rate "
              f"{s['store_hit_rate']:.3f} ({s['store_misses']} misses, "
              f"{s['store_n_evictions']} evictions, "
              f"{s['store_rows_recomputed']} rows recomputed in "
              f"{s['store_recompute_s']*1e3:.0f}ms)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--model", default="gcn",
                    choices=["gcn", "gat", "sage"])
    ap.add_argument("--fanout", type=int, default=8)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--ticks", type=int, default=50)
    ap.add_argument("--queries-per-tick", type=int, default=4)
    ap.add_argument("--mutations-per-tick", type=int, default=8)
    ap.add_argument("--staleness-bound", type=int, default=64)
    ap.add_argument("--executor", default="ref",
                    choices=["ref", "pallas", "dist"],
                    help="delta-refresh backend (dist needs p*m devices)")
    ap.add_argument("--p", type=int, default=4, help="graph partitions")
    ap.add_argument("--m", type=int, default=2, help="feature partitions")
    ap.add_argument("--budget-rows", type=int, default=0,
                    help="resident-row cap per evictable level (0 = "
                         "unbudgeted); misses recompute via the delta "
                         "engine")
    ap.add_argument("--evict-policy", default="heat",
                    choices=["lru", "heat"],
                    help="victim selection for over-budget levels")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="scale the dataset's node count (CI smoke)")
    ap.add_argument("--tenants", default=None,
                    help="multi-tenant QoS: 'name:priority:slot_quota:"
                         "rate:slo,...' (rate 0 = unlimited rows/step); "
                         "replaces the global --staleness-bound")
    args = ap.parse_args()
    eng = build_service(args.dataset, args.model, fanout=args.fanout,
                        n_layers=args.layers,
                        staleness_bound=args.staleness_bound,
                        executor=args.executor, p=args.p, m=args.m,
                        budget_rows=args.budget_rows,
                        evict_policy=args.evict_policy, scale=args.scale,
                        tenants=(parse_tenants(args.tenants)
                                 if args.tenants else None))
    drive(eng, ticks=args.ticks, queries_per_tick=args.queries_per_tick,
          mutations_per_tick=args.mutations_per_tick)


if __name__ == "__main__":
    main()
