"""Training launcher.

Production path (TPU pod): builds the production mesh, shards params/opt
with the rule engine, runs the jitted train_step over the data pipeline.
On this CPU container the same code runs with a 1x1 host mesh and reduced
configs — exercised by examples/train_lm.py and tests/test_train.py.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 50 --reduced --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer
from repro.sharding.context import sharding_context
from repro.sharding.specs import param_specs
from repro.train.checkpoint import save_checkpoint
from repro.train.data import DataConfig, make_pipeline
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step


def run(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 128,
        reduced: bool = True, lr: float = 3e-4, log_every: int = 10,
        checkpoint_path=None, mesh=None, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("use the family-specific example drivers")
    mesh = mesh or make_host_mesh(1, 1)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                          total_steps=steps)

    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    pspec = param_specs(cfg, params, mesh)
    params = jax.device_put(
        params, jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), pspec,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    opt_state = init_opt_state(params, opt_cfg)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                    batch_size=batch, seed=seed))
    losses = []
    t0 = time.time()
    with mesh, sharding_context(mesh):
        for i in range(steps):
            host = next(data)
            batch_dev = {k: jnp.asarray(v) for k, v in host.items()}
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_dev)
            losses.append(float(metrics["loss"]))
            if (i + 1) % log_every == 0 or i == 0:
                print(f"step {i+1:5d}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"{(time.time()-t0)/(i+1):.2f}s/step", flush=True)
    if checkpoint_path:
        save_checkpoint(checkpoint_path, params, opt_state, step=steps)
        print("saved", checkpoint_path)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()
    mesh = make_production_mesh() if args.production_mesh else None
    run(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=args.reduced, lr=args.lr, checkpoint_path=args.checkpoint,
        mesh=mesh)


if __name__ == "__main__":
    main()
