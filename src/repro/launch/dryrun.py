import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run is allowed to see 512 placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]

Each combo writes results/dryrun/<arch>__<shape>__<mesh>.json with the
memory analysis, cost analysis, and per-kind collective bytes parsed from
the post-SPMD optimized HLO — the roofline inputs (EXPERIMENTS.md §Dry-run).
"""
import argparse
import gc
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, applicable_shapes, get_config, get_shape
from repro.launch.inputs import step_arguments
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import (collective_bytes_from_hlo, model_flops,
                                     roofline_terms)
from repro.sharding.context import sharding_context

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _memory_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "host_generated_code_size_in_bytes",
              "host_argument_size_in_bytes", "host_output_size_in_bytes",
              "host_temp_size_in_bytes", "host_alias_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    if not out and ma is not None:
        out["repr"] = repr(ma)
    return out


def run_combo(arch: str, shape_name: str, mesh_kind: str,
              variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "n_chips": n_chips, "variant": variant, "status": "ok"}
    t0 = time.time()
    fn, args, shardings, out_shardings, donate = step_arguments(
        cfg, shape, mesh)
    with mesh, sharding_context(mesh):
        jitted = jax.jit(fn, in_shardings=shardings,
                         out_shardings=out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    mem = _memory_analysis_dict(compiled)
    print(f"[{arch} {shape_name} {mesh_kind}] memory_analysis:", mem)
    rec["memory_analysis"] = mem
    try:
        cost = compiled.cost_analysis()
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
    except Exception:
        cost = {}
    print(f"[{arch} {shape_name} {mesh_kind}] cost_analysis: "
          f"flops={cost.get('flops')}, bytes={cost.get('bytes accessed')}")
    rec["cost_analysis"] = {
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
    }
    hlo = compiled.as_text()
    rec["hlo_chars"] = len(hlo)
    coll = collective_bytes_from_hlo(hlo)
    rec["collectives"] = coll
    del hlo

    # cost_analysis on the partitioned module is per-chip already
    terms = roofline_terms(
        total_flops=rec["cost_analysis"]["flops"],
        total_bytes=rec["cost_analysis"]["bytes_accessed"],
        collective_bytes_per_chip=coll["total"],
        n_chips=n_chips, flops_are_global=False)
    rec["roofline"] = terms.as_dict()
    mf = model_flops(cfg, shape)
    rec["model_flops_global"] = mf
    hw_flops = rec["cost_analysis"]["flops"] * n_chips
    rec["model_flops_ratio"] = (mf / hw_flops) if hw_flops else None
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def combo_path(arch, shape_name, mesh_kind, variant="baseline"):
    suffix = "" if variant == "baseline" else f"__{variant}"
    return RESULTS / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tuning", default="",
                    help="comma flags (see repro/tuning.py); records are "
                         "written under a variant suffix")
    args = ap.parse_args()
    variant = "baseline"
    if args.tuning:
        os.environ["REPRO_TUNING"] = args.tuning
        variant = args.tuning.replace(",", "+")

    RESULTS.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    combos = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([get_shape(args.shape)] if args.shape
                  else applicable_shapes(cfg))
        for sh in shapes:
            for mk in meshes:
                combos.append((arch, sh.name, mk))

    n_ok = n_fail = n_skip = 0
    for arch, shape_name, mesh_kind in combos:
        out = combo_path(arch, shape_name, mesh_kind, variant)
        if out.exists() and not args.force:
            n_skip += 1
            continue
        print(f"=== dryrun {arch} {shape_name} {mesh_kind} "
              f"[{variant}] ===", flush=True)
        try:
            rec = run_combo(arch, shape_name, mesh_kind, variant)
            n_ok += 1
        except Exception as e:  # record the failure, keep going
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"FAILED: {e}", flush=True)
            n_fail += 1
        out.write_text(json.dumps(rec, indent=1))
        jax.clear_caches()
        gc.collect()
    print(f"done: ok={n_ok} fail={n_fail} skip={n_skip}")


if __name__ == "__main__":
    main()
