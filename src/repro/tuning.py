"""Perf-iteration flags + the Pallas block-size autotuner.

Flags (EXPERIMENTS.md §Perf): each hillclimb is a named flag so baseline
vs optimized lower from the SAME code path; the dry-run runs twice and
records both:

  REPRO_TUNING=mla_cache_rep,moe_ep,cp_decode python -m repro.launch.dryrun ...

  mla_cache_seq  H1: shard the MLA latent cache's SEQUENCE over `model`
                 (context parallelism) — scores stay local per shard and
                 only softmax partials + the (B,H,r) output psum, instead
                 of the baseline's per-layer (B,H,S) score psum.
  moe_ep         H2: shard_map expert-parallel MoE dispatch (argsort
                 bucketing per chip + psum combine) instead of the global
                 scatter GSPMD replicates.
  cp_decode      H3: sequence-parallel decode attention — partial softmax
                 (m, l, acc) psum over the KV shards instead of
                 all-gathering the cache (DEAL SPMM's "ship the small
                 partials" applied to attention).
  autotune       force the block-size search to re-run even when
                 ``configs/tuned_blocks.json`` already has an entry for
                 the (kernel, backend, dtype, shape-bucket) key.

Autotuner: the Pallas kernels in ``kernels/`` take ``block_n``/``block_d``
tile sizes whose best values depend on shape, dtype and backend.  A
``BlockTable`` maps ``(kernel, backend, dtype, shape-bucket)`` keys to the
winning blocks; ``ensure_tuned`` times the candidate grid for a key once
and persists the winner to ``configs/tuned_blocks.json``, which
``PallasExecutor(block_table="default")`` consults at bind time.  Block
sizes only change the grid decomposition, never the per-row accumulation
order, so tuned vs untuned outputs are bitwise identical — the table is a
pure perf knob.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Set

DEFAULT_TABLE_PATH = (Path(__file__).resolve().parents[2]
                      / "configs" / "tuned_blocks.json")

# candidate tile grids per kernel — small on purpose: the search is
# O(grid) kernel compilations per (shape-bucket, dtype, backend) key
KERNEL_GRIDS: Dict[str, Dict[str, tuple]] = {
    "spmm": {"block_n": (8, 16, 32, 64), "block_d": (128, 256)},
    "gather_spmm": {"block_n": (8, 16, 32, 64), "block_d": (128, 256)},
    "sddmm": {"block_n": (8, 16, 32, 64)},
    "gat_attention": {"block_n": (8, 16, 32, 64)},
    "flash_attention": {"block_q": (64, 128), "block_k": (64, 128)},
}


def flags() -> Set[str]:
    return set(filter(None, os.environ.get("REPRO_TUNING", "").split(",")))


def on(name: str) -> bool:
    return name in flags()


def autotune_forced() -> bool:
    """REPRO_TUNING=autotune invalidates persisted winners."""
    return on("autotune")


def shape_bucket(n: int) -> int:
    """Pow2 shape bucket (floor 8) — one table entry serves every shape
    that pads to the same power of two, matching the pow2 padding the
    executors/benches already use for compile-cache reuse."""
    b = 8
    while b < n:
        b *= 2
    return b


def _backend() -> str:
    import jax
    return jax.default_backend()


def table_key(kernel: str, backend: str, dtype: str, N: int,
              D: int) -> str:
    return (f"{kernel}/{backend}/{dtype}"
            f"/n{shape_bucket(N)}/d{shape_bucket(D)}")


class BlockTable:
    """Persisted (kernel, backend, dtype, shape-bucket) -> blocks map.

    JSON format (``configs/tuned_blocks.json``)::

        {"spmm/cpu/float32/n4096/d128":
             {"block_n": 32, "block_d": 128, "us": 512.3}, ...}

    ``us`` is the winning median time — informational, ignored by
    lookup.  Unknown keys simply miss (callers fall back to the
    ``auto_block_n`` defaults), so stale tables degrade gracefully.
    """

    def __init__(self, entries: Optional[Dict[str, Dict]] = None,
                 path: Optional[os.PathLike] = None):
        self.entries: Dict[str, Dict] = dict(entries or {})
        self.path = Path(path) if path is not None else DEFAULT_TABLE_PATH

    @classmethod
    def load(cls, path: Optional[os.PathLike] = None) -> "BlockTable":
        p = Path(path) if path is not None else DEFAULT_TABLE_PATH
        entries: Dict[str, Dict] = {}
        if p.exists():
            entries = json.loads(p.read_text())
        return cls(entries, path=p)

    def save(self, path: Optional[os.PathLike] = None) -> Path:
        p = Path(path) if path is not None else self.path
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.entries, indent=1, sort_keys=True)
                     + "\n")
        return p

    def lookup(self, kernel: str, *, N: int, D: int = 128,
               dtype: str = "float32",
               backend: Optional[str] = None) -> Optional[Dict]:
        key = table_key(kernel, backend or _backend(), dtype, N, D)
        got = self.entries.get(key)
        if got is None:
            return None
        return {k: v for k, v in got.items() if k.startswith("block_")}

    def put(self, kernel: str, *, N: int, D: int = 128,
            dtype: str = "float32", blocks: Dict[str, int],
            us: Optional[float] = None,
            backend: Optional[str] = None) -> str:
        key = table_key(kernel, backend or _backend(), dtype, N, D)
        entry = dict(blocks)
        if us is not None:
            entry["us"] = round(float(us), 1)
        self.entries[key] = entry
        return key


def resolve_block_table(spec) -> Optional[BlockTable]:
    """ExecutorSpec ``block_table`` knob -> a BlockTable (or None).

    None/"none" -> no table (auto blocks only); "default" -> the
    persistent repo table (empty when the file is missing); any other
    string -> that JSON path; a BlockTable instance passes through.
    """
    if spec is None or spec == "none":
        return None
    if isinstance(spec, BlockTable):
        return spec
    if spec == "default":
        return BlockTable.load()
    return BlockTable.load(spec)


def candidates(kernel: str, N: int, D: Optional[int] = None):
    """Candidate block dicts for one kernel, pruned to blocks that can
    tile the pow2-padded row bucket (and, when ``D`` is given, feature
    widths that divide D — falling back to ``block_d=D`` for narrow
    features none of the stock widths tile)."""
    grid = KERNEL_GRIDS[kernel]
    names = list(grid)
    combos = [{}]
    for name in names:
        combos = [dict(c, **{name: v}) for c in combos
                  for v in grid[name]]
    if "block_n" in grid:
        bucket = shape_bucket(N)
        combos = [c for c in combos if bucket % c["block_n"] == 0]
    if D is not None and "block_d" in grid:
        viable = [c for c in combos if D % c["block_d"] == 0]
        if not viable:
            seen: Dict[tuple, Dict] = {}
            for c in combos:
                c = dict(c, block_d=D)
                seen[tuple(sorted(c.items()))] = c
            viable = list(seen.values())
        combos = viable
    return combos


def _default_timer(fn: Callable[[], Any], repeats: int = 3) -> float:
    """Median wall-clock seconds of ``fn()`` (fn must block on its
    result, e.g. via jax.block_until_ready)."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def autotune_op(table: BlockTable, kernel: str, make_call: Callable,
                *, N: int, D: int = 128, dtype: str = "float32",
                timer: Optional[Callable] = None,
                repeats: int = 3) -> Dict[str, int]:
    """Time every candidate block combo and record the winner.

    ``make_call(blocks) -> zero-arg callable`` builds the kernel
    invocation for one combo; combos whose warmup call raises are
    skipped (e.g. a tile too large for the shape).  ``timer(fn,
    repeats) -> seconds`` is injectable so tests can search without
    timing real kernels.
    """
    timer = timer or _default_timer
    best_t, best_blocks = None, None
    for blocks in candidates(kernel, N, D):
        fn = make_call(blocks)
        try:
            fn()                                     # warmup / compile
        except Exception:
            continue
        t = timer(fn, repeats)
        if best_t is None or t < best_t:
            best_t, best_blocks = t, blocks
    if best_blocks is None:
        raise ValueError(f"no viable block candidates for {kernel} "
                         f"(N={N}, D={D})")
    table.put(kernel, N=N, D=D, dtype=dtype, blocks=best_blocks,
              us=best_t * 1e6)
    return best_blocks


def ensure_tuned(table: BlockTable, kernel: str, make_call: Callable,
                 *, N: int, D: int = 128, dtype: str = "float32",
                 timer: Optional[Callable] = None,
                 repeats: int = 3) -> Dict[str, int]:
    """Return the tuned blocks for a key, searching (and persisting to
    the table's path) only on a miss — or always when
    ``REPRO_TUNING=autotune`` forces a re-search."""
    if not autotune_forced():
        got = table.lookup(kernel, N=N, D=D, dtype=dtype)
        if got:
            return got
    blocks = autotune_op(table, kernel, make_call, N=N, D=D, dtype=dtype,
                         timer=timer, repeats=repeats)
    table.save()
    return blocks
