"""Perf-iteration flags (EXPERIMENTS.md §Perf).

Each hillclimb is a named flag so baseline vs optimized lower from the SAME
code path; the dry-run runs twice and records both:

  REPRO_TUNING=mla_cache_rep,moe_ep,cp_decode python -m repro.launch.dryrun ...

  mla_cache_seq  H1: shard the MLA latent cache's SEQUENCE over `model`
                 (context parallelism) — scores stay local per shard and
                 only softmax partials + the (B,H,r) output psum, instead
                 of the baseline's per-layer (B,H,S) score psum.
  moe_ep         H2: shard_map expert-parallel MoE dispatch (argsort
                 bucketing per chip + psum combine) instead of the global
                 scatter GSPMD replicates.
  cp_decode      H3: sequence-parallel decode attention — partial softmax
                 (m, l, acc) psum over the KV shards instead of
                 all-gathering the cache (DEAL SPMM's "ship the small
                 partials" applied to attention).
"""
from __future__ import annotations

import os
from typing import Set


def flags() -> Set[str]:
    return set(filter(None, os.environ.get("REPRO_TUNING", "").split(",")))


def on(name: str) -> bool:
    return name in flags()
