"""Dedicated coverage for core/feature_prep: the fused loader must be a
drop-in for redistribute (numerically) while its accounting shows the
standalone shuffle pass is gone (Fig 13 / Fig 21)."""
import numpy as np
import pytest

from repro.core.feature_prep import (fused_load, fused_load_spmm,
                                     redistribute_load, scan_all_load,
                                     write_feature_files)

N, D, OUT, M = 256, 16, 8, 4


@pytest.fixture(scope="module")
def prepared(tmp_path_factory):
    path = tmp_path_factory.mktemp("feats")
    files, feats = write_feature_files(str(path), N, D, n_files=8, seed=0)
    w = np.random.default_rng(0).standard_normal((D, OUT)).astype(np.float32)
    return files, feats, w


def test_fused_matches_redistribute_numerically(prepared):
    files, feats, w = prepared
    x_redist, _ = redistribute_load(files, M, N, D)
    h_fused, stats = fused_load(files, M, N, D, w)
    np.testing.assert_allclose(h_fused, x_redist @ w, atol=1e-5, rtol=1e-5)
    # the location table really maps node id -> loader position
    assert stats["table"].shape == (N,)
    assert np.array_equal(np.sort(stats["table"]), np.arange(N))


def test_fused_byte_counts_skip_shuffle(prepared):
    files, feats, w = prepared
    _, s_redist = redistribute_load(files, M, N, D)
    _, s_fused = fused_load(files, M, N, D, w)
    # both read each row exactly once from disk ...
    assert s_fused["file_rows"] == s_redist["file_rows"] == N
    # ... but only redistribute pays a network shuffle pass
    assert s_redist["net_rows"] > 0
    assert s_fused["net_rows"] == 0


def test_scan_all_reads_everything_m_times(prepared):
    files, feats, w = prepared
    x, s = scan_all_load(files, M, N, D)
    np.testing.assert_array_equal(x, feats)
    assert s["file_rows"] == M * N and s["net_rows"] == 0


@pytest.fixture(scope="module")
def layer1(prepared):
    from repro.core.graph import csr_from_edges, rmat_edges
    from repro.core.sampler import sample_layer_graphs
    src, dst = rmat_edges(N, N * 8, seed=3)
    g = csr_from_edges(src, dst, N)
    return sample_layer_graphs(g, fanout=4, n_layers=1, seed=1)[0]


@pytest.mark.parametrize("executor", ["ref", "pallas"])
def test_fused_spmm_bitwise_and_shuffle_free(prepared, layer1, executor):
    """The FULLY fused loader (loader-order GEMM + table-indirect
    aggregation) must be BITWISE equal to the materialized pipeline
    through the same executor: per-row GEMM dots don't care about row
    order, and the fused gather sees the same values in the same
    reduction order.  And it still pays zero shuffle traffic."""
    from repro.core.ops import DenseIO, get_executor
    files, feats, w = prepared
    ex = get_executor(executor)
    agg, stats = fused_load_spmm(files, M, N, D, w, layer1, ex)

    io = DenseIO.from_layer_graph(layer1)
    want = np.asarray(ex.spmm(ex.gemm(ex.prepare(feats), w),
                              io.mean_w, io))
    np.testing.assert_array_equal(np.asarray(agg), want)
    assert stats["net_rows"] == 0 and stats["file_rows"] == N
    assert np.array_equal(np.sort(stats["table"]), np.arange(N))
