"""MoE dispatch: sort-based capacity routing vs per-token dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models.moe import init_moe_params, moe_block


def _cfg(top_k=2, capacity=64.0):
    cfg = get_config("deepseek-v2-236b").reduced()
    return dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, top_k=top_k,
                                capacity_factor=capacity))


def dense_oracle(x, p, cfg):
    """Route every token through its top-k experts without capacity."""
    m = cfg.moe
    B, S, D = x.shape
    flat = np.asarray(x, np.float64).reshape(-1, D)
    router = np.asarray(p["router"], np.float64)
    logits = flat @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(flat)
    wg = np.asarray(p["w_gate"], np.float64)
    wu = np.asarray(p["w_up"], np.float64)
    wd = np.asarray(p["w_down"], np.float64)
    for t in range(flat.shape[0]):
        top = np.argsort(-probs[t])[:m.top_k]
        gates = probs[t][top]
        gates = gates / gates.sum()
        for e, g in zip(top, gates):
            h = flat[t] @ wg[e]
            h = h / (1 + np.exp(-h)) * (flat[t] @ wu[e])
            out[t] += g * (h @ wd[e])
    if m.n_shared_experts:
        g = flat @ np.asarray(p["shared_w_gate"], np.float64)
        u = flat @ np.asarray(p["shared_w_up"], np.float64)
        out += (g / (1 + np.exp(-g)) * u) @ np.asarray(p["shared_w_down"],
                                                       np.float64)
    return out.reshape(B, S, D)


def test_moe_matches_dense_oracle(rng):
    cfg = _cfg(top_k=2, capacity=64.0)   # capacity high: nothing dropped
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)).astype(
        np.float32) * 0.5)
    out, aux = moe_block(x, p, cfg)
    want = dense_oracle(x, p, cfg)
    np.testing.assert_allclose(np.asarray(out, np.float64), want,
                               atol=1e-4, rtol=1e-3)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_are_partial(rng):
    """With tight capacity some tokens drop but output stays finite and
    close in norm (shared expert still covers every token)."""
    cfg = _cfg(top_k=2, capacity=0.5)
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)).astype(
        np.float32))
    out, aux = moe_block(x, p, cfg)
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(out).sum()) > 0


def test_moe_grad_flows(rng):
    cfg = _cfg()
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)).astype(
        np.float32))

    def loss(p):
        out, aux = moe_block(x, p, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    norms = jax.tree.map(lambda a: float(jnp.abs(a).sum()), g)
    assert norms["router"] > 0 and norms["w_gate"] > 0
