"""The public API: DealConfig round-trip + validation, the plugin
registries, ExecutorSpec.build, and the deprecation shims' bitwise
equivalence to the pre-API hand-wired pipelines (ref + pallas)."""
import copy
import dataclasses
import pathlib

import numpy as np
import pytest

from repro.api import (ConfigError, DealConfig, ExecutorSpec, GraphSpec,
                       ModelSpec, PartitionSpec, QoSSpec, Session,
                       StoreSpec, register_evict_policy, register_model,
                       tenants_from_string)
from repro.api.registry import EVICT_POLICIES, MODELS

ROOT = pathlib.Path(__file__).resolve().parents[1]

SMALL = DealConfig(
    graph=GraphSpec(dataset="rmat", n_nodes=256, avg_degree=8, fanout=4),
    model=ModelSpec(name="gcn", n_layers=2, d_feature=16),
    qos=QoSSpec(staleness_bound=8))


# ----------------------------------------------------------------------
# config tree: serialization + validation
# ----------------------------------------------------------------------

def test_json_roundtrip_is_exact():
    cfgs = [
        DealConfig(),
        SMALL,
        DealConfig(
            graph=GraphSpec(dataset="ogbn-products", scale=0.5, seed=3),
            model=ModelSpec(name="gat", heads=2, d_feature=32),
            partition=PartitionSpec(p=4, m=2),
            executor=ExecutorSpec(name="pallas",
                                  options={"block_n": 8, "block_d": 64}),
            store=StoreSpec(budget_rows=128, evict_policy="lru",
                            admission="full", onboarding="tail"),
            qos=QoSSpec(staleness_bound=16, batch_slots=2,
                        tenants=tenants_from_string(
                            "ui:4:2:0:8,batch:1:1:64:512"))),
    ]
    for cfg in cfgs:
        assert DealConfig.from_json(cfg.to_json()) == cfg
        assert DealConfig.from_dict(cfg.to_dict()) == cfg
        # a second round trip is byte-stable too
        assert DealConfig.from_json(cfg.to_json()).to_json() \
            == cfg.to_json()


def test_checked_in_smoke_config_roundtrips():
    path = ROOT / "configs" / "examples" / "smoke.json"
    cfg = DealConfig.load(path).validate()
    assert DealConfig.from_json(cfg.to_json()) == cfg
    assert cfg.store.onboarding == "tail"


def test_validation_names_every_bad_field():
    bad = DealConfig(
        graph=GraphSpec(dataset="nope", scale=-1, fanout=0),
        model=ModelSpec(name="wat", n_layers=0, heads=3, d_feature=16),
        partition=PartitionSpec(p=0),
        executor=ExecutorSpec(name="cuda"),
        store=StoreSpec(n_shards=0, budget_rows=-2, evict_policy="bogus",
                        admission="maybe", onboarding="head"),
        qos=QoSSpec(staleness_bound=0,
                    tenants=({"name": "", "priority": -1},
                             {"name": "a"}, {"name": "a"})))
    with pytest.raises(ConfigError) as ei:
        bad.validate()
    msg = str(ei.value)
    for frag in ("graph.dataset", "graph.scale", "graph.fanout",
                 "model.name", "model.n_layers", "model.heads",
                 "partition.p", "executor.name", "store.n_shards",
                 "store.budget_rows", "store.evict_policy",
                 "store.admission", "store.onboarding",
                 "qos.staleness_bound", "qos.tenants[0].name",
                 "qos.tenants[0].priority", "qos.tenants[2].name"):
        assert frag in msg, f"{frag} missing from:\n{msg}"
    # unknown names list what IS registered
    assert "heat" in msg and "lru" in msg
    assert "gcn" in msg and "sage" in msg and "gat" in msg
    assert "ref" in msg and "pallas" in msg and "dist" in msg


def test_from_dict_rejects_unknown_fields_by_name():
    d = SMALL.to_dict()
    d["store"]["budget_mb"] = 3
    d["grph"] = {}
    with pytest.raises(ConfigError) as ei:
        DealConfig.from_dict(d)
    assert "store.budget_mb" in str(ei.value)
    assert "grph" in str(ei.value)
    # a non-dict section is named too, not a raw TypeError
    with pytest.raises(ConfigError) as ei:
        DealConfig.from_json('{"graph": 5}')
    assert "graph" in str(ei.value)


def test_validation_names_wrong_typed_fields():
    # hand-edited JSON with wrong value types must get ConfigError with
    # the dotted field path, never a raw TypeError/ValueError
    with pytest.raises(ConfigError) as ei:
        DealConfig.from_json('{"graph": {"fanout": "8"}}').validate()
    assert "graph.fanout" in str(ei.value)
    with pytest.raises(ConfigError) as ei:
        DealConfig.from_json(
            '{"qos": {"tenants": ["ui:1:1:0:4"]}}').validate()
    assert "qos.tenants[0]" in str(ei.value)
    with pytest.raises(ConfigError) as ei:
        DealConfig.from_json('{"executor": {"options": 3}}').validate()
    assert "executor.options" in str(ei.value)
    # wrong-typed tenant FIELDS get dotted paths too
    with pytest.raises(ConfigError) as ei:
        DealConfig.from_json(
            '{"qos": {"tenants": [{"name": "ui", "priority": "4", '
            '"rate": "fast"}]}}').validate()
    assert "qos.tenants[0].priority" in str(ei.value)
    assert "qos.tenants[0].rate" in str(ei.value)
    # and the CLI parser reports ConfigError, not raw ValueError
    with pytest.raises(ConfigError):
        tenants_from_string("ui:abc:2:0:8")
    with pytest.raises(ConfigError):
        tenants_from_string("ui:1:1:0")         # wrong field count
    with pytest.raises(ConfigError):
        tenants_from_string("ui:-1:1:0:8")      # TenantSpec value check


def test_executor_spec_build_unknown_name_lists_registered():
    with pytest.raises(ConfigError) as ei:
        ExecutorSpec(name="cuda").build(PartitionSpec())
    msg = str(ei.value)
    assert "executor.name" in msg and "ref" in msg and "pallas" in msg


def test_executor_spec_dist_fallback_and_checks():
    from repro.core.ops import RefExecutor
    # trivial mesh falls back to ref (the old infer_gnn behavior) ...
    ex = ExecutorSpec(name="dist").build(PartitionSpec(p=1, m=1))
    assert isinstance(ex, RefExecutor)
    # ... unless the caller opted out of the fallback
    with pytest.raises(ConfigError):
        ExecutorSpec(name="dist", fallback_to_ref=False).build(
            PartitionSpec(p=64, m=64))   # no machine has 4096 devices


# ----------------------------------------------------------------------
# registries: third-party plugins without core edits
# ----------------------------------------------------------------------

def test_register_custom_evict_policy_runs_through_store():
    from repro.gnnserve import Query

    @register_evict_policy("fifo_test")
    def fifo(store, level):
        # evict the lowest shard id first, deterministically
        return lambda s: s
    try:
        cfg = dataclasses.replace(
            SMALL, store=StoreSpec(budget_rows=64,
                                   evict_policy="fifo_test"))
        eng = Session.build(cfg).serve()
        oracle = Session.build(SMALL).serve()
        ids = np.arange(256)
        q, qo = Query(uid=0, node_ids=ids), Query(uid=0, node_ids=ids)
        eng.submit(q), oracle.submit(qo)
        eng.run(), oracle.run()
        assert eng.store.n_evictions > 0, "budget never evicted"
        # recompute-on-miss keeps a custom policy bitwise-correct too
        assert np.array_equal(q.out, qo.out)
    finally:
        EVICT_POLICIES.unregister("fifo_test")
    with pytest.raises(ConfigError):
        cfg.validate()      # the name is gone again


def test_register_custom_model_runs_through_session():
    gcn = MODELS.get("gcn")
    register_model("gcn_custom_test", gcn)      # same math, new name
    try:
        cfg = dataclasses.replace(
            SMALL, model=dataclasses.replace(SMALL.model,
                                             name="gcn_custom_test"))
        H = Session.build(cfg).infer_all()
        H_ref = Session.build(SMALL).infer_all()
        assert np.array_equal(H, H_ref)
    finally:
        MODELS.unregister("gcn_custom_test")


def test_reregistering_builtin_requires_overwrite():
    with pytest.raises(ValueError):
        register_model("gcn", object())


# ----------------------------------------------------------------------
# shim equivalence: legacy entry points == the Session they delegate to
# ----------------------------------------------------------------------

SCALE = 256 / 8192          # ogbn-products at 256 nodes


def _legacy_infer(model, executor, *, p=2, m=1, fanout=4, n_layers=2,
                  d_feature=16, seed=0):
    """The pre-API body of launch/infer_gnn.run, verbatim wiring."""
    import jax

    from repro.core.gnn_models import init_gat, init_gcn
    from repro.core.graph import csr_from_edges_distributed, make_dataset
    from repro.core.layerwise import LOCAL_ENGINES
    from repro.core.sampler import sample_layer_graphs
    src, dst, n = make_dataset("ogbn-products", seed=seed, scale=SCALE)
    g, _ = csr_from_edges_distributed(src, dst, n, n_workers=p)
    lgs = sample_layer_graphs(g, fanout=fanout, n_layers=n_layers,
                              seed=seed)
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d_feature), dtype=np.float32)
    dims = [d_feature] * (n_layers + 1)
    key = jax.random.PRNGKey(seed)
    params = (init_gcn(key, dims) if model == "gcn"
              else init_gat(key, dims, heads=1))
    return np.asarray(LOCAL_ENGINES[model](lgs, X, params,
                                           executor=executor))


@pytest.mark.parametrize("executor", ["ref", "pallas"])
@pytest.mark.parametrize("model", ["gcn", "gat"])
def test_infer_gnn_shim_bitwise_equal(model, executor):
    from repro.launch.infer_gnn import run
    H = run("ogbn-products", model, p=2, m=1, fanout=4, n_layers=2,
            d_feature=16, executor=executor, distributed=False,
            scale=SCALE)
    np.testing.assert_array_equal(H, _legacy_infer(model, executor))


def _legacy_service(model, executor, *, fanout=4, n_layers=2,
                    d_feature=16, n_shards=4, staleness_bound=8, seed=0,
                    budget_rows=0):
    """The pre-API body of launch/serve_embeddings.build_service,
    verbatim wiring."""
    import jax

    from repro.core.gnn_models import init_gat, init_gcn, init_sage
    from repro.core.graph import csr_from_edges_distributed, make_dataset
    from repro.core.sampler import sample_layer_graphs
    from repro.gnnserve import (DeltaReinference, EmbeddingServeEngine,
                                attach_recompute, store_from_inference)
    src, dst, n = make_dataset("ogbn-products", seed=seed, scale=SCALE)
    g, _ = csr_from_edges_distributed(src, dst, n, n_workers=4)
    lgs = sample_layer_graphs(g, fanout=fanout, n_layers=n_layers,
                              seed=seed)
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d_feature), dtype=np.float32)
    key = jax.random.PRNGKey(seed)
    dims = [d_feature] * (n_layers + 1)
    params = {"gcn": lambda: init_gcn(key, dims),
              "sage": lambda: init_sage(key, dims),
              "gat": lambda: init_gat(key, dims, heads=1)}[model]()
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], model, params,
                          executor=executor)
    levels = ri.full_levels(X)
    store = store_from_inference(X, levels[1:], n_shards=n_shards,
                                 budget_rows=budget_rows or None)
    if budget_rows:
        attach_recompute(store, ri)
    return EmbeddingServeEngine(store, ri, g,
                                staleness_bound=staleness_bound)


def _drive_pair(eng_a, eng_b, n):
    """Identical traffic against both engines; returns the query pairs."""
    from repro.gnnserve import Query
    pairs = []
    for tick in range(4):
        rng = np.random.default_rng(100 + tick)
        ids = rng.integers(0, n, 32)
        qa, qb = Query(uid=tick, node_ids=ids), Query(uid=tick,
                                                      node_ids=ids)
        s_e, d_e = rng.integers(0, n, 4), rng.integers(0, n, 4)
        for eng, q in ((eng_a, qa), (eng_b, qb)):
            eng.submit(q)
            eng.mutate().add_edges(s_e, d_e)
            eng.run()
        pairs.append((qa, qb))
    return pairs


@pytest.mark.parametrize("executor", ["ref", "pallas"])
def test_build_service_shim_bitwise_equal(executor):
    from repro.launch.serve_embeddings import build_service
    eng = build_service("ogbn-products", "gcn", fanout=4, n_layers=2,
                        d_feature=16, staleness_bound=8,
                        executor=executor, scale=SCALE)
    legacy = _legacy_service("gcn", executor)
    n = eng.store.n_nodes
    assert n == legacy.store.n_nodes == 256
    for qa, qb in _drive_pair(eng, legacy, n):
        assert qa.done and qb.done
        assert qa.served_version == qb.served_version
        np.testing.assert_array_equal(qa.out, qb.out)
    assert eng.store.version == legacy.store.version


def test_budgeted_service_shim_bitwise_equal():
    from repro.launch.serve_embeddings import build_service
    eng = build_service("ogbn-products", "gcn", fanout=4, n_layers=2,
                        d_feature=16, staleness_bound=8,
                        budget_rows=96, scale=SCALE)
    legacy = _legacy_service("gcn", "ref", budget_rows=96)
    for qa, qb in _drive_pair(eng, legacy, eng.store.n_nodes):
        np.testing.assert_array_equal(qa.out, qb.out)
    assert eng.store.n_evictions > 0


# ----------------------------------------------------------------------
# one config drives offline AND online (the quickstart contract)
# ----------------------------------------------------------------------

def test_one_config_offline_and_online():
    with Session.build(SMALL) as s:
        H = s.infer_all()
        eng = s.serve()
        from repro.gnnserve import Query
        q = Query(uid=0, node_ids=np.arange(16))
        eng.submit(q)
        eng.run()
        # the served rows ARE the offline epoch's final level (the store
        # is built from the same layer graphs + params the epoch used)
        np.testing.assert_array_equal(q.out, H[:16])
        st = s.stats()
        assert st["n_served"] == 1 and "t_epoch_s" in st
    with pytest.raises(ConfigError):
        s.infer_all()       # closed
