"""Config registry: exact assigned numbers, divisibility for the production
mesh, parameter budgets."""
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, applicable_shapes, get_config


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    families = {get_config(a).family for a in ARCH_IDS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_numbers(arch):
    cfg = get_config(arch)
    expected = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == expected


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_mesh_divisibility(arch):
    """d_model must shard over fsdp (32 on the 2-pod mesh); the tp-sharded
    output dims must divide 16."""
    cfg = get_config(arch)
    assert cfg.d_model % 32 == 0
    hd = cfg.resolved_head_dim
    assert (cfg.n_heads * hd) % 16 == 0
    assert (cfg.n_kv_heads * hd) % 16 == 0
    if cfg.d_ff:
        assert cfg.d_ff % 16 == 0
    if cfg.moe:
        assert cfg.moe.n_experts % 16 == 0
    if cfg.ssm:
        assert cfg.ssm.d_inner % 16 == 0


def test_param_budgets():
    assert 3e11 < get_config("llama4-maverick-400b-a17b").param_count() < 5e11
    assert 1.4e10 < get_config("llama4-maverick-400b-a17b").active_param_count() < 2.2e10
    assert 1.8e11 < get_config("deepseek-v2-236b").param_count() < 2.9e11
    assert 3e9 < get_config("gemma3-4b").param_count() < 6e9
    assert 2.5e8 < get_config("smollm-360m").param_count() < 5e8
    assert 1e9 < get_config("mamba2-1.3b").param_count() < 1.8e9


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.n_layers <= 2 and r.d_model <= 512
    if r.moe:
        assert r.moe.n_experts <= 4


def test_long500k_applicability():
    runs_long = {a for a in ARCH_IDS
                 if any(s.name == "long_500k"
                        for s in applicable_shapes(get_config(a)))}
    assert runs_long == {"gemma3-4b", "zamba2-7b", "mamba2-1.3b"}


def test_shapes():
    names = [s.name for s in INPUT_SHAPES]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    kinds = {s.name: s.kind for s in INPUT_SHAPES}
    assert kinds["decode_32k"] == "decode" and kinds["long_500k"] == "decode"
