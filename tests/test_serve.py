"""Serving: engine greedy decode == teacher-forced argmax; ragged slots."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.step import prefill_step


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("smollm-360m").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(3))
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    """Teacher-forced greedy continuation via full forward each step."""
    toks = list(map(int, prompt))
    out = []
    for _ in range(n_new):
        logits, _ = forward(cfg, params,
                            {"tokens": jnp.asarray([toks], jnp.int32)},
                            mode="prefill", remat=False)
        nxt = int(np.asarray(logits)[0, -1].argmax())
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_teacher_forcing(small_lm, rng):
    cfg, params = small_lm
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    want = greedy_reference(cfg, params, prompt, 6)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32)
    r = Request(uid=0, prompt=prompt, max_new_tokens=6)
    eng.submit(r)
    eng.run()
    assert r.done
    assert r.out_tokens == want, (r.out_tokens, want)


def test_engine_ragged_batch(small_lm, rng):
    """Several requests with different prompt lengths, decoded together,
    each must match its solo teacher-forced continuation."""
    cfg, params = small_lm
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 7, 5)]
    wants = [greedy_reference(cfg, params, p, 4) for p in prompts]
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, want in zip(reqs, wants):
        assert r.done
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)


def test_prefill_step_logits_match_forward(small_lm, rng):
    cfg, params = small_lm
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                         jnp.int32)
    logits, cache = prefill_step(cfg, params, {"tokens": tokens})
    full, _ = forward(cfg, params, {"tokens": tokens}, mode="prefill",
                      remat=False)
    np.testing.assert_allclose(np.asarray(logits)[:, 0],
                               np.asarray(full)[:, -1], atol=1e-4,
                               rtol=1e-4)
    assert cache["k"].shape[0] == cfg.n_layers
