"""HTTP observability endpoints under CONCURRENT scrapes: the
per-session ``obs.endpoint.TelemetryEndpoint`` (threaded server, shared
stats tree) must serve parallel ``/metrics`` / ``/healthz`` / ``/stats``
readers while the engine keeps serving, and the cluster
``RouterEndpoint`` must aggregate per-shard health states (exercised
here against a stub deployment; the live-deployment integration is in
``test_cluster.py``)."""
import json
import threading
import urllib.request

import numpy as np

from repro.api import DealConfig, Session
from repro.gnnserve.cluster import RouterEndpoint, merge_health
from repro.gnnserve.engine import Query


def _session(port=0):
    return Session.build(DealConfig.from_dict({
        "graph": {"dataset": "rmat", "n_nodes": 160, "avg_degree": 4,
                  "fanout": 4, "seed": 1},
        "model": {"name": "gcn", "n_layers": 2, "d_feature": 16},
        "executor": {"name": "ref"},
        "qos": {"staleness_bound": 4},
        "telemetry": {"enabled": True, "http_port": port},
    }))


def _get(url):
    with urllib.request.urlopen(url, timeout=10) as r:
        assert r.status == 200
        return r.read()


def _scrape_all(base, paths, n_rounds, failures):
    try:
        for _ in range(n_rounds):
            for p in paths:
                body = _get(f"{base}{p}")
                if p == "/metrics":
                    assert b"deal_" in body or body == b""
                else:
                    json.loads(body)
    except Exception as exc:        # surface thread failures to pytest
        failures.append(exc)


def test_telemetry_endpoint_survives_concurrent_scrapes():
    with _session() as s:
        eng = s.serve()
        ep = s.endpoint
        assert ep is not None and ep.port
        base = f"http://127.0.0.1:{ep.port}"
        failures = []
        threads = [threading.Thread(
            target=_scrape_all, args=(base, ["/metrics", "/healthz",
                                            "/stats"], 10, failures))
            for _ in range(6)]
        for t in threads:
            t.start()
        # keep serving WHILE the scrapers hammer the stats tree
        r = np.random.default_rng(2)
        for i in range(30):
            log = eng.mutate()
            log.add_edge(int(r.integers(0, 160)),
                         int(r.integers(0, 160)))
            eng.submit(Query(i, r.integers(0, 160, 8).astype(np.int64)))
            eng.run()
        for t in threads:
            t.join(timeout=30)
        assert not any(t.is_alive() for t in threads)
        assert failures == []
        doc = json.loads(_get(f"{base}/stats"))
        assert doc["n_served"] == 30
        health = json.loads(_get(f"{base}/healthz"))
        assert health["status"] in ("ok", "alerting")
        assert _get(f"{base}/metrics").startswith(b"#") or True


def test_telemetry_endpoint_404_and_stop():
    with _session() as s:
        s.serve()
        ep = s.endpoint
        base = f"http://127.0.0.1:{ep.port}"
        try:
            urllib.request.urlopen(f"{base}/nope", timeout=10)
            assert False, "expected HTTP 404"
        except urllib.error.HTTPError as e:
            assert e.code == 404
    # close() stops the server; later requests must fail to connect
    try:
        urllib.request.urlopen(f"{base}/stats", timeout=2)
        assert False, "endpoint still serving after close()"
    except (urllib.error.URLError, ConnectionError, OSError):
        pass


class _StubRouter:
    def __init__(self, per_shard):
        self.per_shard = per_shard

    def health(self):
        return merge_health(self.per_shard)

    def statuses(self):
        return [{"shard": i, "pid": 1000 + i, "pending": 0}
                for i in range(len(self.per_shard))]

    def router_stats(self):
        return {"n_shards": len(self.per_shard), "n_lookups": 3,
                "n_subqueries": 5, "n_scatter": 2, "n_commits": 1,
                "n_retries": 0, "seq": [1, 1], "pending_mutations": 0}


class _StubDeployment:
    def __init__(self, per_shard):
        self.router = _StubRouter(per_shard)

    def stats(self):
        return {"n_served": 3, "cluster": {"n_shards": 2}}


def test_router_endpoint_aggregates_shard_health_states():
    ok = {"n_alerts": 0, "alerts": [], "burn_rate": {},
          "wait_burn_rate": {}, "firing": [], "status": "ok"}
    alerting = {"n_alerts": 1,
                "alerts": [{"kind": "refresh_backlog"}],
                "burn_rate": {"ui": 3.0}, "wait_burn_rate": {},
                "firing": ["refresh_backlog"], "status": "alerting"}
    ep = RouterEndpoint(_StubDeployment([ok, alerting])).start()
    try:
        base = f"http://127.0.0.1:{ep.port}"
        failures = []
        threads = [threading.Thread(
            target=_scrape_all,
            args=(base, ["/healthz", "/shards", "/stats"], 10,
                  failures)) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert failures == []
        doc = json.loads(_get(f"{base}/healthz"))
        assert doc["status"] == "alerting"         # ANY shard alerting
        assert doc["firing"] == ["shard1:refresh_backlog"]
        assert [s["status"] for s in doc["shards"]] == \
            ["ok", "alerting"]
        shards = json.loads(_get(f"{base}/shards"))
        assert [s["shard"] for s in shards["shards"]] == [0, 1]
        assert shards["router"]["n_shards"] == 2
    finally:
        ep.stop()
