"""Attention correctness: chunked flash vs exact softmax, windows, decode
with per-slot lengths, MLA absorbed decode vs prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import (decode_attention, flash_attention_jnp,
                                    mla_decode, mla_new_cache_entries,
                                    mla_prefill, simple_attention)


def _qkv(rng, B, Sq, Skv, H, K, hd, dtype=np.float32):
    q = rng.standard_normal((B, Sq, H, hd)).astype(dtype)
    k = rng.standard_normal((B, Skv, K, hd)).astype(dtype)
    v = rng.standard_normal((B, Skv, K, hd)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("Sq,Skv,H,K,hd", [
    (64, 64, 4, 4, 32),    # MHA
    (64, 64, 6, 2, 16),    # GQA
    (48, 80, 4, 2, 32),    # ragged (pad path)
])
def test_flash_matches_simple_causal(Sq, Skv, H, K, hd, rng):
    q, k, v = _qkv(rng, 2, Sq, Skv, H, K, hd)
    got = flash_attention_jnp(q, k, v, causal=True, q_block=16, kv_block=32)
    want = simple_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [1, 7, 16, 1 << 30])
def test_flash_window(window, rng):
    q, k, v = _qkv(rng, 1, 64, 64, 2, 2, 16)
    got = flash_attention_jnp(q, k, v, causal=True, window=window,
                              q_block=16, kv_block=16)
    want = simple_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal(rng):
    q, k, v = _qkv(rng, 2, 32, 48, 4, 4, 16)
    got = flash_attention_jnp(q, k, v, causal=False, q_block=16,
                              kv_block=16)
    want = simple_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_matches_prefill_last_row(rng):
    B, S, H, K, hd = 2, 24, 4, 2, 16
    q, k, v = _qkv(rng, B, S, S, H, K, hd)
    full = simple_attention(q, k, v, causal=True)
    out = decode_attention(q[:, -1:], k, v, cache_len=S)
    np.testing.assert_allclose(np.asarray(out)[:, 0],
                               np.asarray(full)[:, -1], atol=2e-5,
                               rtol=2e-5)


def test_decode_per_slot_lengths(rng):
    """(B,) cache_len: each row must only see its own prefix."""
    B, S, H, K, hd = 3, 16, 2, 2, 8
    q, k, v = _qkv(rng, B, 1, S, H, K, hd)
    lens = jnp.asarray([4, 9, 16])
    got = decode_attention(q, k, v, cache_len=lens)
    for b in range(B):
        L = int(lens[b])
        want = decode_attention(q[b:b + 1], k[b:b + 1, :],
                                v[b:b + 1, :], cache_len=L)
        np.testing.assert_allclose(np.asarray(got)[b], np.asarray(want)[0],
                                   atol=2e-5, rtol=2e-5)


def test_mla_absorbed_decode_matches_prefill(rng):
    cfg = get_config("deepseek-v2-236b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    from repro.models.transformer import _init_mla
    p = _init_mla(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S, D = 2, 12, cfg.d_model
    x = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32) * 0.3)
    out_prefill, c_kv, k_rope = mla_prefill(x, p, cfg, jnp.arange(S))
    # absorbed decode at the last position using the prefill caches
    pos = jnp.int32(S - 1)
    out_dec = mla_decode(x[:, -1:], p, cfg, c_kv, k_rope, S, pos)
    np.testing.assert_allclose(np.asarray(out_dec)[:, 0],
                               np.asarray(out_prefill)[:, -1],
                               atol=3e-4, rtol=3e-4)


def test_mla_new_cache_entries_match_prefill(rng):
    cfg = get_config("deepseek-v2-236b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    from repro.models.transformer import _init_mla
    p = _init_mla(jax.random.PRNGKey(1), cfg, jnp.float32)
    B, S = 2, 8
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)).astype(
        np.float32) * 0.3)
    _, c_kv, k_rope = mla_prefill(x, p, cfg, jnp.arange(S))
    ck1, kr1 = mla_new_cache_entries(x[:, -1:], p, cfg, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(ck1)[:, 0],
                               np.asarray(c_kv)[:, -1], atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(kr1)[:, 0],
                               np.asarray(k_rope)[:, -1], atol=1e-5,
                               rtol=1e-5)
