"""Serving-world checkpoints: ``EmbeddingStore.dump/load`` round-trips
the committed front (residency, policy state, version counters) and
``save_world`` / ``Session.from_checkpoint`` restore a full serving
world that serves BITWISE the rows the dumped one served — the same
artifact cluster shard workers restore before replaying their WAL
segment."""
import numpy as np
import pytest

from repro.api import ConfigError, DealConfig, Session
from repro.gnnserve.checkpoint import (load_world, peek_meta,
                                       restore_into_session, save_world)
from repro.gnnserve.engine import Query

D = 16


def _cfg(*, budget_rows=0, n=160):
    return DealConfig.from_dict({
        "graph": {"dataset": "rmat", "n_nodes": n, "avg_degree": 4,
                  "fanout": 4, "seed": 5},
        "model": {"name": "gcn", "n_layers": 2, "d_feature": D},
        "executor": {"name": "ref"},
        "store": {"onboarding": "tail", "budget_rows": budget_rows},
        "qos": {"staleness_bound": 4},
    })


def _churn(eng, *, n=160, ticks=4, seed=9):
    r = np.random.default_rng(seed)
    for t in range(ticks):
        log = eng.mutate()
        for _ in range(4):
            a, b = r.integers(0, n, 2)
            log.add_edge(int(a), int(b))
        ids = np.unique(r.integers(0, n, 3).astype(np.int64))
        log.update_features(
            ids, r.standard_normal((ids.size, D)).astype(np.float32))
        q = Query(t, r.integers(0, n, 10).astype(np.int64))
        eng.submit(q)
        eng.run()


def test_store_dump_load_roundtrip(tmp_path):
    from repro.gnnserve.store import EmbeddingStore
    with Session.build(_cfg()) as s:
        eng = s.serve()
        _churn(eng)
        st = eng.store
        path = tmp_path / "store.npz"
        st.dump(path)
        back = EmbeddingStore.load(path)
        assert back.version == st.version
        assert back.n_nodes == st.n_nodes
        assert back.bounds.tolist() == st.bounds.tolist()
        ids = np.arange(st.n_nodes, dtype=np.int64)
        for level in range(st.n_levels):
            assert np.array_equal(back.lookup(ids, level),
                                  st.lookup(ids, level))


def test_store_dump_load_preserves_residency_under_budget(tmp_path):
    from repro.gnnserve.store import EmbeddingStore
    with Session.build(_cfg(budget_rows=64)) as s:
        eng = s.serve()
        _churn(eng)
        st = eng.store
        st.dump(tmp_path / "b.npz")
        back = EmbeddingStore.load(tmp_path / "b.npz")
        assert back.budget_rows == 64
        assert back.stats()["resident_bytes"] == \
            st.stats()["resident_bytes"]
        # residency bitmaps restore exactly: same shards evicted
        for level in range(st.n_levels):
            for shard in range(st.n_shards):
                assert (back._front[level][shard] is None) == \
                    (st._front[level][shard] is None)


def test_save_world_meta_and_load(tmp_path):
    with Session.build(_cfg()) as s:
        eng = s.serve()
        _churn(eng)
        path = tmp_path / "world.npz"
        save_world(path, eng, committed_seq=7)
        meta = peek_meta(path)
        assert meta["committed_seq"] == 7
        assert meta["n_refreshes"] == eng.n_refreshes
        _, graph, lgs, store = load_world(path)
        assert graph.n_edges == eng.graph.n_edges   # mutated CSR, not
        assert graph.n_edges > s.graph.n_edges      # the build-time one
        assert len(lgs) == len(eng.reinfer.layer_graphs)
        assert store.version == eng.store.version


@pytest.mark.parametrize("budget_rows", [0, 64])
def test_from_checkpoint_serves_bitwise(tmp_path, budget_rows):
    cfg = _cfg(budget_rows=budget_rows)
    path = tmp_path / "world.npz"
    with Session.build(cfg) as s:
        eng = s.serve()
        _churn(eng)
        save_world(path, eng)
        counters = (eng.n_refreshes, eng.ops_drained, eng.n_full_epochs)
        ids = np.arange(0, 120, dtype=np.int64)
        q = Query(100, ids)
        eng.submit(q)
        eng.run()
        want, want_v = q.out.copy(), q.served_version

    with Session.from_checkpoint(
            path, DealConfig.from_dict(cfg.to_dict())) as s2:
        eng2 = s2.engine
        assert (eng2.n_refreshes, eng2.ops_drained,
                eng2.n_full_epochs) == counters
        q2 = Query(100, np.arange(0, 120, dtype=np.int64))
        eng2.submit(q2)
        eng2.run()
        assert q2.served_version == want_v
        assert np.array_equal(q2.out, want)
        # the restored world keeps serving: more churn + a refresh
        _churn(eng2, ticks=2, seed=13)
        assert s2.stats()["store_version"] > 0


def test_from_checkpoint_rejects_cluster_configs(tmp_path):
    cfg = _cfg()
    path = tmp_path / "world.npz"
    with Session.build(cfg) as s:
        save_world(path, s.serve())
    d = cfg.to_dict()
    d["cluster"]["n_shards"] = 2
    with pytest.raises(ConfigError, match="cluster"):
        Session.from_checkpoint(path, DealConfig.from_dict(d))


def test_restore_into_session_requires_fresh_session(tmp_path):
    cfg = _cfg()
    path = tmp_path / "world.npz"
    with Session.build(cfg) as s:
        save_world(path, s.serve())
        with pytest.raises(AssertionError):
            restore_into_session(s, path)   # engine already attached
