"""Cluster serving tier (``gnnserve/cluster``): protocol framing, the
in-process WorkerCore WAL/seq contract, and the live 2-shard
deployment's headline guarantee — cluster-served lookups are BITWISE
equal to the single-process ``Session`` on the same ``DealConfig``
(ref + pallas), including after kill/restart/WAL-replay of one shard —
plus merged stats/attribution, heartbeat wedge detection, and the
aggregated ``/healthz``.

The deployment tests share module-scoped fixtures (worker processes
are expensive to spawn) and run in FILE ORDER: tests that mutate the
worlds mirror the mutation on BOTH the single-process and cluster
sessions, so the equal-worlds invariant holds for every later test.
"""
import json
import socket
import struct
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.api import (ClusterSpec, DealConfig, ExecutorSpec, GraphSpec,
                       ModelSpec, QoSSpec, Session, TelemetrySpec,
                       tenants_from_string)
from repro.gnnserve.cluster import (ProtocolError, WorkerCore,
                                    merge_health, recv_msg, send_msg)
from repro.gnnserve.engine import Query

N = 192
D = 16


def _cfg_dict(*, executor="ref", n=N):
    return {
        "graph": {"dataset": "rmat", "n_nodes": n, "avg_degree": 4,
                  "fanout": 4, "seed": 3},
        "model": {"name": "sage", "n_layers": 2, "d_feature": D},
        "executor": {"name": executor},
        "store": {"onboarding": "tail"},
        "qos": {"staleness_bound": 4},
    }


def _qos_cfg(*, n_shards=2, http_port=0):
    return DealConfig(
        graph=GraphSpec(dataset="rmat", n_nodes=N, avg_degree=4,
                        fanout=4, seed=3),
        model=ModelSpec(name="sage", n_layers=2, d_feature=D),
        executor=ExecutorSpec(name="ref"),
        qos=QoSSpec(staleness_bound=8, batch_slots=4, rows_per_step=64,
                    tenants=tenants_from_string(
                        "ui:4:2:0:4,batch:1:1:0:64")),
        telemetry=TelemetrySpec(enabled=True),
        cluster=ClusterSpec(n_shards=n_shards, http_port=http_port))


def _workload(eng, *, n=N, ticks=5, rows=12, seed=11):
    """Deterministic mixed traffic (edge adds + feature updates +
    queries) — identical on any engine built from the same config."""
    outs = []
    r = np.random.default_rng(seed)
    for t in range(ticks):
        log = eng.mutate()
        for _ in range(3):
            a, b = r.integers(0, n, 2)
            log.add_edge(int(a), int(b))
        ids = np.unique(r.integers(0, n, 4).astype(np.int64))
        log.update_features(
            ids, r.standard_normal((ids.size, D)).astype(np.float32))
        q = Query(1000 + t, r.integers(0, n, rows).astype(np.int64))
        eng.submit(q)
        eng.run()
        outs.append((q.out.copy(), q.served_version))
    return outs


# ----------------------------------------------------------------------
# protocol framing (no processes)
# ----------------------------------------------------------------------

def test_protocol_roundtrip_is_bit_exact():
    a, b = socket.socketpair()
    try:
        arrays = {
            "rows": np.random.default_rng(0).standard_normal(
                (7, 5)).astype(np.float32),
            "ids": np.arange(9, dtype=np.int64)[::3].copy(),
        }
        send_msg(a, {"op": "lookup", "level": -1, "ok": True}, arrays)
        header, got = recv_msg(b)
        assert header == {"op": "lookup", "level": -1, "ok": True}
        assert set(got) == {"rows", "ids"}
        for k in got:
            assert got[k].dtype == arrays[k].dtype
            assert np.array_equal(got[k], arrays[k])
        # empty-array legs survive too
        send_msg(b, {"op": "x"}, {"e": np.empty((0, 3), np.float32)})
        _, got = recv_msg(a)
        assert got["e"].shape == (0, 3)
    finally:
        a.close()
        b.close()


def test_protocol_rejects_eof_and_torn_frames():
    a, b = socket.socketpair()
    a.close()
    with pytest.raises(ProtocolError, match="closed"):
        recv_msg(b)
    b.close()
    a, b = socket.socketpair()
    try:
        # a frame whose header claims to be longer than the frame
        head = json.dumps({"op": "x"}).encode()
        body = struct.pack("<I", len(head) + 999) + head
        a.sendall(struct.pack("<I", len(body)) + body)
        with pytest.raises(ProtocolError, match="header length"):
            recv_msg(b)
    finally:
        a.close()
        b.close()


def test_protocol_frame_cap_rejects_allocation_bomb():
    # the cap must stay small enough that a corrupt length prefix can
    # never trigger a multi-GiB allocation in _recv_exact
    from repro.gnnserve.cluster.protocol import MAX_FRAME
    assert MAX_FRAME <= 1 << 28
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("<I", MAX_FRAME + 1))
        with pytest.raises(ProtocolError, match="exceeds cap"):
            recv_msg(b)
    finally:
        a.close()
        b.close()


def test_float_wire_helpers_roundtrip_exactly():
    from repro.gnnserve.cluster.worker import (_rows_from_wire,
                                               _rows_to_wire)
    rows = np.random.default_rng(3).standard_normal(
        (11, 6)).astype(np.float32)
    wire = json.loads(json.dumps(_rows_to_wire(rows)))
    back = _rows_from_wire(wire)
    assert back.dtype == np.float32
    assert np.array_equal(back, rows)


# ----------------------------------------------------------------------
# WorkerCore in-process: seq chain, WAL replay, config neutralization
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def core_cfg():
    return DealConfig.from_dict({**_cfg_dict(n=128)})


def _commit_header(seq, edge_ops):
    return {"op": "commit", "seq": seq, "edge_ops": edge_ops,
            "n_new_nodes": 0}


def test_worker_core_seq_chain(core_cfg, tmp_path):
    core = WorkerCore(core_cfg, 0, 1, str(tmp_path))
    resp, _ = core.dispatch(_commit_header(1, [["add", 1, 2]]), {})
    assert resp["seq"] == 1 and not resp.get("duplicate")
    v1 = resp["store_version"]
    # duplicate seq acks idempotently, without re-applying
    resp, _ = core.dispatch(_commit_header(1, [["add", 1, 2]]), {})
    assert resp["duplicate"] and resp["store_version"] == v1
    # a gap breaks the monotonic chain loudly
    with pytest.raises(ValueError, match="monotonic"):
        core.dispatch(_commit_header(5, []), {})
    assert core.last_seq == 1


def test_worker_core_wal_replay_is_bitwise(core_cfg, tmp_path):
    import os
    run_dir = str(tmp_path)
    core = WorkerCore(core_cfg, 0, 1, run_dir)
    core.dispatch(_commit_header(1, [["add", 3, 4], ["add", 5, 6]]), {})
    core.dispatch(_commit_header(2, [["del", 3, 4]]), {})
    want, _ = core.dispatch({"op": "digest"}, {})
    # checkpoint restore path: ckpt has committed_seq == 2, empty replay
    restored = WorkerCore(core_cfg, 0, 1, run_dir)
    assert restored.restored and restored.last_seq == 2
    assert restored.replayed == 0
    got, _ = restored.dispatch({"op": "digest"}, {})
    assert got["digests"] == want["digests"]
    # full WAL replay path: no checkpoint, every entry replays
    os.unlink(core.ckpt_path)
    replayed = WorkerCore(core_cfg, 0, 1, run_dir)
    assert not replayed.restored and replayed.replayed == 2
    assert replayed.last_seq == 2
    got, _ = replayed.dispatch({"op": "digest"}, {})
    assert got["digests"] == want["digests"]
    assert got["store_version"] == want["store_version"]


def test_worker_rolls_back_wal_and_world_when_apply_fails(
        core_cfg, tmp_path, monkeypatch):
    (tmp_path / "w").mkdir()
    core = WorkerCore(core_cfg, 0, 1, str(tmp_path / "w"))
    core.dispatch(_commit_header(1, [["add", 1, 2]]), {})
    boom = {"on": True}
    real = WorkerCore._apply_commit

    def flaky(self, entry):
        if boom["on"]:
            raise RuntimeError("injected apply failure")
        return real(self, entry)

    monkeypatch.setattr(WorkerCore, "_apply_commit", flaky)
    with pytest.raises(RuntimeError, match="injected"):
        core.dispatch(_commit_header(2, [["add", 5, 6]]), {})
    # the torn seq-2 entry is truncated back out and the chain intact:
    # a restart must not replay it, a retry must not duplicate it
    assert core.last_seq == 1
    with open(core.wal_path) as f:
        lines = [l for l in f if l.strip()]
    assert len(lines) == 1 and json.loads(lines[0])["seq"] == 1
    boom["on"] = False
    resp, _ = core.dispatch(_commit_header(2, [["add", 5, 6]]), {})
    assert resp["seq"] == 2 and not resp["duplicate"]
    # ... and the recovered world is bitwise-equal to a never-failed one
    (tmp_path / "ctrl").mkdir()
    ctrl = WorkerCore(core_cfg, 0, 1, str(tmp_path / "ctrl"))
    ctrl.dispatch(_commit_header(1, [["add", 1, 2]]), {})
    ctrl.dispatch(_commit_header(2, [["add", 5, 6]]), {})
    assert core.dispatch({"op": "digest"}, {})[0]["digests"] == \
        ctrl.dispatch({"op": "digest"}, {})[0]["digests"]


def test_replay_rejects_duplicate_and_gapped_wal(core_cfg, tmp_path):
    entry = {"seq": 1, "kind": "commit", "edge_ops": [["add", 1, 2]],
             "feat_ids": [], "feat_rows": [], "n_new_nodes": 0,
             "new_node_rows": None}
    dup = tmp_path / "dup"
    dup.mkdir()
    (dup / "shard0.wal").write_text(
        json.dumps(entry) + "\n" + json.dumps(entry) + "\n")
    with pytest.raises(ValueError, match="duplicate|out-of-order"):
        WorkerCore(core_cfg, 0, 1, str(dup))
    gap = tmp_path / "gap"
    gap.mkdir()
    (gap / "shard0.wal").write_text(
        json.dumps(entry) + "\n" + json.dumps({**entry, "seq": 3})
        + "\n")
    with pytest.raises(ValueError, match="gap"):
        WorkerCore(core_cfg, 0, 1, str(gap))


def test_worker_config_overrides_and_neutralization(tmp_path):
    cfg = DealConfig.from_dict({
        **_cfg_dict(n=128),
        "telemetry": {"enabled": False, "http_port": 9999},
        "cluster": {"n_shards": 2,
                    "overrides": [{"shard": 1, "budget_rows": 64,
                                   "staleness_bound": 2}]},
    })
    core = WorkerCore(cfg, 1, 2, str(tmp_path))
    assert core.cfg.cluster.n_shards == 0      # no recursive clusters
    assert core.cfg.telemetry.http_port == -1  # router owns the door
    assert core.cfg.store.budget_rows == 64
    assert core.cfg.qos.staleness_bound == 2
    (tmp_path / "s0").mkdir()
    other = WorkerCore(cfg, 0, 2, str(tmp_path / "s0"))
    assert other.cfg.store.budget_rows == 0    # override is shard-1 only


# ----------------------------------------------------------------------
# router failure semantics over in-process cores (no sockets)
# ----------------------------------------------------------------------

class _CoreChannel:
    """In-process stand-in for ``protocol.Channel`` over a WorkerCore:
    the same request/close surface and error taxonomy (WorkerError for
    handler failures), plus fault injection — ops named in ``fail_ops``
    raise OSError BEFORE reaching the core, modelling a transport
    failure where the shard never saw the RPC."""

    def __init__(self, core):
        self.core = core
        self.fail_ops = set()
        self._lock = threading.Lock()

    def request(self, op, arrays=None, **fields):
        from repro.gnnserve.cluster import WorkerError
        with self._lock:
            if op in self.fail_ops:
                raise OSError(f"injected transport failure on {op!r}")
            try:
                return self.core.dispatch({"op": op, **fields},
                                          dict(arrays or {}))
            except Exception as exc:
                raise WorkerError(
                    f"shard op {op!r} failed: {exc}") from exc

    def close(self):
        pass


@pytest.fixture()
def core_router(core_cfg, tmp_path):
    from repro.gnnserve.cluster import Router
    cores, channels = [], []
    for s in range(2):
        d = tmp_path / f"shard{s}"
        d.mkdir()
        core = WorkerCore(core_cfg, s, 2, str(d))
        cores.append(core)
        channels.append(_CoreChannel(core))
    st, _ = cores[0].dispatch({"op": "status"}, {})
    bounds = np.linspace(0, st["n_nodes"], 3).astype(np.int64)
    return Router(channels, bounds, st["dims"]), cores, channels


def _core_digests(cores):
    return [c.dispatch({"op": "digest"}, {})[0]["digests"]
            for c in cores]


def test_commit_requeues_when_durable_nowhere(core_router):
    router, cores, channels = core_router
    for ch in channels:
        ch.fail_ops.add("commit")
    router.log.add_edge(1, 2)
    with pytest.raises(RuntimeError, match="requeued"):
        router.commit_pending()
    # nothing applied anywhere, the batch is back in the log, and no
    # shard's seq moved — the next commit re-drains under fresh seqs
    assert router.log.pending == 1
    assert router.seq == [0, 0]
    assert all(c.last_seq == 0 for c in cores)
    for ch in channels:
        ch.fail_ops.clear()
    router.commit_pending()
    assert router.seq == [1, 1]
    assert router.log.pending == 0
    d0, d1 = _core_digests(cores)
    assert d0 == d1


def test_commit_partial_failure_parks_inflight_no_seq_reuse(
        core_router, core_cfg, tmp_path):
    router, cores, channels = core_router
    channels[1].fail_ops.add("commit")
    router.log.add_edge(3, 4)
    with pytest.raises(RuntimeError, match="in-flight"):
        router.commit_pending()
    # shard 0 folded the batch; it must NOT requeue (that would double-
    # apply on shard 0 under a reused seq) — it parks in-flight instead
    assert router.seq == [1, 0]
    assert router.log.pending == 0
    assert router.router_stats()["inflight"] == "commit"
    # a new mutation arrives while the commit is parked
    router.log.add_edge(5, 6)
    channels[1].fail_ops.clear()
    router.commit_pending()     # drives the parked batch, then drains
    assert router.seq == [2, 2]
    assert router.router_stats()["inflight"] is None
    d0, d1 = _core_digests(cores)
    assert d0 == d1
    # no double-apply anywhere: equal to a control fed each batch once
    (tmp_path / "ctrl").mkdir()
    ctrl = WorkerCore(core_cfg, 0, 1, str(tmp_path / "ctrl"))
    ctrl.dispatch(_commit_header(1, [["add", 3, 4]]), {})
    ctrl.dispatch(_commit_header(2, [["add", 5, 6]]), {})
    assert ctrl.dispatch({"op": "digest"}, {})[0]["digests"] == d0


def test_commit_resyncs_seq_when_only_the_ack_is_lost(core_router):
    """An applied-but-unacked commit must advance the router's seq via
    the status resync — NOT be re-sent as a new batch (the duplicate
    ack path) or requeued (double-apply)."""
    from repro.gnnserve.cluster import WorkerError
    router, cores, channels = core_router
    real = channels[1].request

    def drop_ack(op, arrays=None, **fields):
        resp = real(op, arrays, **fields)
        if op == "commit":
            raise WorkerError("injected ack loss after apply")
        return resp

    channels[1].request = drop_ack
    router.log.add_edge(7, 8)
    router.commit_pending()     # resync sees last_seq==target: no error
    channels[1].request = real
    assert router.seq == [1, 1]
    assert all(c.last_seq == 1 for c in cores)
    assert router.log.pending == 0
    d0, d1 = _core_digests(cores)
    assert d0 == d1


def test_concurrent_lookups_and_scrapes_never_tear_a_commit(
        core_router):
    router, cores, _ = core_router
    errs = []
    stop = threading.Event()

    def _reader(seed):
        r = np.random.default_rng(seed)
        while not stop.is_set():
            try:
                rows, _ = router.lookup(
                    r.integers(0, 128, 8).astype(np.int64))
                assert rows.shape == (8, D)
                router.engine_stats()   # merged scrape mid-commit
            except Exception as exc:    # noqa: BLE001 — recorded
                errs.append(exc)
                return

    threads = [threading.Thread(target=_reader, args=(i,), daemon=True)
               for i in range(3)]
    for t in threads:
        t.start()
    for i in range(6):
        router.log.add_edge(int(i), int((i * 7 + 1) % 128))
        router.commit_pending()
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert not errs, f"reader raced a commit: {errs[0]}"
    d0, d1 = _core_digests(cores)
    assert d0 == d1


def test_lookup_empty_ids_returns_empty_rows(core_router):
    router, _, _ = core_router
    rows, version = router.lookup(np.empty(0, np.int64))
    assert rows.shape == (0, D)
    assert rows.dtype == np.float32
    assert version == router.statuses()[0]["store_version"]


# ----------------------------------------------------------------------
# the live 2-shard deployment vs the single-process Session
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def fifo_pair():
    base = _cfg_dict()
    s1 = Session.build(DealConfig.from_dict(base))
    e1 = s1.serve()
    s2 = Session.build(DealConfig.from_dict(
        {**base, "cluster": {"n_shards": 2}}))
    e2 = s2.serve()
    o1 = _workload(e1)
    o2 = _workload(e2)
    yield s1, e1, o1, s2, e2, o2
    s1.close()
    s2.close()


def test_cluster_serves_bitwise_equal_to_single_process(fifo_pair):
    _, _, o1, _, _, o2 = fifo_pair
    for i, ((rows1, v1), (rows2, v2)) in enumerate(zip(o1, o2)):
        assert v1 == v2, f"tick {i}: served versions diverge"
        assert np.array_equal(rows1, rows2), f"tick {i}: bytes diverge"


def test_shards_hold_identical_worlds(fifo_pair):
    *_, s2, _, _ = fifo_pair
    digs = s2.cluster.router.digests()
    assert digs[0]["digests"] == digs[1]["digests"]
    assert digs[0]["store_version"] == digs[1]["store_version"]
    sts = s2.cluster.router.statuses()
    assert [st["shard"] for st in sts] == [0, 1]
    assert all(st["pending"] == 0 for st in sts)


def test_merged_stats_keep_session_schema(fifo_pair):
    s1, _, o1, s2, _, _ = fifo_pair
    st1, st2 = s1.stats(), s2.stats()
    # the cluster tree is a superset of the single-process one
    missing = set(st1) - set(st2)
    assert not missing, f"merged stats dropped keys: {sorted(missing)}"
    assert st2["store_version"] == st1["store_version"]
    assert st2["n_served"] == len(o1)          # client queries, not RPCs
    assert st2["n_served_subqueries"] >= st2["n_served"]
    assert st2["pending_mutations"] == 0
    cl = st2["cluster"]
    assert cl["n_shards"] == 2 and len(cl["shards"]) == 2
    assert cl["router"]["n_lookups"] == len(o1)
    assert cl["router"]["seq"] == [5, 5]       # one commit per tick


def test_full_epoch_matches_single_process(fifo_pair):
    _, e1, _, s2, e2, _ = fifo_pair
    e1.full_epoch()
    e2.full_epoch()
    digs = s2.cluster.router.digests()
    assert digs[0]["digests"] == digs[1]["digests"]
    r = np.random.default_rng(23)
    ids = r.integers(0, N, 16).astype(np.int64)
    q1, q2 = Query(2000, ids), Query(2000, ids.copy())
    e1.submit(q1), e2.submit(q2)
    e1.run(), e2.run()
    assert q1.served_version == q2.served_version
    assert np.array_equal(q1.out, q2.out)


def test_killed_shard_rejoins_bitwise_after_replay(fifo_pair):
    _, e1, _, s2, e2, _ = fifo_pair
    dep = s2.cluster
    dep.kill_worker(1)
    dep.restart_worker(1)
    digs = dep.router.digests()
    assert digs[0]["digests"] == digs[1]["digests"], \
        "restarted shard is not bitwise-equal after checkpoint+replay"
    sts = dep.router.statuses()
    assert sts[1]["restored"]                   # came back via checkpoint
    ids = np.arange(60, 120, dtype=np.int64)    # spans both shards
    q1, q2 = Query(3000, ids), Query(3000, ids.copy())
    e1.submit(q1), e2.submit(q2)
    e1.run(), e2.run()
    assert np.array_equal(q1.out, q2.out)
    assert dep.n_restarts >= 1


def test_router_retries_transparently_through_a_dead_worker(fifo_pair):
    _, e1, _, s2, e2, _ = fifo_pair
    dep = s2.cluster
    before = dep.router.n_retries
    dep.kill_worker(0)                          # kill, do NOT restart
    ids = np.arange(0, 50, dtype=np.int64)      # owned by shard 0
    q1, q2 = Query(4000, ids), Query(4000, ids.copy())
    e1.submit(q1), e2.submit(q2)
    e1.run(), e2.run()                          # reconnect hook respawns
    assert np.array_equal(q1.out, q2.out)
    assert dep.router.n_retries > before


def test_wedged_worker_killed_with_stage_named_diagnosis(fifo_pair):
    *_, s2, _, _ = fifo_pair
    dep = s2.cluster
    hbs = dep.check_heartbeats()
    assert all(h["alive"] and h["age_s"] < 5.0 for h in hbs)

    def _hang():
        try:
            dep.router.channels[1].request("_test_hang", seconds=60)
        except Exception:
            pass                                # killed mid-request

    t = threading.Thread(target=_hang, daemon=True)
    t.start()
    deadline = time.time() + 15.0
    while time.time() < deadline:
        hbs = dep.check_heartbeats()
        if hbs[1]["stage"] == "op:_test_hang" and hbs[1]["age_s"] > 1.0:
            break
        time.sleep(0.2)
    diags = dep.kill_wedged(max_age_s=1.0, restart=True)
    t.join(timeout=10)
    assert len(diags) == 1
    assert "shard 1" in diags[0] and "op:_test_hang" in diags[0]
    digs = dep.router.digests()                 # rejoined bitwise again
    assert digs[0]["digests"] == digs[1]["digests"]


def test_node_adds_route_and_onboard_identically(fifo_pair):
    _, e1, _, s2, e2, _ = fifo_pair
    n0 = e2.store.n_nodes
    for eng in (e1, e2):
        r = np.random.default_rng(31)
        log = eng.mutate()
        log.add_nodes(3, r.standard_normal((3, D)).astype(np.float32))
        log.add_edge(int(n0), 5)
        log.add_edge(7, int(n0 + 2))
        eng.refresh()
    assert e1.store.n_nodes == e2.store.n_nodes == n0 + 3
    ids = np.arange(n0 - 2, n0 + 3, dtype=np.int64)   # tail straddle
    q1, q2 = Query(5000, ids), Query(5000, ids.copy())
    e1.submit(q1), e2.submit(q2)
    e1.run(), e2.run()
    assert np.array_equal(q1.out, q2.out)
    digs = s2.cluster.router.digests()
    assert digs[0]["digests"] == digs[1]["digests"]


@pytest.mark.parametrize("executor", ["pallas"])
def test_cluster_bitwise_on_accelerated_executor(executor):
    base = _cfg_dict(executor=executor, n=128)
    with Session.build(DealConfig.from_dict(base)) as s1, \
            Session.build(DealConfig.from_dict(
                {**base, "cluster": {"n_shards": 2}})) as s2:
        o1 = _workload(s1.serve(), n=128, ticks=3)
        o2 = _workload(s2.serve(), n=128, ticks=3)
        for (rows1, v1), (rows2, v2) in zip(o1, o2):
            assert v1 == v2
            assert np.array_equal(rows1, rows2)
        s2.cluster.kill_worker(0)
        s2.cluster.restart_worker(0)
        digs = s2.cluster.router.digests()
        assert digs[0]["digests"] == digs[1]["digests"]


# ----------------------------------------------------------------------
# QoS + telemetry cluster: merged attribution, aggregated /healthz
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def qos_cluster():
    s = Session.build(_qos_cfg())
    eng = s.serve()
    r = np.random.default_rng(5)
    for t in range(12):
        for tenant, rows in (("ui", 4), ("batch", 24)):
            ids = r.integers(0, N, rows).astype(np.int64)
            eng.submit(Query(100 * t + rows, ids, tenant=tenant))
        log = eng.mutate()
        log.add_edge(int(r.integers(0, N)), int(r.integers(0, N)))
        eng.run()
    yield s, eng
    s.close()


def test_cluster_attribution_reconciles_within_gate(qos_cluster):
    from repro.obs.report import ATTRIBUTION_TOLERANCE
    s, _ = qos_cluster
    st = s.stats()
    attribution = st.get("attribution", {})
    assert set(attribution) == {"ui", "batch"}
    for tenant, doc in attribution.items():
        assert doc["n_queries"] > 0
        frac = doc["attributed_frac"]
        assert abs(frac - 1.0) <= ATTRIBUTION_TOLERANCE, \
            f"tenant {tenant}: merged attribution closes at {frac:.3f}"
    tenants = st["tenants"]
    assert set(tenants) == {"ui", "batch"}
    assert tenants["ui"]["staleness_slo"] == 4


def test_router_healthz_aggregates_per_shard_health(qos_cluster):
    s, _ = qos_cluster
    ep = s.cluster.endpoint
    assert ep is not None and ep.port
    base = f"http://127.0.0.1:{ep.port}"
    with urllib.request.urlopen(f"{base}/healthz", timeout=10) as r:
        doc = json.loads(r.read())
    assert doc["status"] in ("ok", "alerting")
    assert [sh["shard"] for sh in doc["shards"]] == [0, 1]
    for sh in doc["shards"]:
        assert sh["status"] in ("ok", "alerting")
    with urllib.request.urlopen(f"{base}/shards", timeout=10) as r:
        shards = json.loads(r.read())
    assert shards["router"]["n_lookups"] > 0
    assert len(shards["shards"]) == 2
    with urllib.request.urlopen(f"{base}/stats", timeout=10) as r:
        st = json.loads(r.read())
    assert st["cluster"]["n_shards"] == 2


def test_merge_health_fires_if_any_shard_fires():
    ok = {"n_alerts": 0, "alerts": [], "burn_rate": {"ui": 0.1},
          "wait_burn_rate": {}, "firing": []}
    bad = {"n_alerts": 2,
           "alerts": [{"kind": "slo_burn", "tenant": "ui"}],
           "burn_rate": {"ui": 2.5}, "wait_burn_rate": {},
           "firing": ["slo_burn:ui"]}
    merged = merge_health([ok, bad])
    assert merged["status"] == "alerting"
    assert merged["firing"] == ["shard1:slo_burn:ui"]
    assert merged["burn_rate"]["ui"] == 2.5    # worst shard wins
    assert merged["alerts"][0]["shard"] == 1
    assert [s["status"] for s in merged["shards"]] == ["ok", "alerting"]
    assert merge_health([ok, ok])["status"] == "ok"
