"""Memory-budgeted store equivalence: with residency capped at 25% / 50%
a store must serve rows BITWISE-equal to an unbudgeted one — across all
three models, the ref and pallas executors (dist runs the same check in
``tests/helpers/dist_check.py::check_evict_equivalence``), and through
mutated refreshes whose staged-overlay reads themselves miss and
recompute.  Plus the engine-level guarantees: snapshot pinning beats
mid-query eviction, and the stats surface the memory model."""
import copy

import jax
import numpy as np
import pytest

from repro.core.gnn_models import init_gat, init_gcn, init_sage
from repro.core.graph import csr_from_edges, rmat_edges
from repro.gnnserve import (DeltaReinference, EmbeddingServeEngine,
                            MutationLog, Query, apply_edge_mutations,
                            attach_recompute, store_from_inference)
from repro.core.sampler import sample_layer_graphs

N, D, L, FANOUT = 256, 16, 2, 6


@pytest.fixture(scope="module")
def world():
    src, dst = rmat_edges(N, N * 8, seed=5)
    g = csr_from_edges(src, dst, N)
    lgs = sample_layer_graphs(g, fanout=FANOUT, n_layers=L, seed=2)
    rng = np.random.default_rng(4)
    X = rng.standard_normal((N, D), dtype=np.float32)
    return g, src, dst, lgs, X


def _params(model):
    key = jax.random.PRNGKey(0)
    dims = [D] * (L + 1)
    return {"gcn": lambda: init_gcn(key, dims),
            "sage": lambda: init_sage(key, dims),
            "gat": lambda: init_gat(key, dims, heads=4)}[model]()


def _build(lgs, X, model, params, executor, budget, policy="heat"):
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], model, params,
                          executor=executor)
    store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4,
                                 budget_rows=budget, evict_policy=policy)
    if budget is not None:
        attach_recompute(store, ri)
    return ri, store


def _mutation(rng, src, dst, n_edge=8, n_feat=3):
    log = MutationLog()
    log.add_edges(rng.integers(0, N, n_edge), rng.integers(0, N, n_edge))
    pick = rng.choice(src.size, n_edge, replace=False)
    log.remove_edges(src[pick], dst[pick])
    fid = rng.choice(N, n_feat, replace=False)
    log.update_features(fid, rng.standard_normal((n_feat, D),
                                                 dtype=np.float32))
    return log.drain()


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
@pytest.mark.parametrize("executor", ["ref", "pallas"])
@pytest.mark.parametrize("frac", [0.25, 0.5])
def test_budgeted_store_bitwise_equal(world, model, executor, frac):
    g, src, dst, lgs, X = world
    params = _params(model)
    ri_o, oracle = _build(lgs, X, model, params, executor, None)
    ri_b, store = _build(lgs, X, model, params, executor, int(N * frac))
    all_ids = np.arange(N)
    rng = np.random.default_rng(7)

    # cold scan: the budgeted store rebuilds every evicted row on demand
    for lvl in range(L + 1):
        np.testing.assert_array_equal(store.lookup(all_ids, lvl),
                                      oracle.lookup(all_ids, lvl))
    assert store.stats()["n_evictions"] > 0
    assert store.stats()["misses"] > 0

    # two mutated refreshes in lockstep; mid-refresh reads go through
    # the staged overlay and hit evicted shards (recompute through it)
    gm = g
    for _ in range(2):
        batch = _mutation(rng, src, dst)
        gm = apply_edge_mutations(gm, batch)
        ri_o.refresh(oracle, gm, batch.feat_ids, batch.feat_rows,
                     batch.affected_dsts())
        miss0 = store.misses
        ri_b.refresh(store, gm, batch.feat_ids, batch.feat_rows,
                     batch.affected_dsts())
        assert store.misses > miss0          # the overlay path was used
        ids = rng.choice(N, 64, replace=False)
        np.testing.assert_array_equal(store.lookup(ids, -1),
                                      oracle.lookup(ids, -1))
    for lvl in range(L + 1):
        np.testing.assert_array_equal(store.lookup(all_ids, lvl),
                                      oracle.lookup(all_ids, lvl))


@pytest.mark.parametrize("policy", ["heat", "lru"])
def test_policies_evict_cold_not_hot(world, policy):
    """Both policies must keep a repeatedly-hit shard resident and evict
    the never-touched ones."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri, store = _build(lgs, X, "gcn", params, "ref", N // 4, policy)
    hot = np.arange(0, N // 4)               # shard 0, exactly the budget
    for _ in range(6):
        store.lookup(hot, 1)
    assert store.resident_rows(1) <= N // 4
    misses_before = store.misses
    store.lookup(hot, 1)                     # still resident: all hits
    assert store.misses == misses_before


def test_scan_resistant_admission_keeps_hot_set(world):
    """Rows recomputed on a miss and touched exactly once are admitted
    on PROBATION (zero heat): a one-shot full scan leaves its shards
    stone-cold and the hot working set survives the next eviction
    round.  ``admission="full"`` (the pre-satellite behavior) shows the
    failure mode: the scan's fresh heat outbids the decayed hot shard."""
    from repro.gnnserve import EmbeddingStore
    g, src, dst, lgs, X = world
    params = _params("gcn")
    extra_misses = {}
    for admission in ("probation", "full"):
        ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn",
                              params)
        levels = ri.full_levels(X)
        store = EmbeddingStore(levels, n_shards=4, budget_rows=N // 4,
                               evict_policy="heat", heat_decay=0.5,
                               admission=admission)
        attach_recompute(store, ri)
        hot = np.arange(N // 4)              # exactly shard 0
        store.lookup(hot, 1)                 # admit (probationary)
        store.lookup(hot, 1)                 # second touch: earns heat
        store.lookup(np.arange(N // 4, N), 1)   # one-shot cold scan
        m0 = store.misses
        store.lookup(hot, 1)
        extra_misses[admission] = store.misses - m0
    assert extra_misses["probation"] == 0, \
        "one-shot scan evicted the hot working set despite probation"
    assert extra_misses["full"] > 0          # the mode probation fixes


def test_probationary_rows_serve_identical_bytes(world):
    """Probation only shapes the heat map — admitted bytes are the same
    either way, including across a mutated refresh."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    stores = {}
    rng_m = np.random.default_rng(2)
    batch = _mutation(rng_m, src, dst)
    g2 = apply_edge_mutations(g, batch)
    for admission in ("probation", "full"):
        ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn",
                              params)
        store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4,
                                     budget_rows=N // 4,
                                     admission=admission)
        attach_recompute(store, ri)
        ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
                   batch.affected_dsts())
        stores[admission] = store
    rng = np.random.default_rng(6)
    for _ in range(4):
        ids = rng.choice(N, 48, replace=False)
        lvl = int(rng.integers(1, L + 1))
        np.testing.assert_array_equal(
            stores["probation"].lookup(ids, lvl),
            stores["full"].lookup(ids, lvl))


def test_mid_query_eviction_cannot_tear(world):
    """A query pinned at epoch v must serve epoch-v bits even when a
    refresh commits AND the budget evicts its shards mid-query."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri, store = _build(lgs, X, "gcn", params, "ref", N // 4)
    levels_v0 = [store.lookup(np.arange(N), lvl).copy()
                 for lvl in range(L + 1)]
    eng = EmbeddingServeEngine(store, ri, g, batch_slots=2,
                               rows_per_step=16, staleness_bound=4)
    q = Query(uid=0, node_ids=np.arange(64))
    eng.submit(q)
    eng.step()                               # pins epoch 0, gathers 0..15
    rng = np.random.default_rng(9)
    eng.mutate().add_edges(rng.integers(0, N, 6), rng.integers(0, N, 6))
    # thrash the budget between this query's gathers with competing
    # queries over DIFFERENT rows (forces evictions of q's shards)
    eng.submit(Query(uid=1, node_ids=np.arange(N - 64, N)))
    eng.run()                                # refresh + evictions inside
    assert eng.store.version == 1
    assert q.done and q.served_version == 0
    np.testing.assert_array_equal(q.out, levels_v0[-1][q.node_ids])


def test_fused_gather_across_pins_survives_eviction(world):
    """Two queries pinned at the same version can hold DIFFERENT shard
    arrays when the budget evicts + re-admits between their pins; after
    a mid-flight epoch flip the fused gather must fall back to each
    query's own snapshot instead of raising SnapshotMiss — and both
    responses stay on their pinned epoch."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri, store = _build(lgs, X, "gcn", params, "ref", N // 4)  # 1 shard
    levels_v0 = store.lookup(np.arange(N), -1).copy()
    eng = EmbeddingServeEngine(store, ri, g, batch_slots=2,
                               rows_per_step=16, staleness_bound=4)
    q1 = Query(uid=0, node_ids=np.arange(3 * (N // 4), N))     # shard 3
    q2 = Query(uid=1, node_ids=np.arange(0, N // 4))           # shard 0
    eng.submit(q1)
    eng.submit(q2)
    eng.step()               # both pin v0; q2's pin evicts q1's shard
    rng = np.random.default_rng(21)
    eng.mutate().add_edges(rng.integers(0, N, 6), rng.integers(0, N, 6))
    eng.run()                # refresh commits mid-flight, gathers resume
    assert eng.store.version == 1
    assert q1.done and q2.done
    assert q1.served_version == 0 and q2.served_version == 0
    np.testing.assert_array_equal(q1.out, levels_v0[q1.node_ids])
    np.testing.assert_array_equal(q2.out, levels_v0[q2.node_ids])


def test_failed_refresh_drops_mid_refresh_subset_plans(world):
    """A refresh that fails AFTER resampling rolls the layer graphs back
    in place; any frontier plan cached between the resample and the
    failure (the dist layer loop does this) describes samples that no
    longer exist and must be invalidated with the rollback."""
    from repro.core.partition import build_subset_plan_cached
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri, store = _build(lgs, X, "gcn", params, "ref", None)
    rng = np.random.default_rng(31)
    batch = _mutation(rng, src, dst)
    g2 = apply_edge_mutations(g, batch)
    rows = np.arange(0, N, 4, dtype=np.int64)
    leaked = {}

    def cache_then_fail(l, r, read_level):
        # what DistExecutor.run_rows does right before compute
        leaked["plan"] = build_subset_plan_cached(ri.layer_graphs[0],
                                                  rows, 4)
        raise ValueError("injected layer failure")

    orig = ri._layer_rows
    ri._layer_rows = cache_then_fail
    with pytest.raises(ValueError):
        ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
                   batch.affected_dsts())
    ri._layer_rows = orig
    assert store.version == 0                    # nothing committed
    # the plan cached over the rolled-back samples must NOT be served
    assert build_subset_plan_cached(ri.layer_graphs[0], rows, 4) \
        is not leaked["plan"]


def test_stats_surface_memory_model(world):
    """`stats()`/`memory_stats()` expose resident bytes per level and
    budget utilization without reaching into `_front` (the satellite)."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri, store = _build(lgs, X, "gcn", params, "ref", N // 2)
    mem = store.memory_stats()
    assert set(mem) == {f"level{i}" for i in range(L + 1)}
    for level, v in enumerate(mem.values()):
        assert v["resident_bytes"] == v["resident_rows"] * D * 4
        if level > 0:
            assert v["resident_rows"] <= N // 2
            assert 0.0 <= v["budget_util"] <= 1.0
    # level 0 is pinned and fully resident
    assert mem["level0"]["resident_rows"] == N
    s = store.stats()
    for key in ("hits", "misses", "hit_rate", "n_evictions",
                "rows_evicted", "n_recomputes", "n_recompute_spans",
                "rows_recomputed", "recompute_s", "resident_bytes",
                "budget_rows", "budget_util"):
        assert key in s, key
    assert s["budget_rows"] == N // 2
    eng = EmbeddingServeEngine(store, ri, g)
    for key in ("store_hit_rate", "store_n_evictions",
                "store_resident_bytes", "store_budget_util"):
        assert key in eng.stats(), key
