"""Mamba-2 SSD: chunked scan vs naive recurrence; decode continues prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm as ssm_mod
from repro.models.ssm import SSMCache, mamba2_block, mamba2_decode, ssd_chunked


def naive_ssd(x, dt, A, B_, C_):
    """Token-by-token linear recurrence oracle."""
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    state = np.zeros((Bsz, H, N, P), np.float64)
    y = np.zeros((Bsz, S, H, P), np.float64)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Bf = np.repeat(np.asarray(B_, np.float64), rep, axis=2)
    Cf = np.repeat(np.asarray(C_, np.float64), rep, axis=2)
    Af = np.asarray(A, np.float64)
    for t in range(S):
        dA = np.exp(dtf[:, t] * Af)                       # (B,H)
        upd = np.einsum("bhn,bhp->bhnp", Bf[:, t] * dtf[:, t][..., None],
                        xf[:, t])
        state = state * dA[..., None, None] + upd
        y[:, t] = np.einsum("bhn,bhnp->bhp", Cf[:, t], state)
    return y, state


@pytest.mark.parametrize("S,chunk", [(32, 8), (48, 16), (16, 16)])
def test_ssd_chunked_vs_naive(S, chunk, rng):
    Bsz, H, P, G, N = 2, 4, 8, 1, 16
    x = jnp.asarray(rng.standard_normal((Bsz, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.random((Bsz, S, H)).astype(np.float32) * 0.5 + 0.1)
    A = -jnp.asarray(rng.random(H).astype(np.float32) + 0.5)
    B_ = jnp.asarray(rng.standard_normal((Bsz, S, G, N)).astype(np.float32))
    C_ = jnp.asarray(rng.standard_normal((Bsz, S, G, N)).astype(np.float32))
    y, final = ssd_chunked(x, dt, A, B_, C_, chunk)
    y_ref, final_ref = naive_ssd(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y, np.float64), y_ref,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final, np.float64), final_ref,
                               atol=1e-4, rtol=1e-4)


def test_block_decode_continues_prefill(rng):
    """Prefill S tokens with return_state, decode token S, compare vs a
    full S+1 prefill."""
    cfg = get_config("mamba2-1.3b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    p = ssm_mod.init_ssm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S, D = 2, 16, cfg.d_model
    x = jnp.asarray(rng.standard_normal((B, S + 1, D)).astype(np.float32)
                    * 0.2)
    full = mamba2_block(x, p, cfg)
    out_pre, cache = mamba2_block(x[:, :S], p, cfg, return_state=True)
    np.testing.assert_allclose(np.asarray(full)[:, :S],
                               np.asarray(out_pre), atol=1e-4, rtol=1e-4)
    out_dec, _ = mamba2_decode(x[:, S:S + 1], p, cfg, cache)
    np.testing.assert_allclose(np.asarray(out_dec)[:, 0],
                               np.asarray(full)[:, S], atol=1e-4,
                               rtol=1e-4)


def test_cache_shapes(rng):
    cfg = get_config("mamba2-1.3b").reduced()
    c = ssm_mod.init_ssm_cache(3, cfg, jnp.float32)
    s = cfg.ssm
    assert c.conv.shape == (3, s.d_conv - 1,
                            s.d_inner + 2 * s.n_groups * s.d_state)
    assert c.state.shape == (3, s.n_heads, s.d_state, s.head_dim)
