"""Preemptible chunked refresh: RefreshJob chunking is bitwise-invariant
(any chunk size produces the one-shot refresh's exact store bytes, on
every executor), the QoS engine interleaves chunks with tenant gathers
(a strict tenant's gather is admitted BETWEEN chunks instead of waiting
out the whole frontier), and chunked engines serve the exact bits of
inline engines under identical traffic."""
import copy

import jax
import numpy as np
import pytest

from repro import obs
from repro.core.gnn_models import init_gcn
from repro.core.graph import csr_from_edges, rmat_edges
from repro.core.sampler import sample_layer_graphs
from repro.gnnserve import (DeltaReinference, EmbeddingServeEngine,
                            MutationLog, Query, apply_edge_mutations,
                            parse_tenants, store_from_inference)

N, D, L, FANOUT = 384, 16, 3, 6


@pytest.fixture(scope="module")
def world():
    src, dst = rmat_edges(N, N * 8, seed=21)
    g = csr_from_edges(src, dst, N)
    lgs = sample_layer_graphs(g, fanout=FANOUT, n_layers=L, seed=4)
    X = np.random.default_rng(6).standard_normal((N, D), dtype=np.float32)
    params = init_gcn(jax.random.PRNGKey(2), [D] * (L + 1))
    return g, src, dst, lgs, X, params


def _fresh(world, executor="ref"):
    g, src, dst, lgs, X, params = world
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params,
                          executor=executor)
    store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4)
    return ri, store


def _batch(world, rng, n_edge=24, n_feat=16):
    g, src, dst, *_ = world
    log = MutationLog()
    log.add_edges(rng.integers(0, N, n_edge), rng.integers(0, N, n_edge))
    pick = rng.choice(src.size, n_edge, replace=False)
    log.remove_edges(src[pick], dst[pick])
    log.update_features(
        rng.choice(N, n_feat, replace=False),
        rng.standard_normal((n_feat, D), dtype=np.float32))
    return log.drain()


# ----------------------------------------------------------------------
# RefreshJob: chunked == one-shot, bitwise
# ----------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["ref", "pallas"])
@pytest.mark.parametrize("chunk", [7, 64, 10 ** 9])
def test_chunked_refresh_bitwise_equals_inline(world, executor, chunk):
    """Any chunk size — misaligned, pow2-bucket-sized, or larger than
    every frontier — commits the exact bytes of the one-shot refresh."""
    g = world[0]
    batch = _batch(world, np.random.default_rng(31))
    g2 = apply_edge_mutations(g, batch)

    ri_a, store_a = _fresh(world, executor)
    stats_a = ri_a.refresh(store_a, g2, batch.feat_ids, batch.feat_rows,
                           batch.affected_dsts())

    ri_b, store_b = _fresh(world, executor)
    job = ri_b.begin_refresh(store_b, g2, batch.feat_ids, batch.feat_rows,
                             batch.affected_dsts(), chunk_rows=chunk)
    n_steps = 0
    while not job.done:
        info = job.step()
        n_steps += 1
        assert info["rows"] <= (chunk if chunk > 0 else 10 ** 18)
    stats_b = job.finish()

    assert stats_b["n_chunks"] == n_steps
    if chunk >= N:                      # chunk > frontier: one per layer
        assert stats_b["n_chunks"] == sum(
            1 for f in job.frontier if f.size)
    else:
        assert stats_b["n_chunks"] > stats_a["n_chunks"]
    assert stats_b["version"] == stats_a["version"] == 1
    # chunking re-gathers neighbors shared across chunk boundaries, so
    # the WORK counter may grow — the committed bits are what's invariant
    assert stats_b["rows_gemm"] >= stats_a["rows_gemm"]
    assert stats_b["frontier_sizes"] == stats_a["frontier_sizes"]
    all_ids = np.arange(N)
    for lvl in range(1, ri_a.n_layers + 1):
        np.testing.assert_array_equal(store_b.lookup(all_ids, lvl),
                                      store_a.lookup(all_ids, lvl),
                                      err_msg=f"level {lvl}")


def test_chunk_boundaries_do_not_leak_into_resample_seeds(world):
    """Two different chunk sizes over the SAME mutations agree bitwise —
    the content-addressed resample seeds carry no chunk term."""
    g = world[0]
    batch = _batch(world, np.random.default_rng(41))
    g2 = apply_edge_mutations(g, batch)
    stores = []
    for chunk in (5, 113):
        ri, store = _fresh(world)
        job = ri.begin_refresh(store, g2, batch.feat_ids, batch.feat_rows,
                               batch.affected_dsts(), chunk_rows=chunk)
        while not job.done:
            job.step()
        job.finish()
        stores.append(store)
    for lvl in range(1, L + 1):
        np.testing.assert_array_equal(
            stores[0].lookup(np.arange(N), lvl),
            stores[1].lookup(np.arange(N), lvl))


def test_refresh_job_abort_rolls_back_store_and_graphs(world):
    """abort() mid-job leaves readers on the committed epoch and the
    layer graphs on their pre-resample rows; a clean retry then matches
    the one-shot oracle."""
    g = world[0]
    batch = _batch(world, np.random.default_rng(51))
    g2 = apply_edge_mutations(g, batch)
    ri, store = _fresh(world)
    before = store.lookup(np.arange(N), -1).copy()
    nbr0 = ri.layer_graphs[0].nbr.copy()
    job = ri.begin_refresh(store, g2, batch.feat_ids, batch.feat_rows,
                           batch.affected_dsts(), chunk_rows=16)
    job.step()
    job.abort()
    assert store.version == 0
    np.testing.assert_array_equal(store.lookup(np.arange(N), -1), before)
    np.testing.assert_array_equal(ri.layer_graphs[0].nbr, nbr0)
    with pytest.raises(AssertionError):
        job.step()                      # dead job refuses further work

    ri2, store2 = _fresh(world)         # clean retry == one-shot oracle
    ri2.refresh(store2, g2, batch.feat_ids, batch.feat_rows,
                batch.affected_dsts())
    ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
               batch.affected_dsts())
    np.testing.assert_array_equal(store.lookup(np.arange(N), -1),
                                  store2.lookup(np.arange(N), -1))


def test_chunk_spans_and_layer_spans_emitted(world):
    """Each chunk step emits a ``refresh.chunk`` span nested in a
    ``refresh.layer`` span (the metric tests key on the latter)."""
    g = world[0]
    batch = _batch(world, np.random.default_rng(61))
    g2 = apply_edge_mutations(g, batch)
    ri, store = _fresh(world)
    tel = obs.Telemetry(enabled=True)
    with obs.use(tel):
        job = ri.begin_refresh(store, g2, batch.feat_ids, batch.feat_rows,
                               batch.affected_dsts(), chunk_rows=32)
        while not job.done:
            job.step()
        stats = job.finish()
    m = tel.metrics.to_dict()
    assert m["refresh.chunk_ms.count"] == stats["n_chunks"] > L
    assert m["refresh.layer_ms.count"] == stats["n_chunks"]


# ----------------------------------------------------------------------
# QoS engine: chunked schedule == inline schedule, bit for bit
# ----------------------------------------------------------------------

def _engine(world, *, chunk_rows=0, onboarding="none",
            tenants="ui:4:2:0:4,batch:1:1:0:64"):
    g, src, dst, lgs, X, params = world
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params)
    store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4,
                                 onboarding=onboarding)
    return EmbeddingServeEngine(store, ri, g, batch_slots=4,
                                rows_per_step=64,
                                tenants=parse_tenants(tenants),
                                refresh_chunk_rows=chunk_rows)


def test_chunked_engine_bitwise_equals_inline_engine(world):
    """Identical tick-drained traffic through a chunked and an inline
    QoS engine: every query's bytes AND served version agree, and so do
    the final store bits — chunking changes scheduling, never results."""
    engines = {c: _engine(world, chunk_rows=c) for c in (0, 16)}
    rng = np.random.default_rng(71)
    pairs = []
    for tick in range(10):
        ids = {"ui": rng.integers(0, N, 24),
               "batch": rng.integers(0, N, 96)}
        per_engine = {}
        for c, eng in engines.items():
            qs = {name: Query(uid=tick, node_ids=ids[name], tenant=name)
                  for name in ("ui", "batch")}
            for q in qs.values():
                eng.submit(q)
            per_engine[c] = qs
        s_e, d_e = rng.integers(0, N, 3), rng.integers(0, N, 3)
        fid = rng.choice(N, 4, replace=False)
        frows = rng.standard_normal((4, D), dtype=np.float32)
        for c, eng in engines.items():
            eng.mutate().add_edges(s_e, d_e)
            eng.mutate().update_features(fid, frows)
            eng.run()
        for name in ("ui", "batch"):
            pairs.append((name, per_engine[0][name], per_engine[16][name]))
    inline, chunked = engines[0], engines[16]
    assert inline.n_refreshes == chunked.n_refreshes > 0
    assert chunked.n_refresh_chunks > chunked.n_refreshes  # really split
    assert inline.n_refresh_chunks == 0
    for name, qi, qc in pairs:
        assert qi.done and qc.done
        assert qi.served_version == qc.served_version, (name, qi.uid)
        np.testing.assert_array_equal(qi.out, qc.out,
                                      err_msg=str((name, qi.uid)))
    for lvl in range(1, L + 1):
        np.testing.assert_array_equal(
            inline.store.lookup(np.arange(N), lvl),
            chunked.store.lookup(np.arange(N), lvl))


def test_strict_gather_admitted_between_chunks(world):
    """The stall fix itself: while a batch-triggered refresh job is in
    flight, a strict tenant's NEW query is pinned and gathered between
    chunks — it completes before the job commits — while the demanding
    tenant's query waits for the commit."""
    eng = _engine(world, chunk_rows=2,
                  tenants="ui:4:2:0:100000,batch:1:1:0:2")
    rng = np.random.default_rng(81)
    # a big feature burst => a frontier of hundreds of rows => with
    # chunk_rows=2 the job needs many steps to drain
    for lo in range(0, 128, 16):
        eng.mutate().update_features(
            np.arange(lo, lo + 16, dtype=np.int64),
            rng.standard_normal((16, D), dtype=np.float32))
    qb = Query(uid=0, node_ids=rng.integers(0, N, 8), tenant="batch")
    eng.submit(qb)
    eng.step()                          # batch is due -> job opens
    assert eng._rjob is not None and not qb.done
    qu = Query(uid=1, node_ids=rng.integers(0, N, 8), tenant="ui")
    eng.submit(qu)
    while not qu.done:
        assert eng._rjob is not None, \
            "job drained before the strict gather finished"
        eng.step()
    assert eng._rjob is not None        # ui finished BETWEEN chunks
    assert qu.served_version == 0       # at its (current) pinned view
    assert not qb.done                  # the demander still waits
    eng.run()
    assert qb.done and qb.served_version == eng.store.version == 1
    assert eng.n_refresh_chunks > 10
    np.testing.assert_array_equal(
        qb.out, eng.store.lookup(qb.node_ids, -1))
    ts = eng.stats()["tenants"]
    assert ts["batch"]["n_deferred_pins"] > 0   # held behind its own job


def test_fresh_query_waits_for_chunked_commit(world):
    """fresh=True under chunking: the query's tenant joins the waiters
    and its response carries the post-refresh epoch."""
    eng = _engine(world, chunk_rows=4,
                  tenants="ui:4:2:0:100000,batch:1:1:0:100000")
    rng = np.random.default_rng(91)
    eng.mutate().update_features(
        np.arange(48, dtype=np.int64),
        rng.standard_normal((48, D), dtype=np.float32))
    q = Query(uid=0, node_ids=rng.integers(0, N, 12), tenant="ui",
              fresh=True)
    eng.submit(q)
    eng.run()
    assert q.done and q.served_version == eng.store.version == 1
    assert eng.n_refreshes == 1 and eng.n_refresh_chunks > 1
    np.testing.assert_array_equal(q.out, eng.store.lookup(q.node_ids, -1))


def test_chunked_onboarding_under_qos(world):
    """Node adds ride a chunked job: the tail commits atomically with
    the last chunk, and a mid-job tail-id query waits for it."""
    eng = _engine(world, chunk_rows=8, onboarding="tail",
                  tenants="ui:4:2:0:2,batch:1:1:0:100000")
    rng = np.random.default_rng(101)
    eng.mutate().add_nodes(3, rng.standard_normal((3, D), np.float32))
    new = np.arange(N, N + 3)
    eng.mutate().add_edges(rng.integers(0, N, 6), np.repeat(new, 2))
    qt = Query(uid=0, node_ids=np.arange(N - 1, N + 3), tenant="batch")
    eng.submit(qt)
    eng.submit(Query(uid=1, node_ids=rng.integers(0, N, 8), tenant="ui"))
    eng.run()
    assert eng.n_onboarded == 3 and eng.store.n_nodes == N + 3
    assert eng.n_refresh_chunks > 1
    assert qt.done and qt.served_version == eng.store.version
    np.testing.assert_array_equal(qt.out,
                                  eng.store.lookup(qt.node_ids, -1))
