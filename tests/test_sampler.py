"""Layer-wise sampler: all sampled neighbors are true in-neighbors."""
import numpy as np

from repro.core.sampler import (frontier_sizes, sample_ego_networks,
                                sample_layer_graphs)


def test_sampled_neighbors_are_real(small_graph, layer_graphs):
    g = small_graph
    for lg in layer_graphs:
        for v in range(0, g.n_nodes, 17):
            true = set(g.neighbors(v).tolist())
            got = lg.nbr[v][lg.mask[v]]
            if not true:
                assert not lg.mask[v].any()
            else:
                assert set(got.tolist()) <= true


def test_small_rows_take_every_neighbor(small_graph, layer_graphs):
    g = small_graph
    deg = g.degrees()
    lg = layer_graphs[0]
    for v in np.where((deg > 0) & (deg <= lg.fanout))[0][:50]:
        got = sorted(set(lg.nbr[v][lg.mask[v]].tolist()))
        assert got == sorted(set(g.neighbors(v).tolist()))


def test_layers_are_independent(small_graph):
    lgs = sample_layer_graphs(small_graph, fanout=4, n_layers=2, seed=0)
    assert not np.array_equal(lgs[0].nbr, lgs[1].nbr)


def test_deterministic(small_graph):
    a = sample_layer_graphs(small_graph, fanout=4, n_layers=2, seed=5)
    b = sample_layer_graphs(small_graph, fanout=4, n_layers=2, seed=5)
    assert np.array_equal(a[0].nbr, b[0].nbr)
    assert np.array_equal(a[1].mask, b[1].mask)


def test_ego_baseline_and_frontiers(small_graph, layer_graphs):
    targets = np.arange(8)
    egos = sample_ego_networks(small_graph, targets, fanout=4, n_layers=2)
    assert len(egos) == 8 and all(len(h) == 3 for h in egos)
    fr = frontier_sizes(layer_graphs[:2], targets)
    assert fr[0].size <= fr[1].size <= fr[2].size
