"""Multi-tenant QoS scheduler invariants (gnnserve.qos).

Property-style suite (reusing the ``seed_property`` harness of
``test_gnnserve_properties``) over the scheduler and the QoS engine:

  1. quota conservation: every allocation grants sum(slots) <= B and
     sum(rows) <= rows_per_step, never more than a slot's need, and
     never exceeds a tenant's token bucket;
  2. no starvation: every admitted query with work left makes progress
     within K steps (K = 1 for unlimited-rate tenants, ceil(slots/rate)
     for rate-limited ones) — even while refresh charges depress the
     DRR credit;
  3. SLO safety + monotonicity: observed staleness stays strictly under
     each tenant's SLO, and TIGHTENING one tenant's SLO never changes
     another tenant's bits (it can only refresh the shared store more
     often, which the lagged per-tenant views hide);
  4. per-tenant bitwise equality: each tenant's outputs equal a
     single-tenant engine run at that tenant's SLO, bit for bit, for
     ref AND pallas executors (content-addressed resampling makes
     refresh batching invariant);
  5. preemptive quota reclaim: a saturating batch tenant cannot delay a
     quota-holding tenant's admission, and a preempted query resumes
     without tearing (its pinned epoch is preserved).
"""
import copy

import numpy as np
import pytest

from repro.core.gnn_models import init_gcn
from repro.core.graph import csr_from_edges, rmat_edges
from repro.core.sampler import sample_layer_graphs
from repro.gnnserve import (DeltaReinference, EmbeddingServeEngine, Query,
                            QoSScheduler, TenantRegistry, TenantSpec,
                            parse_tenants, store_from_inference)
from test_gnnserve_properties import seed_property

N, D, L, FANOUT = 256, 16, 2, 4


@pytest.fixture(scope="module")
def world():
    src, dst = rmat_edges(N, N * 6, seed=5)
    g = csr_from_edges(src, dst, N)
    lgs = sample_layer_graphs(g, fanout=FANOUT, n_layers=L, seed=2)
    X = np.random.default_rng(3).standard_normal((N, D), dtype=np.float32)
    import jax
    params = init_gcn(jax.random.PRNGKey(0), [D] * (L + 1))
    return g, src, dst, lgs, X, params


def _engine(world, *, tenants=None, bound=64, executor="ref",
            batch_slots=4, rows_per_step=64):
    g, src, dst, lgs, X, params = world
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params,
                          executor=executor)
    store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4)
    return EmbeddingServeEngine(store, ri, g, batch_slots=batch_slots,
                                rows_per_step=rows_per_step,
                                staleness_bound=bound, tenants=tenants)


# ----------------------------------------------------------------------
# registry / parsing
# ----------------------------------------------------------------------

def test_parse_tenants_roundtrip():
    reg = parse_tenants("ui:4:2:0:8,batch:1.5:1:96:512")
    assert reg.names == ["ui", "batch"]
    assert reg["ui"] == TenantSpec("ui", priority=4, slot_quota=2,
                                   rate=0, staleness_slo=8)
    assert reg["batch"].rate == 96 and reg["batch"].priority == 1.5
    assert reg.total_quota == 3
    with pytest.raises(ValueError):
        parse_tenants("ui:4:2:0")                   # missing field
    with pytest.raises(AssertionError):
        TenantRegistry([TenantSpec("a"), TenantSpec("a")])   # dup name
    with pytest.raises(AssertionError):
        TenantSpec("x", priority=0)                 # weight must be > 0


def test_quota_exceeding_slots_rejected():
    reg = parse_tenants("a:1:3:0:8,b:1:2:0:8")
    with pytest.raises(AssertionError):
        QoSScheduler(reg, batch_slots=4, rows_per_step=64)


# ----------------------------------------------------------------------
# (1) quota conservation — pure scheduler, random demands
# ----------------------------------------------------------------------

@seed_property()
def test_allocation_conserves_budget_and_tokens(seed):
    rng = np.random.default_rng(seed)
    n_tenants = int(rng.integers(1, 4))
    B = int(rng.integers(n_tenants, 7))
    budget = int(rng.integers(4, 200))
    specs = [TenantSpec(f"t{i}", priority=float(rng.integers(1, 8)),
                        slot_quota=1,
                        rate=float(rng.choice([0, 0, 4, 16])),
                        staleness_slo=8) for i in range(n_tenants)]
    sched = QoSScheduler(TenantRegistry(specs), batch_slots=B,
                         rows_per_step=budget)
    for _ in range(20):
        if rng.random() < 0.3:      # refresh charges mid-stream
            sched.charge_refresh(float(rng.integers(0, 4 * budget)))
        active, used = [], set()
        tokens_before = {s.name: (sched.state(s.name).tokens
                                  + s.rate)    # post-refill balance
                         for s in specs}
        for _ in range(int(rng.integers(1, B + 1))):
            slot = int(rng.integers(0, B))
            if slot in used:
                continue
            used.add(slot)
            active.append((slot, f"t{int(rng.integers(0, n_tenants))}",
                           int(rng.integers(0, 3 * budget))))
        grants = sched.allocate(active, budget)
        assert sum(grants.values()) <= budget           # row conservation
        by_name = {}
        for slot, name, need in active:
            assert grants.get(slot, 0) <= need          # never overfill
            by_name.setdefault(name, 0)
            by_name[name] += grants.get(slot, 0)
        for s in specs:                                 # token bucket cap
            if s.rate > 0 and s.name in by_name:
                cap = min(tokens_before[s.name], s.rate * sched.burst_steps)
                assert by_name[s.name] <= cap + 1e-9, s.name


# ----------------------------------------------------------------------
# (2) starvation bound
# ----------------------------------------------------------------------

@seed_property(max_examples=10, fallback=5)
def test_no_starvation_within_k_steps(seed):
    """Unlimited-rate tenants progress EVERY step; rate-limited tenants
    within K = ceil(active_slots / rate) steps — under adversarial
    priorities and steady refresh charges."""
    rng = np.random.default_rng(seed)
    specs = [TenantSpec("hog", priority=100.0, slot_quota=1, rate=0.0,
                        staleness_slo=10 ** 6),
             TenantSpec("meek", priority=1.0, slot_quota=1, rate=0.0,
                        staleness_slo=10 ** 6),
             TenantSpec("drip", priority=1.0, slot_quota=1,
                        rate=0.5, staleness_slo=10 ** 6)]
    B, budget = 3, 16
    sched = QoSScheduler(TenantRegistry(specs), batch_slots=B,
                         rows_per_step=budget)
    need = {0: 10 ** 6, 1: 10 ** 6, 2: 10 ** 6}
    names = {0: "hog", 1: "meek", 2: "drip"}
    since = {0: 0, 1: 0, 2: 0}
    K = {0: 1, 1: 1, 2: int(np.ceil(1 / 0.5))}
    for _ in range(60):
        if rng.random() < 0.5:
            sched.charge_refresh(float(rng.integers(0, 10 * budget)))
        grants = sched.allocate([(i, names[i], need[i]) for i in range(3)],
                                budget)
        for i in range(3):
            got = grants.get(i, 0)
            need[i] -= got
            since[i] = 0 if got > 0 else since[i] + 1
            assert since[i] < K[i] + 1, \
                f"slot {i} ({names[i]}) starved {since[i]} steps (K={K[i]})"


# ----------------------------------------------------------------------
# (3) SLO safety + monotonicity
# ----------------------------------------------------------------------

def _drive_pairs(eng, n, ticks, rng, sizes=(24, 96)):
    """Tick-drained mixed traffic; returns per-tenant query lists."""
    out = {"ui": [], "batch": []}
    for tick in range(ticks):
        for name, size in zip(("ui", "batch"), sizes):
            q = Query(uid=tick, node_ids=rng.integers(0, n, size),
                      tenant=name)
            eng.submit(q)
            out[name].append(q)
        k = 3
        eng.mutate().add_edges(rng.integers(0, n, k), rng.integers(0, n, k))
        eng.run()
    return out


@seed_property(max_examples=5, fallback=3)
def test_slo_safety_and_tightening_monotonicity(world, seed):
    """Observed staleness < SLO for every tenant; and tightening ui's
    SLO leaves batch's bits untouched."""
    outs = {}
    for ui_slo in (12, 3):
        eng = _engine(world, tenants=parse_tenants(
            f"ui:4:2:0:{ui_slo},batch:1:1:0:500"))
        qs = _drive_pairs(eng, N, 10, np.random.default_rng(seed))
        ts = eng.stats()["tenants"]
        assert ts["ui"]["staleness_max"] < ui_slo
        assert ts["batch"]["staleness_max"] < 500
        assert ts["ui"]["slo_violations"] == 0
        assert ts["batch"]["slo_violations"] == 0
        outs[ui_slo] = qs
    for q_loose, q_tight in zip(outs[12]["batch"], outs[3]["batch"]):
        np.testing.assert_array_equal(q_loose.out, q_tight.out)


# ----------------------------------------------------------------------
# (4) per-tenant bitwise equality vs a solo engine at the same SLO
# ----------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["ref", "pallas"])
def test_tenant_bitwise_equals_solo_run(world, executor):
    slos = {"ui": 4, "batch": 64}
    multi = _engine(world, executor=executor, tenants=parse_tenants(
        f"ui:4:2:0:{slos['ui']},batch:1:1:0:{slos['batch']}"))
    solos = {name: _engine(world, bound=slo, executor=executor)
             for name, slo in slos.items()}
    rng = np.random.default_rng(17)
    pairs = []
    for tick in range(12):
        ids = {"ui": rng.integers(0, N, 24),
               "batch": rng.integers(0, N, 96)}
        for name in ("ui", "batch"):
            qm = Query(uid=tick, node_ids=ids[name], tenant=name)
            qs = Query(uid=tick, node_ids=ids[name])
            multi.submit(qm)
            solos[name].submit(qs)
            pairs.append((name, qm, qs))
        s_e, d_e = rng.integers(0, N, 2), rng.integers(0, N, 2)
        for e in (multi, *solos.values()):
            e.mutate().add_edges(s_e, d_e)
            e.run()
    assert multi.n_refreshes > 0
    # the loose tenant really lagged behind the shared store's epochs
    ts = multi.stats()["tenants"]
    assert ts["batch"]["view_version"] < multi.store.version \
        or multi.n_refreshes == 0
    for name, qm, qs in pairs:
        assert qm.done and qs.done
        assert qm.served_version == qs.served_version, (name, qm.uid)
        np.testing.assert_array_equal(qm.out, qs.out, err_msg=str((name,
                                                                   qm.uid)))


# ----------------------------------------------------------------------
# (5) preemptive quota reclaim
# ----------------------------------------------------------------------

def test_preemption_reclaims_quota_without_tearing(world):
    """Batch scans saturate all slots (work-conserving lending); when ui
    arrives, a borrowed slot is preempted the SAME step, ui is admitted,
    and the paused scan later resumes and still serves one epoch."""
    g, src, dst, lgs, X, params = world
    eng = _engine(world, rows_per_step=32, tenants=parse_tenants(
        "ui:4:2:0:1000,batch:1:1:0:1000"))
    rng = np.random.default_rng(9)
    scans = [Query(uid=i, node_ids=rng.integers(0, N, 128), tenant="batch")
             for i in range(4)]
    for q in scans:
        eng.submit(q)
    eng.step()                          # all 4 slots lent to batch
    assert all(q is not None and q.tenant == "batch" for q in eng.slot_q)
    pinned_version = scans[0].served_version
    assert pinned_version == 0

    # mutate past nothing (slo huge) but refresh manually mid-flight to
    # move the store's epoch under the paused scans
    ui = [Query(uid=100 + i, node_ids=rng.integers(0, N, 16), tenant="ui")
          for i in range(2)]
    for q in ui:
        eng.submit(q)
    eng.step()
    # ui's quota (2) reclaimed two borrowed slots immediately
    in_slots = {q.tenant for q in eng.slot_q if q is not None}
    assert "ui" in in_slots
    n_ui = sum(1 for q in eng.slot_q if q is not None and q.tenant == "ui")
    assert n_ui == 2
    assert eng.stats()["tenants"]["batch"]["n_preemptions"] == 2

    eng.mutate().add_edges(rng.integers(0, N, 4), rng.integers(0, N, 4))
    eng.refresh()                       # epoch flips while scans paused
    eng.run()
    assert all(q.done for q in scans + ui)
    # paused scans resumed on their ORIGINAL pinned epoch: no torn reads
    levels_v0 = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn",
                                 params).full_levels(X)
    for q in scans:
        assert q.served_version == 0
        np.testing.assert_array_equal(q.out, levels_v0[-1][q.node_ids])


def test_budgeted_store_lagged_views_restart_without_tearing(world):
    """QoS on a memory-budgeted store: an old epoch is NOT
    reconstructible (recompute replays current graphs), so a lagged
    view that hits evicted rows must RESTART its query on the current
    epoch — fresher than the SLO requires, never staler, and never a
    byte from two epochs.  Oracle: an unbudgeted twin driven in
    lockstep (same refresh planning — eviction never changes it), whose
    per-version levels every served query must match at its
    served_version."""
    from repro.gnnserve import attach_recompute
    g, src, dst, lgs, X = world[:5]
    params = world[5]

    def build(budget):
        ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn",
                              params)
        store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4,
                                     budget_rows=budget)
        if budget is not None:
            attach_recompute(store, ri)
        reg = parse_tenants("ui:4:2:0:4,batch:1:1:0:1000")
        return EmbeddingServeEngine(store, ri, g, batch_slots=4,
                                    rows_per_step=64, tenants=reg)

    eng, twin = build(N // 4), build(None)
    oracle = {0: twin.store.lookup(np.arange(N), -1).copy()}
    rng = np.random.default_rng(29)
    queries = []
    for tick in range(10):
        ids = {"ui": rng.integers(0, N, 24),
               "batch": rng.integers(0, N, 96)}
        for name in ("ui", "batch"):
            qb = Query(uid=tick, node_ids=ids[name], tenant=name)
            qt = Query(uid=tick, node_ids=ids[name], tenant=name)
            eng.submit(qb)
            twin.submit(qt)
            queries.append((name, qb, qt))
        s_e, d_e = rng.integers(0, N, 3), rng.integers(0, N, 3)
        for e in (eng, twin):
            e.mutate().add_edges(s_e, d_e)
            e.run()
        oracle[twin.store.version] = twin.store.lookup(np.arange(N),
                                                       -1).copy()
    assert eng.n_refreshes == twin.n_refreshes > 0
    ts = eng.stats()["tenants"]
    # the lagged batch view really hit evicted rows and restarted
    assert ts["batch"]["n_view_restarts"] > 0
    assert ts["ui"]["slo_violations"] == 0
    for name, qb, qt in queries:
        assert qb.done and qt.done
        # the budgeted run may serve FRESHER (restart), never staler
        assert qb.served_version >= qt.served_version, (name, qb.uid)
        np.testing.assert_array_equal(          # one epoch, no torn bytes
            qb.out, oracle[qb.served_version][qb.node_ids],
            err_msg=str((name, qb.uid, qb.served_version)))


def test_idle_capacity_borrowing_is_free(world):
    """Work-conserving leftovers are use-it-or-lose-it: a tenant that
    soaked up idle capacity for many steps is NOT pinned to the minimum
    grant once contention returns — its DRR credit only ever pays for
    its weighted share."""
    reg = parse_tenants("ui:4:1:0:1000,batch:1:1:0:1000")
    sched = QoSScheduler(reg, batch_slots=4, rows_per_step=64)
    for _ in range(500):                  # ui idle, batch soaks all 64
        got = sched.allocate([(0, "batch", 10 ** 6)], 64)
        assert got[0] == 64
    grants = sched.allocate([(0, "batch", 10 ** 6), (1, "ui", 4)], 64)
    assert grants[1] == 4                 # ui takes its small need
    # batch gets its weighted share of the rest at once, not min-grant
    assert grants[0] >= 64 * (1 / 5) - 1
    assert grants[0] + grants[1] <= 64


def test_unknown_tenant_rejected(world):
    eng = _engine(world, tenants=parse_tenants("ui:1:1:0:8"))
    with pytest.raises(KeyError):
        eng.submit(Query(uid=0, node_ids=np.arange(4), tenant="nope"))


def test_plain_engine_unchanged_without_tenants(world):
    """No registry -> the engine is the PR-1 engine: global bound, FIFO,
    no qos state."""
    eng = _engine(world, bound=4)
    assert eng.qos is None
    q = Query(uid=0, node_ids=np.arange(32))
    eng.submit(q)
    eng.run()
    assert q.done and "tenants" not in eng.stats()
