"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.graph import csr_from_edges, csr_from_edges_distributed
from repro.core.partition import build_plan
from repro.core.sampler import sample_layer_graphs

edge_lists = st.integers(2, 6).flatmap(
    lambda logn: st.integers(1, 200).flatmap(
        lambda e: st.tuples(
            st.just(2 ** logn),
            st.lists(st.tuples(st.integers(0, 2 ** logn - 1),
                               st.integers(0, 2 ** logn - 1)),
                     min_size=e, max_size=e))))


@given(edge_lists)
@settings(max_examples=30, deadline=None)
def test_csr_roundtrip(data):
    """edges -> CSR -> edges is a multiset identity."""
    n, edges = data
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    g = csr_from_edges(src, dst, n)
    back = sorted((int(g.indices[i]), int(v))
                  for v in range(n)
                  for i in range(g.indptr[v], g.indptr[v + 1]))
    assert back == sorted(map(tuple, map(lambda e: (e[0], e[1]), edges)))


@given(edge_lists, st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_distributed_construction_equiv(data, workers):
    n, edges = data
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    g1 = csr_from_edges(src, dst, n)
    g2, _ = csr_from_edges_distributed(src, dst, n, n_workers=workers)
    assert np.array_equal(g1.indptr, g2.indptr)
    for v in range(n):
        assert sorted(g1.neighbors(v)) == sorted(g2.neighbors(v))


@given(edge_lists, st.integers(1, 2), st.sampled_from([1, 2, 4]),
       st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_partition_covers_all_edges(data, n_layers, P, seed):
    """Every masked layer-graph edge appears in exactly one plan group."""
    n, edges = data
    if n % P:
        return
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    g = csr_from_edges(src, dst, n)
    lgs = sample_layer_graphs(g, fanout=3, n_layers=n_layers, seed=seed)
    plan = build_plan(lgs, P, 1)
    for li, lp in enumerate(plan.layers):
        total = sum(int(lp.edge_mask[p, k].sum())
                    for p in range(P) for k in range(P))
        assert total == int(lgs[li].mask.sum())


@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_chunked_ce_matches_full(B_S, chunk, seed):
    """Chunked CE == full-logits CE for arbitrary S/chunk combos."""
    import jax
    import jax.numpy as jnp
    from repro.train.loss import chunked_softmax_xent
    rng = np.random.default_rng(seed)
    B, S, D, V = 2, B_S, 8, 11
    hid = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))
    head = jnp.asarray(rng.standard_normal((D, V)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    got = chunked_softmax_xent(hid, head, labels, chunk=chunk)
    logits = np.asarray(hid) @ np.asarray(head)
    logz = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) \
        + logits.max(-1)
    gold = np.take_along_axis(logits, np.asarray(labels)[..., None],
                              -1)[..., 0]
    want = (logz - gold).mean()
    np.testing.assert_allclose(float(got), want, atol=1e-4, rtol=1e-4)


@given(st.integers(2, 32), st.integers(1, 6), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_mean_weights_rowsum(n, f, seed):
    """mean_weights rows sum to 1 where any neighbor exists, else 0."""
    from repro.core.gnn_models import mean_weights
    rng = np.random.default_rng(seed)
    mask = rng.random((n, f)) > 0.5
    w = mean_weights(mask)
    sums = w.sum(1)
    has = mask.any(1)
    np.testing.assert_allclose(sums[has], 1.0, atol=1e-6)
    np.testing.assert_allclose(sums[~has], 0.0, atol=1e-6)
