"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracle.

Plus the fused-kernel acceptance tests (gather+SPMM, SDDMM+softmax):
random-data sweeps at the standard tolerances AND strict <5e-7 f32
checks on mantissa-quantized inputs, where every reduction is exact in
any association order — so kernel-vs-oracle differences must be ZERO,
not merely small.  And the block autotuner round-trip."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gat_attention import gat_attention
from repro.kernels.gather_spmm import gather_spmm
from repro.kernels.sddmm import sddmm
from repro.kernels.spmm import spmm

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


def _quantized(rng, shape, step=2 ** -6, span=32):
    """f32 values on a coarse mantissa lattice (multiples of ``step``,
    small magnitude): short sums of them are EXACT in any association
    order, so fused vs oracle must agree bitwise."""
    return (rng.integers(-span, span, shape) * step).astype(np.float32)


@pytest.mark.parametrize("N,D,F,bn,bd", [
    (16, 128, 4, 8, 128),
    (32, 256, 8, 8, 128),
    (64, 128, 16, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_sweep(N, D, F, bn, bd, dtype, rng):
    h = jnp.asarray(rng.standard_normal((N, D)), dtype)
    w = jnp.asarray(rng.standard_normal((N, F)), dtype)
    nbr = jnp.asarray(rng.integers(0, N, (N, F)), jnp.int32)
    mask = jnp.asarray(rng.random((N, F)) > 0.25)
    got = spmm(h, w, nbr, mask, block_n=bn, block_d=bd)
    want = ref.spmm_ref(h, w, nbr, mask)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype] * F, rtol=3e-2)


@pytest.mark.parametrize("N,D,F", [(16, 64, 4), (32, 128, 8), (24, 96, 6)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sddmm_sweep(N, D, F, dtype, rng):
    q = jnp.asarray(rng.standard_normal((N, D)), dtype)
    k = jnp.asarray(rng.standard_normal((N, D)), dtype)
    nbr = jnp.asarray(rng.integers(0, N, (N, F)), jnp.int32)
    mask = jnp.asarray(rng.random((N, F)) > 0.25)
    got = sddmm(q, k, nbr, mask, block_n=8)
    want = ref.sddmm_ref(q, k, nbr, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=ATOL[dtype] * np.sqrt(D), rtol=3e-2)


@pytest.mark.parametrize("BH,S,hd,bq,bk", [
    (2, 128, 64, 64, 64),
    (4, 256, 64, 128, 128),
    (2, 128, 128, 32, 64),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(BH, S, hd, bq, bk, causal, dtype, rng):
    q = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=3e-2)


# ----------------------------------------------------------------------
# fused index-gather + SPMM
# ----------------------------------------------------------------------

@pytest.mark.parametrize("R,U,D,F,bn,bd", [
    (16, 16, 128, 4, 8, 128),       # square geometry
    (32, 48, 256, 8, 8, 128),       # subset: more table rows than outputs
    (64, 80, 96, 16, 16, 32),       # delta-shaped, non-pow2 D
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_spmm_sweep(R, U, D, F, bn, bd, dtype, rng):
    h = jnp.asarray(rng.standard_normal((U, D)), dtype)
    table = jnp.asarray(rng.permutation(U), jnp.int32)
    w = jnp.asarray(rng.standard_normal((R, F)), dtype)
    nbr = jnp.asarray(rng.integers(0, U, (R, F)), jnp.int32)
    mask = jnp.asarray(rng.random((R, F)) > 0.25)
    got = gather_spmm(h, table, w, nbr, mask, block_n=bn, block_d=bd)
    want = ref.gather_spmm_ref(h, table, w, nbr, mask)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype] * F, rtol=3e-2)


def test_gather_spmm_bitwise_vs_materialized(rng):
    """The fused indirection must equal the materialized reorder BITWISE:
    spmm over h[table] sees the same values in the same per-row order."""
    R, U, D, F = 32, 40, 128, 8
    h = jnp.asarray(rng.standard_normal((U, D)).astype(np.float32))
    table = jnp.asarray(rng.permutation(U), jnp.int32)
    w = jnp.asarray(rng.standard_normal((R, F)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, U, (R, F)), jnp.int32)
    mask = jnp.asarray(rng.random((R, F)) > 0.25)
    fused = gather_spmm(h, table, w, nbr, mask, block_n=8, block_d=128)
    materialized = spmm(jnp.take(h, table, axis=0), w, nbr, mask,
                        block_n=8, block_d=128)
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(materialized))


def test_gather_spmm_quantized_strict(rng):
    """Acceptance gate: f32 max err < 5e-7 vs the oracle.  On the
    quantized lattice the sums are exact, so this is really 0.0."""
    R, U, D, F = 64, 96, 128, 16
    h = jnp.asarray(_quantized(rng, (U, D)))
    table = jnp.asarray(rng.permutation(U), jnp.int32)
    w = jnp.asarray(_quantized(rng, (R, F)))
    nbr = jnp.asarray(rng.integers(0, U, (R, F)), jnp.int32)
    mask = jnp.asarray(rng.random((R, F)) > 0.25)
    got = np.asarray(gather_spmm(h, table, w, nbr, mask))
    want = np.asarray(ref.gather_spmm_ref(h, table, w, nbr, mask))
    assert np.abs(got - want).max() < 5e-7


# ----------------------------------------------------------------------
# fused SDDMM + masked softmax (GAT attention)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("N,U,D,F,heads", [
    (16, 16, 64, 4, 1),
    (32, 48, 64, 8, 4),             # subset geometry: U > N
    (64, 64, 128, 16, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gat_attention_sweep(N, U, D, F, heads, dtype, rng):
    q = jnp.asarray(rng.standard_normal((N, D)), dtype)
    k = jnp.asarray(rng.standard_normal((U, D)), dtype)
    nbr = jnp.asarray(rng.integers(0, U, (N, F)), jnp.int32)
    mask = jnp.asarray(rng.random((N, F)) > 0.25)
    got = gat_attention(q, k, nbr, mask, heads=heads)
    want = ref.gat_attention_ref(q, k, nbr, mask, heads)
    assert got.shape == (N, F, heads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=ATOL[dtype], rtol=3e-2)
    # masked slots are exactly zero and unmasked rows sum to 1 per head
    got_np = np.asarray(got)
    assert (got_np[~np.asarray(mask)] == 0.0).all()


def test_gat_attention_strict_f32(rng):
    """Acceptance gate: fused attention within 5e-7 of the oracle on
    random f32 data (softmax normalizes, so the dot rounding washes)."""
    N, U, D, F, heads = 64, 96, 128, 16, 4
    q = jnp.asarray(rng.standard_normal((N, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((U, D)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, U, (N, F)), jnp.int32)
    mask = jnp.asarray(rng.random((N, F)) > 0.25)
    got = np.asarray(gat_attention(q, k, nbr, mask, heads=heads))
    want = np.asarray(ref.gat_attention_ref(q, k, nbr, mask, heads))
    assert np.abs(got - want).max() < 5e-7


# ----------------------------------------------------------------------
# executor integration: fused paths on non-aligned shapes
# ----------------------------------------------------------------------

def _dense_io(rng, R, U, F, table=True):
    from repro.core.ops import DenseIO
    nbr = rng.integers(0, U, (R, F)).astype(np.int32)
    mask = rng.random((R, F)) > 0.25
    tbl = rng.permutation(U).astype(np.int32) if table else None
    return DenseIO(nbr, mask, table=tbl)


def test_executor_fused_gather_non_aligned_strict(rng):
    """PallasExecutor's fused-gather spmm on awkward shapes (R not a
    block multiple, D needing column padding) vs the ref executor over
    the SAME io — quantized inputs, so < 5e-7 means exact."""
    from repro.core.ops import PallasExecutor, RefExecutor
    R, U, D, F = 23, 37, 20, 6
    io = _dense_io(rng, R, U, F)
    h = jnp.asarray(_quantized(rng, (U, D)))
    got = np.asarray(PallasExecutor(use_kernel=True).spmm(h, io.mean_w, io))
    want = np.asarray(RefExecutor().spmm(h, io.mean_w, io))
    assert got.shape == (R, D)
    assert np.abs(got - want).max() < 5e-7


def test_executor_fused_gather_matches_unfused(rng):
    """fused_gather=False resolves the table eagerly; both routes must
    produce identical bits."""
    from repro.core.ops import PallasExecutor
    R, U, D, F = 50, 61, 32, 8
    io = _dense_io(rng, R, U, F)
    h = jnp.asarray(rng.standard_normal((U, D)).astype(np.float32))
    fused = PallasExecutor(use_kernel=True, fused_gather=True)
    unfused = PallasExecutor(use_kernel=True, fused_gather=False)
    np.testing.assert_array_equal(
        np.asarray(fused.spmm(h, io.mean_w, io)),
        np.asarray(unfused.spmm(h, io.mean_w, io)))


@pytest.mark.parametrize("N,D,heads", [(50, 32, 4), (64, 64, 1)])
def test_executor_fused_attention_layer(N, D, heads, rng):
    """A full GAT layer through ``run_layer``: the peephole must fire on
    the fused executor, agree tightly with the unfused kernel path, and
    match the jnp oracle within the standard tolerance."""
    import jax

    from repro.core.gnn_models import init_gat, model_spec
    from repro.core.ops import (DenseIO, PallasExecutor, RefExecutor,
                                run_layer)
    F = 6
    spec = model_spec("gat", init_gat(jax.random.PRNGKey(0), [D, D],
                                      heads=heads))
    io = _dense_io(rng, N, N, F, table=False)
    H = jnp.asarray(rng.standard_normal((N, D)).astype(np.float32))

    fused_ex = PallasExecutor(use_kernel=True, fused_attention=True)
    unfused_ex = PallasExecutor(use_kernel=True, fused_attention=False)
    assert fused_ex.attn_scores_softmax is not None
    assert unfused_ex.attn_scores_softmax is None

    layer = spec.layers[0]
    got = np.asarray(run_layer(fused_ex, layer, io, H, H, heads))
    unfused = np.asarray(run_layer(unfused_ex, layer, io, H, H, heads))
    want = np.asarray(run_layer(RefExecutor(), layer, io, H, H, heads))
    np.testing.assert_allclose(got, unfused, atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=3e-3)


# ----------------------------------------------------------------------
# block-size autotuner
# ----------------------------------------------------------------------

def test_autotune_roundtrip(tmp_path, monkeypatch):
    """ensure_tuned searches the candidate grid once (injected timer),
    persists the winner, serves later calls from the file, and re-runs
    only under REPRO_TUNING=autotune."""
    from repro import tuning
    monkeypatch.delenv("REPRO_TUNING", raising=False)
    path = tmp_path / "blocks.json"
    table = tuning.BlockTable(path=path)
    current, seen = {}, []

    def make_call(blocks):
        def fn():
            current.clear()
            current.update(blocks)
        return fn

    def timer(fn, repeats):
        fn()
        seen.append(dict(current))
        return abs(current["block_n"] - 32) + 1.0   # 32 always wins

    blocks = tuning.ensure_tuned(table, "sddmm", make_call, N=100,
                                 timer=timer)
    assert blocks == {"block_n": 32}
    assert path.exists() and seen     # searched and persisted
    # every block_n candidate that tiles the n128 bucket was tried
    assert sorted(c["block_n"] for c in seen) == [8, 16, 32, 64]

    # a fresh load serves the whole shape bucket without re-searching
    t2 = tuning.BlockTable.load(path)
    n_calls = len(seen)
    assert tuning.ensure_tuned(t2, "sddmm", make_call, N=100,
                               timer=timer) == {"block_n": 32}
    assert tuning.ensure_tuned(t2, "sddmm", make_call, N=128,
                               timer=timer) == {"block_n": 32}
    assert len(seen) == n_calls
    got = t2.lookup("sddmm", N=100)
    assert got == {"block_n": 32}     # the `us` field stays out of lookup

    # forcing invalidates the persisted winner
    monkeypatch.setenv("REPRO_TUNING", "autotune")
    assert tuning.autotune_forced()
    tuning.ensure_tuned(t2, "sddmm", make_call, N=100, timer=timer)
    assert len(seen) > n_calls


def test_executor_consults_block_table(rng):
    """A bound BlockTable overrides the constructor blocks at bind time,
    and tuned vs default blocks are bitwise-identical (block sizes never
    change the per-row accumulation order)."""
    from repro import tuning
    from repro.core.ops import PallasExecutor
    N, U, D, F = 64, 64, 128, 8
    tb = tuning.BlockTable()
    tb.put("gather_spmm", N=N, D=D, blocks={"block_n": 16, "block_d": 128})
    ex = PallasExecutor(use_kernel=True, block_table=tb)
    assert ex._pick_blocks("gather_spmm", N, D, jnp.float32) == (16, 128)
    assert ex._pick_blocks("spmm", N, D, jnp.float32) == (None, 128)

    io = _dense_io(rng, N, U, F)
    h = jnp.asarray(rng.standard_normal((U, D)).astype(np.float32))
    got = np.asarray(ex.spmm(h, io.mean_w, io))
    base = np.asarray(PallasExecutor(use_kernel=True).spmm(h, io.mean_w,
                                                           io))
    np.testing.assert_array_equal(got, base)


def test_auto_block_n_defaults():
    """The satellite fix: sddmm no longer hard-defaults to block_n=8 —
    both kernels take the largest divisor <= 64 of the row count."""
    from repro.kernels.spmm import auto_block_n
    assert auto_block_n(256) == 64
    assert auto_block_n(24) == 8
    assert auto_block_n(20) == 4
    assert auto_block_n(7) == 1


def test_flash_matches_model_attention(rng):
    """The Pallas kernel and the model's jnp flash agree."""
    from repro.models.attention import flash_attention_jnp
    BH, S, hd = 2, 128, 64
    q = jnp.asarray(rng.standard_normal((BH, S, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((BH, S, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((BH, S, hd)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True)
    want = flash_attention_jnp(q[:, :, None], k[:, :, None], v[:, :, None],
                               causal=True, q_block=64, kv_block=64)[:, :, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
