"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.sddmm import sddmm
from repro.kernels.spmm import spmm

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


@pytest.mark.parametrize("N,D,F,bn,bd", [
    (16, 128, 4, 8, 128),
    (32, 256, 8, 8, 128),
    (64, 128, 16, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_sweep(N, D, F, bn, bd, dtype, rng):
    h = jnp.asarray(rng.standard_normal((N, D)), dtype)
    w = jnp.asarray(rng.standard_normal((N, F)), dtype)
    nbr = jnp.asarray(rng.integers(0, N, (N, F)), jnp.int32)
    mask = jnp.asarray(rng.random((N, F)) > 0.25)
    got = spmm(h, w, nbr, mask, block_n=bn, block_d=bd)
    want = ref.spmm_ref(h, w, nbr, mask)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype] * F, rtol=3e-2)


@pytest.mark.parametrize("N,D,F", [(16, 64, 4), (32, 128, 8), (24, 96, 6)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sddmm_sweep(N, D, F, dtype, rng):
    q = jnp.asarray(rng.standard_normal((N, D)), dtype)
    k = jnp.asarray(rng.standard_normal((N, D)), dtype)
    nbr = jnp.asarray(rng.integers(0, N, (N, F)), jnp.int32)
    mask = jnp.asarray(rng.random((N, F)) > 0.25)
    got = sddmm(q, k, nbr, mask, block_n=8)
    want = ref.sddmm_ref(q, k, nbr, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=ATOL[dtype] * np.sqrt(D), rtol=3e-2)


@pytest.mark.parametrize("BH,S,hd,bq,bk", [
    (2, 128, 64, 64, 64),
    (4, 256, 64, 128, 128),
    (2, 128, 128, 32, 64),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(BH, S, hd, bq, bk, causal, dtype, rng):
    q = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=3e-2)


def test_flash_matches_model_attention(rng):
    """The Pallas kernel and the model's jnp flash agree."""
    from repro.models.attention import flash_attention_jnp
    BH, S, hd = 2, 128, 64
    q = jnp.asarray(rng.standard_normal((BH, S, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((BH, S, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((BH, S, hd)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True)
    want = flash_attention_jnp(q[:, :, None], k[:, :, None], v[:, :, None],
                               causal=True, q_block=64, kv_block=64)[:, :, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
