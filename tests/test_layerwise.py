"""Layer-wise engine vs ego-batched baseline: identical embeddings, and the
baseline provably does redundant work (the waste DEAL removes)."""
import jax
import numpy as np
import pytest

from repro.core.gnn_models import init_gat, init_gcn, init_sage
from repro.core.layerwise import (LOCAL_ENGINES, ego_batched_gcn_infer,
                                  local_gcn_infer)


@pytest.fixture(scope="module")
def feats(layer_graphs):
    rng = np.random.default_rng(1)
    N = layer_graphs[0].n_nodes
    return rng.standard_normal((N, 32), dtype=np.float32)


def test_ego_batched_matches_layerwise(layer_graphs, feats):
    params = init_gcn(jax.random.PRNGKey(0), [32, 32, 16])
    lgs = layer_graphs[:2]
    want = np.asarray(local_gcn_infer(lgs, feats, params))
    got, work = ego_batched_gcn_infer(lgs, feats, params, batch_size=64)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)


def test_ego_batched_redundancy(layer_graphs, feats):
    """Smaller batches -> strictly more GEMM rows than DEAL's k*N."""
    params = init_gcn(jax.random.PRNGKey(0), [32, 32, 16])
    lgs = layer_graphs[:2]
    N = lgs[0].n_nodes
    _, work_small = ego_batched_gcn_infer(lgs, feats, params, batch_size=16)
    _, work_big = ego_batched_gcn_infer(lgs, feats, params, batch_size=N)
    deal_work = 2 * N
    assert work_small > work_big >= deal_work


@pytest.mark.parametrize("model", ["gcn", "gat", "sage"])
def test_local_engines_finite(model, layer_graphs, feats):
    key = jax.random.PRNGKey(0)
    dims = [32, 32, 16]
    params = {"gcn": init_gcn(key, dims),
              "gat": init_gat(key, dims, heads=4),
              "sage": init_sage(key, dims)}[model]
    H = LOCAL_ENGINES[model](layer_graphs[:2], feats, params)
    assert H.shape == (layer_graphs[0].n_nodes, 16)
    assert np.isfinite(np.asarray(H)).all()


def test_sharing_analytics(layer_graphs):
    from repro.core.sharing import sharing_table, sharing_vs_batch_size
    t = sharing_table(layer_graphs, batch_size=32)
    assert t["deal"] == 1.0
    assert 0.0 <= t["p3"] <= t["dgi_batched"] <= 1.0
    curve = sharing_vs_batch_size(layer_graphs,
                                  fractions=(0.05, 0.25, 1.0))
    vals = list(curve.values())
    assert vals == sorted(vals), "sharing must grow with batch size"
    assert vals[-1] > 0.99   # single batch == full sharing


def test_feature_prep_equivalence(tmp_path):
    from repro.core.feature_prep import (fused_load, redistribute_load,
                                         scan_all_load, write_feature_files)
    N, D, M = 256, 16, 4
    files, feats = write_feature_files(str(tmp_path), N, D, n_files=8)
    w = np.random.default_rng(0).standard_normal((D, 8)).astype(np.float32)
    x1, s1 = scan_all_load(files, M, N, D)
    x2, s2 = redistribute_load(files, M, N, D)
    np.testing.assert_array_equal(x1, feats)
    np.testing.assert_array_equal(x2, feats)
    h1, s3 = fused_load(files, M, N, D, w)
    np.testing.assert_allclose(h1, feats @ w, atol=1e-5)
    assert s1["file_rows"] == M * N        # scans everything M times
    assert s2["file_rows"] == N            # reads once
    assert s3["net_rows"] == 0             # no shuffle pass at all
