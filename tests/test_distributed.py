"""Distributed primitive + engine correctness on an 8-device host mesh.

Runs tests/helpers/dist_check.py in a subprocess (the main process must
keep 1 device; XLA locks the count at first init)."""
import pathlib
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "dist_check.py"
TUNED = pathlib.Path(__file__).parent / "helpers" / "tuned_check.py"


def _run_check(script: pathlib.Path) -> subprocess.CompletedProcess:
    """One retry on TIMEOUT only: 8 forced host devices on a small box
    can wedge their collectives (threads asleep, ~0 CPU) — an
    environmental deadlock, observed rarely and never reproducible
    standalone.  A real check failure exits nonzero fast and is NOT
    retried."""
    for attempt in (0, 1):
        try:
            return subprocess.run([sys.executable, str(script)],
                                  capture_output=True, text=True,
                                  timeout=1200)
        except subprocess.TimeoutExpired:
            if attempt:
                raise
            print(f"# {script.name} wedged (collective deadlock on "
                  "oversubscribed fake devices); retrying once")


@pytest.mark.slow
def test_distributed_primitives_and_engines():
    res = _run_check(HELPER)
    print(res.stdout)
    print(res.stderr[-2000:] if res.returncode else "")
    assert res.returncode == 0, res.stdout + res.stderr[-2000:]
    assert "ALL DISTRIBUTED CHECKS PASSED" in res.stdout


@pytest.mark.slow
def test_tuned_variants_match_baseline():
    """§Perf hillclimbs (moe_ep, cp_decode) are numerics-preserving."""
    res = _run_check(TUNED)
    print(res.stdout)
    assert res.returncode == 0, res.stdout + res.stderr[-2000:]
    assert "ALL TUNED CHECKS PASSED" in res.stdout
