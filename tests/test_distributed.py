"""Distributed primitive + engine correctness on an 8-device host mesh.

Runs tests/helpers/dist_check.py in a subprocess (the main process must
keep 1 device; XLA locks the count at first init)."""
import os
import pathlib
import subprocess
import sys
import time

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "dist_check.py"
TUNED = pathlib.Path(__file__).parent / "helpers" / "tuned_check.py"

# a wedged collective stops the helper's main-thread heartbeat; no
# single check (compiles included) legitimately goes this long silent
STALE_S = 300.0
TOTAL_S = 1800.0
POLL_S = 5.0


def _read_heartbeat(path: pathlib.Path):
    """(mtime, stage-label) of the helper's last main-thread beat."""
    try:
        return os.path.getmtime(path), path.read_text().split(" ", 1)[-1].strip()
    except OSError:
        return None, "<no heartbeat yet>"


def _run_once(script: pathlib.Path, hb: pathlib.Path):
    """Run the helper, polling its heartbeat.  Returns
    ``(CompletedProcess | None, wedged_stage | None)`` — a wedge (stale
    heartbeat or total-budget blowout) kills the process and reports the
    stage it died in."""
    proc = subprocess.Popen([sys.executable, str(script),
                             "--heartbeat", str(hb)],
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    start = time.monotonic()
    while True:
        try:
            out, err = proc.communicate(timeout=POLL_S)
            return subprocess.CompletedProcess(proc.args, proc.returncode,
                                               out, err), None
        except subprocess.TimeoutExpired:
            pass
        mtime, stage = _read_heartbeat(hb)
        silent = (time.time() - mtime if mtime is not None
                  else time.monotonic() - start)
        if silent > STALE_S or time.monotonic() - start > TOTAL_S:
            proc.kill()
            out, err = proc.communicate()
            print(f"# {script.name} heartbeat silent {silent:.0f}s "
                  f"(last stage: {stage}); killed")
            print(out[-2000:])
            return None, stage


def _run_check(script: pathlib.Path, tmp_path) -> subprocess.CompletedProcess:
    """One retry on a WEDGE only: 8 forced host devices on a small box
    can deadlock their collectives (threads asleep, ~0 CPU) — an
    environmental hang, observed rarely and never reproducible
    standalone.  The helper heartbeats from its main thread per check,
    so a wedge is detected within ``STALE_S`` and diagnosed with the
    stage it stopped in.  A real check failure exits nonzero fast and
    is NOT retried."""
    for attempt in (0, 1):
        hb = tmp_path / f"{script.stem}.heartbeat.{attempt}"
        res, stage = _run_once(script, hb)
        if res is not None:
            if attempt:
                print(f"# {script.name}: retry succeeded after a wedge")
            return res
        if attempt:
            pytest.fail(f"{script.name} wedged twice (stage: {stage})")
        print(f"# {script.name} wedged at stage {stage!r} (collective "
              "deadlock on oversubscribed fake devices); retrying once")


@pytest.mark.slow
def test_distributed_primitives_and_engines(tmp_path):
    res = _run_check(HELPER, tmp_path)
    print(res.stdout)
    print(res.stderr[-2000:] if res.returncode else "")
    assert res.returncode == 0, res.stdout + res.stderr[-2000:]
    assert "ALL DISTRIBUTED CHECKS PASSED" in res.stdout


@pytest.mark.slow
def test_tuned_variants_match_baseline(tmp_path):
    """§Perf hillclimbs (moe_ep, cp_decode) are numerics-preserving."""
    res = _run_check(TUNED, tmp_path)
    print(res.stdout)
    assert res.returncode == 0, res.stdout + res.stderr[-2000:]
    assert "ALL TUNED CHECKS PASSED" in res.stdout
