"""Distributed primitive + engine correctness on an 8-device host mesh.

Runs tests/helpers/dist_check.py in a subprocess (the main process must
keep 1 device; XLA locks the count at first init)."""
import pathlib
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "dist_check.py"
TUNED = pathlib.Path(__file__).parent / "helpers" / "tuned_check.py"


@pytest.mark.slow
def test_distributed_primitives_and_engines():
    res = subprocess.run([sys.executable, str(HELPER)],
                         capture_output=True, text=True, timeout=1200)
    print(res.stdout)
    print(res.stderr[-2000:] if res.returncode else "")
    assert res.returncode == 0, res.stdout + res.stderr[-2000:]
    assert "ALL DISTRIBUTED CHECKS PASSED" in res.stdout


@pytest.mark.slow
def test_tuned_variants_match_baseline():
    """§Perf hillclimbs (moe_ep, cp_decode) are numerics-preserving."""
    res = subprocess.run([sys.executable, str(TUNED)],
                         capture_output=True, text=True, timeout=1200)
    print(res.stdout)
    assert res.returncode == 0, res.stdout + res.stderr[-2000:]
    assert "ALL TUNED CHECKS PASSED" in res.stdout
