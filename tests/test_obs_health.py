"""Serving-tier health observability: per-query critical-path
attribution (segments must reconcile with measured e2e wall time), the
SLO burn-rate monitor's detectors and hysteresis, the live telemetry
endpoint, the report CLI + trajectory gate, the Session stats-leaf
naming guard, and the bitwise proof that the instrumented SERVING path
equals the uninstrumented one (ref + pallas)."""
import json
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.api import (DealConfig, ExecutorSpec, GraphSpec, ModelSpec,
                       QoSSpec, Session, TelemetrySpec,
                       tenants_from_string)
from repro.gnnserve import Query
from repro.obs import compat, report
from repro.obs.health import SEGMENTS, AttributionCollector, HealthMonitor
from repro.obs.validate import validate_trace

TOL = report.ATTRIBUTION_TOLERANCE


def _cfg(*, executor="ref", telemetry=True, tenants="", n=256,
         bound=8, **tel_kw):
    return DealConfig(
        graph=GraphSpec(dataset="rmat", n_nodes=n, avg_degree=4,
                        fanout=4, seed=0),
        model=ModelSpec(name="gcn", n_layers=2, d_feature=16),
        executor=ExecutorSpec(name=executor),
        qos=QoSSpec(staleness_bound=bound, batch_slots=4,
                    rows_per_step=64,
                    tenants=(tenants_from_string(tenants)
                             if tenants else ())),
        telemetry=TelemetrySpec(enabled=telemetry, **tel_kw))


def _drive(eng, *, ticks=20, n=256, tenants=("ui", "batch"), seed=0):
    """Deterministic mixed traffic; returns the completed queries."""
    rng = np.random.default_rng(seed)
    qs = []
    uid = 0
    for _ in range(ticks):
        for name in tenants:
            rows = 8 if name == "ui" else 32
            q = Query(uid=uid, node_ids=rng.integers(0, n, rows),
                      tenant=name)
            uid += 1
            eng.submit(q)
            qs.append(q)
        eng.mutate().add_edges(rng.integers(0, n, 2),
                               rng.integers(0, n, 2))
        eng.run()
    return qs


# ----------------------------------------------------------------------
# AttributionCollector
# ----------------------------------------------------------------------

def test_attribution_collector_aggregates_and_ranks():
    c = AttributionCollector(top_k=2)
    for i, e2e in enumerate([10_000, 30_000, 20_000]):
        c.record(uid=i, tenant="ui", e2e_ns=e2e,
                 segments_ns={"queue_wait": e2e // 2, "pin": e2e // 2})
    c.record(uid=9, tenant="batch", e2e_ns=5_000,
             segments_ns={"gather": 4_000})
    assert c.n_queries == 4
    s = c.summary()
    assert s["ui"]["n_queries"] == 3
    assert s["ui"]["e2e_ms"]["sum"] == pytest.approx(0.06)
    assert s["ui"]["e2e_ms"]["max"] == pytest.approx(0.03)
    assert s["ui"]["attributed_frac"] == pytest.approx(1.0)
    # unmeasured time shows up as an attribution gap, not a crash
    assert s["batch"]["attributed_frac"] == pytest.approx(0.8)
    assert s["batch"]["segments_frac"]["gather"] == pytest.approx(0.8)
    top = c.top_paths()
    assert [r["uid"] for r in top] == [1, 2]        # slowest first, k=2
    assert set(top[0]["segments_ms"]) == set(SEGMENTS)


# ----------------------------------------------------------------------
# HealthMonitor detectors
# ----------------------------------------------------------------------

def test_slo_burn_fires_once_with_hysteresis():
    m = HealthMonitor({"ui": 4}, window=10, error_budget=0.1,
                      burn_threshold=2.0)
    for _ in range(3):
        m.on_staleness("ui", 10)        # violating: burn -> 10
    assert [a["kind"] for a in m.alerts] == ["slo_burn"]
    assert m.alerts[0]["subject"] == "ui"
    assert m.burn_rate["ui"] >= 2.0
    for _ in range(3):                   # still above threshold/2: armed
        m.on_staleness("ui", 10)
    assert len(m.alerts) == 1            # edge-triggered, not per-step
    for _ in range(40):                  # healthy reads re-arm it
        m.on_staleness("ui", 0)
    assert m.burn_rate["ui"] < 1.0
    m.on_staleness("ui", 10)
    for _ in range(5):
        m.on_staleness("ui", 10)
    assert [a["kind"] for a in m.alerts] == ["slo_burn", "slo_burn"]


def test_wait_burn_disabled_by_default_and_fires_when_set():
    off = HealthMonitor({"ui": 4}, window=4)
    off.on_wait("ui", 1e9)
    assert off.alerts == [] and off.wait_burn_rate == {}
    on = HealthMonitor({"ui": 4}, window=4, error_budget=0.5,
                       burn_threshold=2.0, wait_slo_ms=1.0)
    for _ in range(4):
        on.on_wait("ui", 50.0)
    assert [a["kind"] for a in on.alerts] == ["wait_burn"]


def test_evict_thrash_and_counter_reset_tolerance():
    m = HealthMonitor({"d": 8}, window=8, thrash_evictions=10)
    ev = 0
    for _ in range(5):                    # first step primes the baseline
        ev += 3
        m.on_step(pending=0, evictions=ev)
    assert [a["kind"] for a in m.alerts] == ["evict_thrash"]
    # a full_epoch store swap resets cumulative counters: the monitor
    # must clamp the negative delta, not fire or crash
    m2 = HealthMonitor({"d": 8}, window=8, thrash_evictions=10)
    m2.on_step(pending=0, evictions=100)
    m2.on_step(pending=0, evictions=0)          # swapped store
    m2.on_step(pending=0, evictions=2)
    assert m2.alerts == []


def test_refresh_backlog_needs_growth_and_magnitude():
    m = HealthMonitor({"d": 2}, window=4, backlog_factor=2.0)
    for p in (1, 2, 3, 4):                      # grows but under cap=4...
        m.on_step(pending=p, evictions=0)
    m.on_step(pending=9, evictions=0)           # ...now over, and grew
    assert [a["kind"] for a in m.alerts] == ["refresh_backlog"]
    flat = HealthMonitor({"d": 2}, window=4, backlog_factor=2.0)
    for _ in range(8):
        flat.on_step(pending=9, evictions=0)    # high but not growing
    assert flat.alerts == []


def test_route_flap_detector():
    m = HealthMonitor({"d": 8}, window=32, flap_threshold=4)
    loc = dist = 0
    for i in range(10):                          # alternate every step
        if i % 2:
            loc += 1
        else:
            dist += 1
        m.on_step(pending=0, evictions=0, route_local=loc,
                  route_dist=dist)
    assert [a["kind"] for a in m.alerts] == ["route_flap"]
    steady = HealthMonitor({"d": 8}, window=32, flap_threshold=4)
    for i in range(10):                          # always local: no flips
        steady.on_step(pending=0, evictions=0, route_local=i + 1,
                       route_dist=0)
    assert steady.alerts == []


def test_alert_lands_in_counters_and_trace():
    tel = obs.Telemetry(enabled=True, clock=obs.FakeClock(0, 1000))
    with obs.use(tel):
        m = HealthMonitor({"ui": 1}, window=4, error_budget=0.5,
                          burn_threshold=1.5)
        for _ in range(4):
            m.on_staleness("ui", 5)
    assert tel.metrics.to_dict()["health.alerts"] == 1
    assert tel.metrics.to_dict()["health.alerts.slo_burn"] == 1
    ev = [e for e in tel.tracer.events if e[0] == "health.alert"]
    assert len(ev) == 1 and ev[0][4]["kind"] == "slo_burn"
    assert tel.metrics.to_dict()["health.burn_rate.ui"] >= 1.5


# ----------------------------------------------------------------------
# end-to-end attribution through the serving engine
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_session():
    s = Session.build(_cfg(tenants="ui:4:2:0:4,batch:1:1:0:64"))
    qs = _drive(s.serve(), ticks=20)
    yield s, qs
    s.close()


def test_attribution_closes_within_tolerance(served_session):
    s, qs = served_session
    assert all(q.done for q in qs)
    attrib = s.stats()["attribution"]
    assert set(attrib) == {"ui", "batch"}
    for tenant, a in attrib.items():
        assert a["n_queries"] == 20
        assert abs(a["attributed_frac"] - 1.0) <= TOL, \
            f"{tenant} closes at {a['attributed_frac']:.3f}"
        assert set(a["segments_frac"]) == set(SEGMENTS)


def test_per_query_events_ride_their_own_track(served_session):
    s, qs = served_session
    doc = obs.chrome_trace(s.telemetry.tracer)
    qevents = [e for e in doc["traceEvents"]
               if e.get("name") == "serve.query"]
    assert len(qevents) == len(qs)
    tids = {e["tid"] for e in qevents}
    assert tids == {1}                   # own Perfetto track
    assert any(e.get("ph") == "M" and e.get("name") == "thread_name"
               and e["args"]["name"] == "queries"
               for e in doc["traceEvents"])
    args = qevents[0]["args"]
    assert {"uid", "tenant"} <= set(args)
    assert all(f"{seg}_ms" in args for seg in SEGMENTS)
    # the non-query spans are untouched by the track assignment
    assert all(e["tid"] == 0 for e in doc["traceEvents"]
               if e.get("ph") == "X" and e["name"] != "serve.query")
    # scheduler grants are in the timeline too, uid-attributed
    grants = [e for e in doc["traceEvents"]
              if e.get("name") == "qos.grant"]
    assert len(grants) >= len(qs)            # re-grants after preemption
    assert {"uid", "tenant", "slot"} <= set(grants[0]["args"])


def test_dump_trace_embeds_attribution_and_report_checks(
        served_session, tmp_path):
    s, _ = served_session
    doc = s.dump_trace(tmp_path / "t.json")
    assert set(doc["deal_attribution"]) == {"ui", "batch"}
    assert doc["deal_top_queries"][0]["e2e_ms"] >= \
        doc["deal_top_queries"][-1]["e2e_ms"]
    assert "deal_health" in doc
    text = report.render_report(doc)
    assert "critical paths" in text and "per-tenant attribution" in text
    assert report.check_trace(doc) == []
    assert report.main([str(tmp_path / "t.json"), "--check"]) == 0


def test_attribution_absent_without_telemetry():
    # shield against the module fixture's still-installed telemetry:
    # this session must really serve with obs disabled
    with obs.use(obs.DISABLED):
        with Session.build(_cfg(telemetry=False)) as s:
            _drive(s.serve(), ticks=3, tenants=("default",))
            st = s.stats()
            assert "attribution" not in st and "health" not in st
            assert s.engine.attrib is None and s.engine.health is None


def test_fifo_engine_attributes_too():
    with Session.build(_cfg(bound=64)) as s:
        _drive(s.serve(), ticks=6, tenants=("default",))
        a = s.stats()["attribution"]["default"]
        assert a["n_queries"] == 6
        assert abs(a["attributed_frac"] - 1.0) <= TOL


# ----------------------------------------------------------------------
# synthetic SLO violation: alert on the endpoint AND in the report
# ----------------------------------------------------------------------

def test_slo_violation_surfaces_on_every_pane(tmp_path):
    cfg = _cfg(bound=64, http_port=0,
               snapshot_path=str(tmp_path / "snap.json"),
               snapshot_every_s=30.0,       # exercised by stop()'s final write
               health_window=8, slo_error_budget=0.5, burn_threshold=1.5,
               wait_slo_ms=1e-6)            # every wait violates
    s = Session.build(cfg)
    try:
        _drive(s.serve(), ticks=8, tenants=("default",))
        st = s.stats()
        kinds = {a["kind"] for a in st["health"]["alerts"]}
        assert "wait_burn" in kinds
        # pane 1: the Prometheus endpoint
        base = f"http://127.0.0.1:{s.endpoint.port}"
        text = urllib.request.urlopen(base + "/metrics").read().decode()
        assert "deal_health_alerts_wait_burn 1" in text
        assert s.prometheus_text() == text
        hz = json.load(urllib.request.urlopen(base + "/healthz"))
        assert hz["status"] == "alerting"
        stats_doc = json.load(urllib.request.urlopen(base + "/stats"))
        assert stats_doc["health"]["n_alerts"] >= 1
        assert urllib.request.urlopen(base + "/stats").status == 200
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope")
        # pane 2: the report CLI over the dumped trace
        doc = s.dump_trace(tmp_path / "t.json")
        text = report.render_report(doc)
        assert "ALERT wait_burn" in text
    finally:
        s.close()
    # the endpoint is down and the final snapshot is on disk
    assert s.endpoint is None
    snap = json.loads((tmp_path / "snap.json").read_text())
    assert snap["health"]["status"] == "alerting"
    assert snap["stats"]["health"]["n_alerts"] >= 1


# ----------------------------------------------------------------------
# bitwise neutrality of the instrumented SERVING path
# ----------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["ref", "pallas"])
def test_instrumented_serving_is_bitwise_neutral(executor):
    outs = {}
    for telemetry in (False, True):
        with Session.build(_cfg(executor=executor,
                                telemetry=telemetry,
                                tenants="ui:4:2:0:4,batch:1:1:0:64")) as s:
            qs = _drive(s.serve(), ticks=6)
            assert all(q.done for q in qs)
            outs[telemetry] = [(q.served_version, q.out.copy())
                               for q in qs]
    for (v_off, o_off), (v_on, o_on) in zip(outs[False], outs[True]):
        assert v_off == v_on
        assert o_off.dtype == o_on.dtype
        assert np.array_equal(o_off, o_on)      # bitwise, not approx


# ----------------------------------------------------------------------
# ring-buffer overflow under a long serve loop
# ----------------------------------------------------------------------

def test_ring_overflow_keeps_nesting_and_exports(tmp_path):
    with Session.build(_cfg(bound=64, capacity=64)) as s:
        _drive(s.serve(), ticks=30, tenants=("default",))
        tr = s.telemetry.tracer
        assert tr.n_dropped > 0             # the buffer really wrapped
        assert tr.depth == 0                # no corrupted open-span state
        assert len(tr.events) == 64
        # spans record at EXIT: completion times stay monotone through
        # the wrap (oldest dropped, insertion order intact)
        ends = [e[1] + e[2] for e in tr.events_in_order()]
        assert ends == sorted(ends)
        doc = s.dump_trace(tmp_path / "t.json")
        assert doc["deal_dropped_spans"] == tr.n_dropped
    problems, summary = validate_trace(doc, min_coverage=0.0)
    assert problems == []
    assert summary["n_spans"] == 64
    # the report renders the truncated buffer and flags the drop
    assert "dropped (ring buffer wrapped)" in report.render_report(doc)


def test_truncated_export_under_fake_clock(tmp_path):
    tel = obs.Telemetry(enabled=True, clock=obs.FakeClock(0, 1000),
                        capacity=2)
    with tel.span("outer"):
        for i in range(4):
            with tel.span(f"inner{i}"):
                pass
    doc = obs.dump_chrome_trace(tel.tracer, tmp_path / "t.json")
    names = [e["name"] for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert names == ["inner3", "outer"]     # oldest spans gone
    assert doc["deal_dropped_spans"] == 3
    assert validate_trace(doc, min_coverage=0.0)[0] == []


# ----------------------------------------------------------------------
# validate: exact span-name inventory (the chunked-refresh CI gate)
# ----------------------------------------------------------------------

def test_validate_require_spans():
    tel = obs.Telemetry(enabled=True, clock=obs.FakeClock(0, 1000))
    with tel.span("refresh.chunk"):
        pass
    doc = obs.chrome_trace(tel.tracer)
    assert validate_trace(doc, min_coverage=0.0,
                          require_spans=("refresh.chunk",))[0] == []
    problems, _ = validate_trace(
        doc, min_coverage=0.0,
        require_spans=("refresh.layer", "refresh.route"))
    assert len(problems) == 2
    assert "refresh.layer" in problems[0]
    assert "refresh.chunk" in problems[0]   # nearest-by-prefix hint


# ----------------------------------------------------------------------
# stats-leaf naming guard + cutover translation
# ----------------------------------------------------------------------

def test_every_session_stats_leaf_resolves(served_session):
    s, _ = served_session
    st = s.stats()
    s.refresh()                              # populate refresh counters
    st = s.stats()
    uni, unmapped = compat.unified_from_session(st)
    assert unmapped == [], \
        f"stats keys without a unified metric name: {unmapped}"
    assert uni["serve.queries"] == st["n_served"]
    assert uni["serve.refresh_chunks"] == st["n_refresh_chunks"]
    assert uni["refresh.route_local"] == st["refresh_cutover"]["n_local"]
    assert uni["refresh.route_tail_rows"] == \
        st["refresh_cutover"]["n_tail"]
    # and the unified metrics view carries the cutover counters too
    assert st["metrics"]["refresh.route_local"] == \
        st["refresh_cutover"]["n_local"]


def test_unified_from_refresh_covers_chunking_keys():
    uni = compat.unified_from_refresh(
        {"n_chunks": 3, "n_tail_routed": 2, "local_cutover": True,
         "n_onboarded": 1, "rows_gemm": 10})
    assert uni == {"delta.chunks": 3, "delta.tail_routed": 2,
                   "delta.local_cutover": 1, "delta.onboarded": 1,
                   "delta.rows_gemm": 10}


def test_unified_from_session_flags_drift(served_session):
    s, _ = served_session
    st = dict(s.stats())
    st["n_fancy_new_counter"] = 7
    st["tenants"] = {"ui": {"made_up_key": 1}}
    _, unmapped = compat.unified_from_session(st)
    assert "n_fancy_new_counter" in unmapped
    assert "tenants.ui.made_up_key" in unmapped


# ----------------------------------------------------------------------
# trajectory: append + the share-drift gate
# ----------------------------------------------------------------------

def _traj_entry(share_store, *, fail=False):
    return {"ts": "2026-08-08T00:00:00", "git": "abc1234",
            "smoke": True, "executor": "ref",
            "failures": ["qos"] if fail else [],
            "benches": {"qos": {"stages": {
                "store.gather": {"count": 5,
                                 "total_ms": 100.0 * share_store},
                "refresh.layer": {"count": 5,
                                  "total_ms": 100.0
                                  * (1 - share_store)}},
                "coverage": 0.95, "n_spans": 10}}}


def test_trajectory_append_caps_and_gate_passes_on_itself(tmp_path):
    path = tmp_path / "TRAJECTORY.json"
    for _ in range(3):
        entries = report.append_trajectory(path, _traj_entry(0.3))
    assert len(entries) == 3
    problems, summary = report.check_trajectory(entries)
    assert problems == [] and summary["verdict"] == "ok"
    assert summary["compared"] == 2          # identical entries: pass
    assert report.main(["--trajectory", str(path)]) == 0
    # the file is capped PER (executor, smoke) key ...
    for _ in range(report.TRAJECTORY_MAX_PER_KEY + 5):
        entries = report.append_trajectory(path, _traj_entry(0.3))
    assert len(entries) == report.TRAJECTORY_MAX_PER_KEY
    # ... so a second key keeps its own independent history
    other = dict(_traj_entry(0.3))
    other["executor"] = "pallas"
    entries = report.append_trajectory(path, other)
    assert len(entries) == report.TRAJECTORY_MAX_PER_KEY + 1
    assert sum(e["executor"] == "pallas" for e in entries) == 1


def test_trajectory_gate_catches_share_drift_and_failures(tmp_path):
    entries = [_traj_entry(0.3) for _ in range(4)]
    drifted = entries + [_traj_entry(0.9)]    # +0.6 share > 0.3 tolerance
    problems, summary = report.check_trajectory(drifted)
    assert summary["verdict"] == "fail"
    assert any("store.gather" in p for p in problems)
    path = tmp_path / "TRAJECTORY.json"
    for e in drifted:
        report.append_trajectory(path, e)
    assert report.main(["--trajectory", str(path)]) == 1
    # a failed bench in the latest entry always regresses
    problems, _ = report.check_trajectory(entries
                                          + [_traj_entry(0.3, fail=True)])
    assert any("failed" in p for p in problems)
    # a fresh seed (no comparable baseline) passes
    assert report.check_trajectory([_traj_entry(0.3)])[0] == []
    # baselines never mix (executor, smoke) keys
    other = dict(_traj_entry(0.9)); other["executor"] = "pallas"
    problems, summary = report.check_trajectory(entries + [other])
    assert summary["n_baseline"] == 0 and problems == []


def test_report_check_rejects_broken_traces():
    assert report.check_trace({"traceEvents": []}) != []
    bad_attrib = {
        "traceEvents": [{"name": "a", "ph": "X", "ts": 0, "dur": 1,
                         "pid": 0, "tid": 0}],
        "deal_attribution": {"ui": {
            "n_queries": 1,
            "e2e_ms": {"sum": 1, "mean": 1, "p50": 1, "p95": 1, "max": 1},
            "segments_ms": {s: 0 for s in SEGMENTS},
            "segments_frac": {s: 0 for s in SEGMENTS},
            "attributed_frac": 0.5}}}       # closes at 50%: outside 5%
    assert any("closes at 0.500" in p
               for p in report.check_trace(bad_attrib))
    orphan = {"traceEvents": [
        {"name": "serve.query", "ph": "X", "ts": 0, "dur": 1,
         "pid": 0, "tid": 0, "args": {"tenant": "ui", "uid": 0}}]}
    assert any("deal_attribution" in p for p in report.check_trace(orphan))
