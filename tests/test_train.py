"""Training loop: loss decreases; checkpoint roundtrip."""
import numpy as np
import pytest

from repro.launch.train import run as train_run
from repro.train.checkpoint import restore_checkpoint, save_checkpoint


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    params, losses = train_run("smollm-360m", steps=60, batch=4, seq=64,
                               reduced=True, lr=3e-3, log_every=20)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.15, (first, last)


def test_checkpoint_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.train.optimizer import AdamWConfig, init_opt_state

    cfg = get_config("smollm-360m").reduced()
    p = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(p, AdamWConfig())
    path = tmp_path / "ckpt.npz"
    save_checkpoint(path, p, opt, step=7, metadata={"arch": cfg.arch_id})
    p2, opt2, step = restore_checkpoint(path, p, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(opt2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_data_pipeline_structured():
    from repro.train.data import DataConfig, synthetic_batches
    it = synthetic_batches(DataConfig(vocab_size=64, seq_len=32,
                                      batch_size=4, seed=0))
    b = next(it)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # the Markov structure must be predictable: successor entropy < uniform
    b2 = next(it)
    assert not np.array_equal(b["tokens"], b2["tokens"])


def test_adamw_converges_quadratic():
    import jax
    import jax.numpy as jnp
    from repro.train.optimizer import (AdamWConfig, adamw_update,
                                       init_opt_state)
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, grad_clip=100.0)
    params = {"x": jnp.ones((4, 4)) * 5.0}
    opt = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 0.3
