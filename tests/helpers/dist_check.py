"""Multi-device distributed correctness checks — run IN A SUBPROCESS so the
main pytest process keeps a single device (see conftest note).

Exit code 0 == all checks passed.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import _heartbeat as hb  # noqa: E402

hb.init(sys.argv)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import primitives as prim  # noqa: E402
from repro.core.gnn_models import (init_gat, init_gcn,  # noqa: E402
                                   init_sage, mean_weights)
from repro.core.graph import csr_from_edges, rmat_edges  # noqa: E402
from repro.core.layerwise import (DistributedLayerwise,  # noqa: E402
                                  local_gat_infer, local_gcn_infer,
                                  local_sage_infer)
from repro.core.partition import build_plan  # noqa: E402
from repro.core.sampler import sample_layer_graphs  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402


def check(name, got, want, atol=2e-5):
    err = np.abs(np.asarray(got) - np.asarray(want)).max()
    ok = err <= atol
    print(f"{'OK ' if ok else 'FAIL'} {name}: max_err={err:.2e}")
    hb.beat(name)
    if not ok:
        sys.exit(1)


def main():
    P_, M_ = 4, 2
    mesh = make_host_mesh(P_, M_)
    N, D = 256, 64
    src, dst = rmat_edges(N, N * 8, seed=1)
    g = csr_from_edges(src, dst, N)
    lgs = sample_layer_graphs(g, fanout=8, n_layers=2, seed=0)
    rng = np.random.default_rng(0)
    X = rng.standard_normal((N, D), dtype=np.float32)
    W = rng.standard_normal((D, D), dtype=np.float32) * 0.1

    hd = NamedSharding(mesh, P("data", "model"))
    Xs = jax.device_put(jnp.asarray(X), hd)

    for variant in ("deal", "deal_ring", "cagnet"):
        gm = prim.make_gemm(mesh, variant)
        check(f"gemm/{variant}", gm(Xs, jnp.asarray(W)), X @ W, 5e-5)

    plan = build_plan(lgs, P_, M_)
    lp = plan.layers[0]
    dev = prim.plan_device_arrays(lp)
    w = mean_weights(lgs[0].mask)
    ws = jax.device_put(jnp.asarray(w), NamedSharding(mesh, P("data", None)))
    want = prim.ref_spmm(jnp.asarray(X), jnp.asarray(w),
                         jnp.asarray(lgs[0].nbr), jnp.asarray(lgs[0].mask))
    deal_args = (dev["send_local"], dev["edge_dst"], dev["edge_slot"],
                 dev["edge_pos"], dev["edge_mask"])
    for variant in ("deal", "graph_exchange", "allgather"):
        sp = prim.make_spmm(mesh, lp, variant)
        if variant == "allgather":
            nbr = jnp.asarray(lgs[0].nbr.reshape(P_, N // P_, -1))
            msk = jnp.asarray(lgs[0].mask.reshape(P_, N // P_, -1))
            got = sp(Xs, ws, nbr, msk)
        elif variant == "graph_exchange":
            got = sp(Xs, ws, dev["mirror_src"], dev["edge_dst"],
                     dev["edge_slot"], dev["edge_mask"])
        else:
            got = sp(Xs, ws, *deal_args)
        check(f"spmm/{variant}", got, want)

    # ungrouped (monolithic comm) variant must also be exact
    sp_mono = prim.make_spmm(mesh, lp, "deal", grouped=False)
    check("spmm/deal-ungrouped", sp_mono(Xs, ws, *deal_args), want)

    q = rng.standard_normal((N, D), dtype=np.float32)
    qs = jax.device_put(jnp.asarray(q), hd)
    want_e = prim.ref_sddmm(jnp.asarray(q), jnp.asarray(X),
                            jnp.asarray(lgs[0].nbr),
                            jnp.asarray(lgs[0].mask))
    for variant in ("deal", "dup"):
        sd = prim.make_sddmm(mesh, lp, variant)
        check(f"sddmm/{variant}", sd(qs, Xs, *deal_args), want_e, 2e-4)

    pg = init_gcn(jax.random.PRNGKey(0), [D, 64, 32])
    eng = DistributedLayerwise(mesh, lgs, "gcn", pg)
    check("engine/gcn", eng.infer(X), local_gcn_infer(lgs, X, pg), 5e-5)

    pa = init_gat(jax.random.PRNGKey(1), [D, 64, 32], heads=1)
    eng2 = DistributedLayerwise(mesh, lgs, "gat", pa)
    check("engine/gat", eng2.infer(X), local_gat_infer(lgs, X, pa), 5e-5)
    # sddmm must keep its deal-style plan args even when the spmm
    # variant changes (regression: gat + graph_exchange)
    eng2b = DistributedLayerwise(mesh, lgs, "gat", pa,
                                 spmm_variant="graph_exchange")
    check("engine/gat-graph_exchange", eng2b.infer(X),
          local_gat_infer(lgs, X, pa), 5e-5)

    ps = init_sage(jax.random.PRNGKey(2), [D, 64, 32])
    eng3 = DistributedLayerwise(mesh, lgs, "sage", ps)
    check("engine/sage", eng3.infer(X), local_sage_infer(lgs, X, ps), 5e-5)

    check_dist_delta(mesh, g, lgs, X)
    check_evict_equivalence(mesh, g, lgs, X)
    check_chunked_refresh(mesh, g, lgs, X)
    check_tail_onboarding(mesh, g, lgs, X)

    print("ALL DISTRIBUTED CHECKS PASSED")


def check_dist_delta(mesh, g, lgs, X):
    """Row-subset (frontier) execution on the mesh: DistExecutor-backed
    delta refresh must be BITWISE-equal to a full epoch through the same
    executor, for every model — the distributed-delta-refresh guarantee.
    """
    import copy

    from repro.core.ops import DistExecutor
    from repro.gnnserve import (DeltaReinference, MutationLog,
                                apply_edge_mutations, store_from_inference)

    N, D = X.shape
    L = len(lgs)
    rng = np.random.default_rng(3)
    dex = DistExecutor(mesh)
    for model in ("gcn", "sage", "gat"):
        key = jax.random.PRNGKey(4)
        dims = [D] * L + [32]
        params = {"gcn": lambda: init_gcn(key, dims),
                  "sage": lambda: init_sage(key, dims),
                  "gat": lambda: init_gat(key, dims, heads=1)}[model]()
        ri = DeltaReinference([copy.deepcopy(l) for l in lgs], model,
                              params, executor=dex)
        levels = ri.full_levels(X)
        ref = DeltaReinference([copy.deepcopy(l) for l in lgs], model,
                               params).full_levels(X)
        check(f"delta_dist/{model}/full_levels_vs_ref",
              levels[-1], ref[-1], 5e-5)

        store = store_from_inference(X, levels[1:], n_shards=4)
        log = MutationLog()
        log.add_edges(rng.integers(0, N, 8), rng.integers(0, N, 8))
        fid = rng.choice(N, 3, replace=False)
        log.update_features(fid, rng.standard_normal(
            (3, D)).astype(np.float32))
        batch = log.drain()
        g2 = apply_edge_mutations(g, batch)
        stats = ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
                           batch.affected_dsts())
        assert 0 < stats["frontier_sizes"][-1] < N, stats
        X2 = X.copy()
        X2[batch.feat_ids] = batch.feat_rows
        oracle = DeltaReinference(ri.layer_graphs, model, params,
                                 executor=dex).full_levels(X2)
        for lvl in range(1, L + 1):
            got = store.lookup(np.arange(N), lvl)
            exact = bool((got == oracle[lvl]).all())
            print(f"{'OK ' if exact else 'FAIL'} delta_dist/{model}/"
                  f"level{lvl}: bitwise={exact} "
                  f"frontier={stats['frontier_sizes']}")
            if not exact:
                sys.exit(1)


def check_evict_equivalence(mesh, g, lgs, X):
    """Memory-budgeted store on the DIST executor: with residency capped
    at 50% then tightened to 25%, lookups and a mutated refresh
    (mid-refresh staged misses included) must serve rows bitwise-equal
    to an unbudgeted store — recompute-on-miss routes through
    ``DistExecutor.run_rows``.  Reads are SAMPLED (not full scans): each
    distinct recompute frontier compiles fresh collective geometries on
    the mesh, so full scans at every level would dominate the suite's
    wall clock without adding coverage.
    """
    import copy

    from repro.core.ops import DistExecutor
    from repro.gnnserve import (DeltaReinference, MutationLog,
                                apply_edge_mutations, attach_recompute,
                                store_from_inference)

    N, D = X.shape
    L = len(lgs)
    dex = DistExecutor(mesh)
    for model in ("gcn", "sage", "gat"):
        rng = np.random.default_rng(11)
        key = jax.random.PRNGKey(4)
        dims = [D] * L + [32]
        params = {"gcn": lambda: init_gcn(key, dims),
                  "sage": lambda: init_sage(key, dims),
                  "gat": lambda: init_gat(key, dims, heads=1)}[model]()

        ri_o = DeltaReinference([copy.deepcopy(l) for l in lgs], model,
                                params, executor=dex)
        oracle = store_from_inference(X, ri_o.full_levels(X)[1:],
                                      n_shards=4)
        ri_b = DeltaReinference([copy.deepcopy(l) for l in lgs], model,
                                params, executor=dex)
        store = attach_recompute(
            store_from_inference(X, ri_b.full_levels(X)[1:], n_shards=4,
                                 budget_rows=N // 2), ri_b)

        def sampled_equal(tag):
            ids = np.sort(rng.choice(N, 96, replace=False))
            exact = all(bool((store.lookup(ids, lvl) ==
                              oracle.lookup(ids, lvl)).all())
                        for lvl in range(1, L + 1))
            st = store.stats()
            ok = exact and st["n_evictions"] > 0 and st["misses"] > 0
            print(f"{'OK ' if ok else 'FAIL'} evict_dist/{model}/{tag}: "
                  f"bitwise={exact} evictions={st['n_evictions']} "
                  f"misses={st['misses']} "
                  f"recomputed={st['rows_recomputed']}")
            if not ok:
                sys.exit(1)

        sampled_equal("budget0.5")
        log = MutationLog()
        log.add_edges(rng.integers(0, N, 8), rng.integers(0, N, 8))
        fid = rng.choice(N, 3, replace=False)
        log.update_features(fid, rng.standard_normal(
            (3, D)).astype(np.float32))
        batch = log.drain()
        g2 = apply_edge_mutations(g, batch)
        # lockstep refresh: both stores move version 0 -> 1, so the
        # deterministic resample draws the same rows; the budgeted one
        # recomputes its staged-overlay misses through run_rows
        ri_o.refresh(oracle, g2, batch.feat_ids, batch.feat_rows,
                     batch.affected_dsts())
        ri_b.refresh(store, g2, batch.feat_ids, batch.feat_rows,
                     batch.affected_dsts())
        sampled_equal("budget0.5+refresh")
        store.budget_rows = N // 4          # tighten: 50% -> 25%
        store._enforce_budget()
        sampled_equal("budget0.25")


def check_chunked_refresh(mesh, g, lgs, X):
    """Preemptible chunked refresh on the MESH: a ``begin_refresh`` job
    drained 13 rows at a time commits the exact bytes of the one-shot
    dist refresh — chunk boundaries never change which reduction
    produced a row's bits."""
    import copy

    from repro.core.ops import DistExecutor
    from repro.gnnserve import (DeltaReinference, MutationLog,
                                apply_edge_mutations, store_from_inference)

    N, D = X.shape
    L = len(lgs)
    rng = np.random.default_rng(17)
    dex = DistExecutor(mesh)
    params = init_gcn(jax.random.PRNGKey(4), [D] * L + [32])
    log = MutationLog()
    log.add_edges(rng.integers(0, N, 12), rng.integers(0, N, 12))
    fid = rng.choice(N, 6, replace=False)
    log.update_features(fid, rng.standard_normal((6, D)).astype(np.float32))
    batch = log.drain()
    g2 = apply_edge_mutations(g, batch)

    stores = {}
    for chunk in (0, 13):
        ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn",
                              params, executor=dex)
        store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4)
        job = ri.begin_refresh(store, g2, batch.feat_ids, batch.feat_rows,
                               batch.affected_dsts(), chunk_rows=chunk)
        while not job.done:
            job.step()
        stats = job.finish()
        stores[chunk] = store
        if chunk:
            assert stats["n_chunks"] > L, stats
    for lvl in range(1, L + 1):
        exact = bool((stores[13].lookup(np.arange(N), lvl) ==
                      stores[0].lookup(np.arange(N), lvl)).all())
        print(f"{'OK ' if exact else 'FAIL'} chunked_dist/gcn/level{lvl}: "
              f"bitwise={exact}")
        hb.beat(f"chunked_dist/level{lvl}")
        if not exact:
            sys.exit(1)


def check_tail_onboarding(mesh, g, lgs, X):
    """onboarding="tail" THROUGH the dist executor: tail-partition rows
    (and rows sampling them) route through the local path while main
    rows keep the frozen mesh geometry — and the refreshed store is
    bitwise-equal to a full epoch through the same routed executor
    (``full_epoch`` is the oracle AND the fold)."""
    import copy

    from repro.core.ops import DistExecutor
    from repro.gnnserve import (DeltaReinference, EmbeddingServeEngine,
                                store_from_inference)

    N, D = X.shape
    L = len(lgs)
    rng = np.random.default_rng(23)
    dex = DistExecutor(mesh)
    params = init_gcn(jax.random.PRNGKey(5), [D] * L + [32])
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params,
                          executor=dex)
    store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4,
                                 onboarding="tail")
    eng = EmbeddingServeEngine(store, ri, g, staleness_bound=4)
    k = 3
    eng.mutate().add_nodes(k, rng.standard_normal((k, D)).astype(np.float32))
    new = np.arange(N, N + k)
    eng.mutate().add_edges(rng.integers(0, N, 2 * k), np.repeat(new, 2))
    eng.mutate().add_edges(new, rng.integers(0, N, k))
    stats = eng.refresh()
    assert stats["n_onboarded"] == k, stats
    assert ri.n_tail_routed > 0, "no rows took the tail-local route"
    assert ri.n_dist_layers > 0, "main rows left the mesh"
    # oracle: a full routed epoch over the CURRENT (grown) layer graphs
    # — same frozen n_main, so per-row reductions match the refresh
    X2 = eng.store.lookup(np.arange(N + k, dtype=np.int64), 0)
    oracle = ri.full_levels(X2)
    for lvl in range(1, L + 1):
        exact = bool((eng.store.lookup(np.arange(N + k), lvl) ==
                      oracle[lvl]).all())
        print(f"{'OK ' if exact else 'FAIL'} tail_dist/refresh/level{lvl}:"
              f" bitwise={exact} tail_routed={ri.n_tail_routed}")
        hb.beat(f"tail_dist/level{lvl}")
        if not exact:
            sys.exit(1)
    fold = eng.full_epoch()
    ok = (eng.store.n_tail_shards == 0
          and fold["version"] == eng.store.version
          and bool((eng.store.lookup(np.arange(N + k), -1) ==
                    oracle[-1]).all()))
    print(f"{'OK ' if ok else 'FAIL'} tail_dist/fold: "
          f"n_shards={eng.store.n_shards} bitwise={ok}")
    hb.beat("tail_dist/fold")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
