"""Subprocess check: the §Perf tuned paths (moe_ep, cp_decode) match the
baseline numerics exactly."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import _heartbeat as hb  # noqa: E402

hb.init(sys.argv)

import dataclasses  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models.attention import (cp_decode_attention,  # noqa: E402
                                    decode_attention)
from repro.models.moe import init_moe_params, moe_block  # noqa: E402
from repro.sharding.context import sharding_context  # noqa: E402


def check(name, got, want, atol):
    err = np.abs(np.asarray(got, np.float32)
                 - np.asarray(want, np.float32)).max()
    ok = err <= atol
    print(f"{'OK ' if ok else 'FAIL'} {name}: max_err={err:.2e}")
    hb.beat(name)
    if not ok:
        sys.exit(1)


def main():
    rng = np.random.default_rng(0)

    # ---- H2: expert-parallel MoE == baseline dispatch ----
    cfg = get_config("deepseek-v2-236b").reduced()
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 8, cfg.d_model)).astype(
        np.float32) * 0.5)
    base, _ = jax.jit(lambda x, p: moe_block(x, p, cfg))(x, p)
    mesh = make_host_mesh(4, 2)
    os.environ["REPRO_TUNING"] = "moe_ep"
    with mesh, sharding_context(mesh):
        ep, _ = jax.jit(lambda x, p: moe_block(x, p, cfg))(x, p)
    check("moe_ep == baseline", ep, base, 1e-4)
    os.environ["REPRO_TUNING"] = ""

    # ---- H3: partial-softmax cp decode == plain decode attention ----
    mesh = make_host_mesh(8, 1)
    B, S, H, K, hd = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal((B, S, K, hd)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((B, S, K, hd)).astype(np.float32))
    want = decode_attention(q, kc, vc, cache_len=49)
    with mesh:
        got = jax.jit(lambda q, kc, vc: cp_decode_attention(
            q, kc, vc, cache_len=49, mesh=mesh))(q, kc, vc)
    check("cp_decode == decode", got, want, 2e-5)
    # windowed (gemma local layers)
    want_w = decode_attention(q, kc, vc, cache_len=49, window=7)
    with mesh:
        got_w = jax.jit(lambda q, kc, vc: cp_decode_attention(
            q, kc, vc, cache_len=49, mesh=mesh, window=7))(q, kc, vc)
    check("cp_decode windowed", got_w, want_w, 2e-5)

    print("ALL TUNED CHECKS PASSED")


if __name__ == "__main__":
    main()
