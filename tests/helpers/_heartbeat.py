"""Main-thread liveness file for the subprocess checks.

The forced-8-device collective checks occasionally wedge (every thread
asleep at a collective, ~0 CPU) — an environmental deadlock the parent
previously could only detect with one long global timeout.  Each check
now stamps this file from the MAIN thread as it completes (a timer
thread would keep ticking through a wedge and hide it), so the parent
can watch the file's mtime: fresh stamps mean slow-but-alive, a stale
stamp names exactly the stage that wedged.
"""
import sys
import time

_path = None


def init(argv):
    """Install the heartbeat path from a ``--heartbeat PATH`` argv pair
    (stripped from ``argv``); absent flag = heartbeat disabled."""
    global _path
    if "--heartbeat" in argv:
        i = argv.index("--heartbeat")
        _path = argv[i + 1]
        del argv[i:i + 2]
        beat("startup")


def beat(label: str) -> None:
    """Stamp the liveness file with now + the stage about to run (or
    just finished).  Called from the main thread only."""
    if _path is None:
        return
    try:
        with open(_path, "w") as f:
            f.write(f"{time.time():.3f} {label}\n")
    except OSError as e:        # a broken heartbeat must not fail checks
        print(f"# heartbeat write failed: {e}", file=sys.stderr)
