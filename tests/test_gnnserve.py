"""gnnserve subsystem: store semantics, CSR overlay splice, delta
re-inference bitwise equivalence, and the continuous-batching engine."""
import copy

import jax
import numpy as np
import pytest

from repro.core.gnn_models import init_gat, init_gcn, init_sage
from repro.core.graph import csr_from_edges, rmat_edges
from repro.core.layerwise import LOCAL_ENGINES
from repro.core.ops import DistExecutor
from repro.core.sampler import sample_layer_graphs
from repro.gnnserve import (DeltaReinference, EmbeddingServeEngine,
                            EmbeddingStore, MutationLog, Query,
                            apply_edge_mutations, store_from_inference)

N, D, L, FANOUT = 512, 32, 3, 8


@pytest.fixture(scope="module")
def world():
    src, dst = rmat_edges(N, N * 8, seed=7)
    g = csr_from_edges(src, dst, N)
    lgs = sample_layer_graphs(g, fanout=FANOUT, n_layers=L, seed=3)
    rng = np.random.default_rng(1)
    X = rng.standard_normal((N, D), dtype=np.float32)
    return g, src, dst, lgs, X


def _params(model, key=None):
    key = key or jax.random.PRNGKey(0)
    dims = [D] * L + [16]
    return {"gcn": lambda: init_gcn(key, dims),
            "sage": lambda: init_sage(key, dims),
            "gat": lambda: init_gat(key, [D] * (L + 1), heads=4)}[model]()


def _mutate(rng, src, dst, n_edge=8, n_feat=3):
    log = MutationLog()
    log.add_edges(rng.integers(0, N, n_edge), rng.integers(0, N, n_edge))
    pick = rng.choice(src.size, n_edge, replace=False)
    log.remove_edges(src[pick], dst[pick])
    if n_feat:
        fid = rng.choice(N, n_feat, replace=False)
        log.update_features(fid, rng.standard_normal((n_feat, D),
                                                     dtype=np.float32))
    return log


# ----------------------------------------------------------------------
# store
# ----------------------------------------------------------------------

def test_store_roundtrip_and_double_buffer(world):
    *_, X = world
    h1 = np.arange(N * 8, dtype=np.float32).reshape(N, 8)
    store = EmbeddingStore([X, h1], n_shards=4)
    ids = np.array([0, 17, 200, N - 1])
    np.testing.assert_array_equal(store.lookup(ids, 0), X[ids])
    np.testing.assert_array_equal(store.lookup(ids, -1), h1[ids])

    store.begin_update()
    store.write_rows(1, ids, np.full((ids.size, 8), -5.0, np.float32))
    # readers still see the committed front buffer
    np.testing.assert_array_equal(store.lookup(ids, 1), h1[ids])
    # the staged view reads through
    assert (store.lookup_staged(ids, 1) == -5.0).all()
    v0 = store.version
    store.commit()
    assert store.version == v0 + 1
    assert (store.lookup(ids, 1) == -5.0).all()
    # untouched rows of the dirtied shard survive the copy-on-write
    others = np.array([1, 18, 201])
    np.testing.assert_array_equal(store.lookup(others, 1), h1[others])

    store.begin_update()
    store.write_rows(1, ids, np.zeros((ids.size, 8), np.float32))
    store.abort()
    assert (store.lookup(ids, 1) == -5.0).all()


# ----------------------------------------------------------------------
# mutation overlay
# ----------------------------------------------------------------------

def test_apply_edge_mutations_matches_rebuild(world):
    g, src, dst, *_ = world
    rng = np.random.default_rng(5)
    log = _mutate(rng, src, dst, n_edge=32, n_feat=0)
    batch = log.drain()
    g2 = apply_edge_mutations(g, batch)

    # oracle: edit the edge list and rebuild the CSR from scratch
    edges = {(int(s), int(d)) for s, d in zip(src, dst)}
    kept = [(int(s), int(d)) for s, d in zip(src, dst)]
    for s, d in zip(batch.del_src, batch.del_dst):
        if (int(s), int(d)) in edges:
            kept.remove((int(s), int(d)))
    kept += list(zip(batch.add_src.tolist(), batch.add_dst.tolist()))
    g3 = csr_from_edges(np.array([e[0] for e in kept]),
                        np.array([e[1] for e in kept]), N)
    np.testing.assert_array_equal(g2.indptr, g3.indptr)
    for v in range(N):          # per-row multiset equality
        assert sorted(g2.neighbors(v).tolist()) == \
            sorted(g3.neighbors(v).tolist()), v


def test_add_then_remove_same_edge_nets_out(world):
    """Intra-batch op order is honored: add-then-remove of an edge not in
    the base graph must be a no-op, and remove-then-add must keep it."""
    g, *_ = world
    v = 0
    before = sorted(g.neighbors(v).tolist())
    absent = N - 1 if (N - 1) not in before else N - 2
    log = MutationLog()
    log.add_edge(absent, v)
    log.remove_edge(absent, v)
    g2 = apply_edge_mutations(g, log.drain())
    assert sorted(g2.neighbors(v).tolist()) == before

    log = MutationLog()
    log.remove_edge(absent, v)      # no-op: not present yet
    log.add_edge(absent, v)
    g3 = apply_edge_mutations(g, log.drain())
    assert sorted(g3.neighbors(v).tolist()) == sorted(before + [absent])


def test_remove_missing_edge_is_noop(world):
    g, *_ = world
    log = MutationLog()
    log.remove_edge(int(g.indices[0]) + 1, 0)   # likely absent pair
    before = g.neighbors(0).copy()
    g2 = apply_edge_mutations(g, log.drain())
    got = g2.neighbors(0)
    assert sorted(got.tolist()) == sorted(before.tolist()) or \
        len(got) == len(before) - 1


# ----------------------------------------------------------------------
# delta re-inference
# ----------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_delta_refresh_bitwise_matches_full(world, model):
    g, src, dst, lgs, X = world
    params = _params(model)
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], model, params)
    levels = ri.full_levels(X)
    # sanity: full_levels agrees bitwise with the existing local engine
    want = np.asarray(LOCAL_ENGINES[model](lgs, X, params))
    np.testing.assert_array_equal(levels[-1], want)

    store = store_from_inference(X, levels[1:], n_shards=4)
    rng = np.random.default_rng(11)
    batch = _mutate(rng, src, dst).drain()
    g2 = apply_edge_mutations(g, batch)
    stats = ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
                       batch.affected_dsts())
    assert stats["version"] == 1
    assert 0 < stats["frontier_sizes"][-1] <= N

    # oracle: from-scratch recompute over the SAME mutated layer graphs
    X2 = X.copy()
    X2[batch.feat_ids] = batch.feat_rows
    oracle = DeltaReinference(ri.layer_graphs, model, params).full_levels(X2)
    all_ids = np.arange(N)
    for lvl in range(1, ri.n_layers + 1):
        got = store.lookup(all_ids, lvl)
        np.testing.assert_array_equal(got, oracle[lvl])  # bitwise, ALL rows


def test_refresh_batching_is_invariant(world):
    """Folding one mutation stream in one batch or two lands on
    bitwise-identical store bytes (content-addressed resample seeding) —
    the property the QoS engine's per-tenant freshness views rely on."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    rng = np.random.default_rng(41)
    logs = [_mutate(np.random.default_rng(s), src, dst) for s in (1, 2)]
    batches = [lg_.drain() for lg_ in logs]

    def fold(batch_seq):
        ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn",
                              params)
        store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4)
        gm = g
        for b in batch_seq:
            gm = apply_edge_mutations(gm, b)
            ri.refresh(store, gm, b.feat_ids, b.feat_rows,
                       b.affected_dsts())
        return store

    # one big batch: replay both logs into a single drain
    big = MutationLog()
    for b in batches:
        big.requeue(b)
    split, whole = fold(batches), fold([big.drain()])
    all_ids = np.arange(N)
    for lvl in range(L + 1):
        np.testing.assert_array_equal(split.lookup(all_ids, lvl),
                                      whole.lookup(all_ids, lvl))


def test_reverse_index_splice_equals_rebuild(world):
    """`splice_reverse_index` over the resampled rows' old/new entries
    must equal a from-scratch `build_reverse_index`, indptr and rows
    bitwise, across chained mutations."""
    from repro.gnnserve import (build_reverse_index, resample_rows,
                                splice_reverse_index)
    g, src, dst, lgs, X = world
    lgs2 = [copy.deepcopy(l) for l in lgs]
    rev = [build_reverse_index(lg) for lg in lgs2]
    rng = np.random.default_rng(3)
    gm = g
    for _ in range(3):
        batch = _mutate(rng, src, dst, n_edge=12, n_feat=0).drain()
        gm = apply_edge_mutations(gm, batch)
        rows = batch.affected_dsts()
        old = [(lg.nbr[rows].copy(), lg.mask[rows].copy()) for lg in lgs2]
        resample_rows(gm, lgs2, rows, seed=0)
        for l, lg in enumerate(lgs2):
            rev[l] = splice_reverse_index(rev[l], rows, old[l][0],
                                          old[l][1], lg.nbr[rows],
                                          lg.mask[rows])
            fresh = build_reverse_index(lg)
            np.testing.assert_array_equal(rev[l].indptr, fresh.indptr)
            np.testing.assert_array_equal(rev[l].rows, fresh.rows)


def test_refresh_maintains_reverse_index_incrementally(world):
    """After the first refresh builds the reverse indexes, later mutated
    refreshes SPLICE them (O(changed)) instead of rebuilding (O(N*F))."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params)
    store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4)
    rng = np.random.default_rng(13)
    gm = g
    for it in range(3):
        batch = _mutate(rng, src, dst).drain()
        gm = apply_edge_mutations(gm, batch)
        ri.refresh(store, gm, batch.feat_ids, batch.feat_rows,
                   batch.affected_dsts())
    # first refresh lazily rebuilt each layer's index; the next two
    # spliced it in place of the old full-rebuild-every-refresh path
    assert ri.rev_rebuilds == ri.n_layers
    assert ri.rev_splices == 2 * ri.n_layers
    from repro.gnnserve import build_reverse_index
    for l, lg in enumerate(ri.layer_graphs):
        fresh = build_reverse_index(lg)
        np.testing.assert_array_equal(ri._rev[l].indptr, fresh.indptr)
        np.testing.assert_array_equal(ri._rev[l].rows, fresh.rows)


def test_frontier_is_complete(world):
    """Every row the mutation actually changed is inside the frontier —
    rows outside it were provably safe to skip."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params)
    before = ri.full_levels(X)
    store = store_from_inference(X, before[1:], n_shards=4)
    rng = np.random.default_rng(23)
    batch = _mutate(rng, src, dst).drain()
    g2 = apply_edge_mutations(g, batch)
    stats = ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
                       batch.affected_dsts())
    after = DeltaReinference(ri.layer_graphs, "gcn", params).full_levels(
        store.lookup(np.arange(N), 0))
    final_frontier = stats["frontier_sizes"][-1]
    changed = np.nonzero((before[-1] != after[-1]).any(axis=1))[0]
    assert changed.size <= final_frontier
    # and delta never recomputed everything for this tiny batch
    assert final_frontier < N


# ----------------------------------------------------------------------
# serve engine
# ----------------------------------------------------------------------

def _engine(world, staleness_bound=4):
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params)
    levels = ri.full_levels(X)
    store = store_from_inference(X, levels[1:], n_shards=4)
    eng = EmbeddingServeEngine(store, ri, g, batch_slots=3,
                               rows_per_step=32,
                               staleness_bound=staleness_bound)
    return eng, levels


def test_engine_serves_correct_rows(world):
    eng, levels = _engine(world)
    rng = np.random.default_rng(3)
    qs = [Query(uid=i, node_ids=rng.choice(N, 100, replace=False))
          for i in range(7)]
    for q in qs:
        eng.submit(q)
    eng.run()
    assert all(q.done for q in qs)
    for q in qs:
        np.testing.assert_array_equal(q.out, levels[-1][q.node_ids])
    s = eng.stats()
    assert s["n_served"] == 7 and s["n_refreshes"] == 0
    # continuous batching: way fewer gather steps than per-query serial
    assert s["n_gather_steps"] < 7 * (100 // 10)


def test_engine_staleness_triggers_refresh(world):
    g, src, dst, lgs, X = world
    eng, levels = _engine(world, staleness_bound=4)
    rng = np.random.default_rng(9)
    # 2 pending mutations: below the bound, serving stays stale
    eng.mutate().add_edges(rng.integers(0, N, 2), rng.integers(0, N, 2))
    q1 = Query(uid=0, node_ids=np.arange(50))
    eng.submit(q1)
    eng.run()
    assert eng.n_refreshes == 0 and q1.served_version == 0
    # crossing the bound forces a refresh before the next gather
    eng.mutate().add_edges(rng.integers(0, N, 5), rng.integers(0, N, 5))
    q2 = Query(uid=1, node_ids=np.arange(50))
    eng.submit(q2)
    eng.run()
    assert eng.n_refreshes == 1 and eng.store.version == 1
    assert q2.served_version == 1 and eng.staleness == 0
    # served rows match a from-scratch epoch over the refreshed state
    oracle = DeltaReinference(eng.reinfer.layer_graphs, "gcn",
                              eng.reinfer.params).full_levels(
        eng.store.lookup(np.arange(N), 0))
    np.testing.assert_array_equal(q2.out, oracle[-1][q2.node_ids])


def test_failed_refresh_preserves_log_and_rolls_back(world):
    """A bad batch must neither discard the good mutations drained with
    it nor leave layer graphs and store out of sync."""
    g, src, dst, lgs, X = world
    eng, _ = _engine(world, staleness_bound=1)
    eng.mutate().add_edge(N + 5, 0)                 # invalid source id
    eng.mutate().update_features(
        np.array([1, 2]), np.random.default_rng(2).standard_normal(
            (2, D), dtype=np.float32))
    before = eng.staleness
    with pytest.raises(AssertionError):
        eng.refresh()
    assert eng.staleness == before                  # nothing lost
    assert eng.store.version == 0                   # nothing committed

    # a failure INSIDE the store transaction rolls the resample back too:
    # a later clean refresh must leave store == from-scratch epoch
    ri, store = eng.reinfer, eng.store
    log = MutationLog()
    log.add_edges(np.array([5, 6]), np.array([7, 8]))
    batch = log.drain()
    g2 = apply_edge_mutations(g, batch)
    with pytest.raises(ValueError):
        ri.refresh(store, g2, np.array([0]),
                   np.zeros((1, 99), np.float32),   # wrong feature width
                   batch.affected_dsts())
    ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
               batch.affected_dsts())
    oracle = DeltaReinference(ri.layer_graphs, "gcn",
                              ri.params).full_levels(
        store.lookup(np.arange(N), 0))
    for lvl in range(1, ri.n_layers + 1):
        np.testing.assert_array_equal(store.lookup(np.arange(N), lvl),
                                      oracle[lvl])


def test_mid_query_refresh_serves_one_epoch(world):
    """A refresh landing while a query is mid-gather must not tear the
    response across epochs: every row comes from the pinned snapshot."""
    g, src, dst, lgs, X = world
    eng, levels = _engine(world, staleness_bound=2)
    eng.rows_per_step = 16
    q = Query(uid=0, node_ids=np.arange(64))
    eng.submit(q)
    eng.step()                                      # rows 0..15 at v0
    rng = np.random.default_rng(3)
    eng.mutate().add_edges(rng.integers(0, N, 4), rng.integers(0, N, 4))
    eng.run()                                       # refresh fires mid-query
    assert eng.store.version == 1
    assert q.served_version == 0                    # pinned at first gather
    np.testing.assert_array_equal(q.out, levels[-1][q.node_ids])


def test_engine_fresh_query_and_node_adds(world):
    eng, _ = _engine(world, staleness_bound=10_000)
    rng = np.random.default_rng(13)
    eng.mutate().add_edges(rng.integers(0, N, 3), rng.integers(0, N, 3))
    q = Query(uid=0, node_ids=np.arange(10), fresh=True)
    eng.submit(q)
    eng.run()
    assert q.done and q.served_version == 1 and eng.n_refreshes == 1

    eng.mutate().add_nodes(2)
    eng.submit(Query(uid=1, node_ids=np.arange(4), fresh=True))
    with pytest.raises(NotImplementedError):
        eng.run()


# ----------------------------------------------------------------------
# frontier-size cutover (dist -> local routing for tiny frontiers)
# ----------------------------------------------------------------------

class _FakeDist(DistExecutor):
    """A DistExecutor by type only: any mesh work explodes.  Lets the
    cutover tests prove which route a layer actually took without
    spinning up a mesh subprocess."""

    def __init__(self):          # no mesh, no plan
        pass

    def run_rows(self, *a, **k):
        raise AssertionError("dist path taken")


def test_cutover_routes_tiny_frontiers_local(world):
    """With the threshold above every universe size, all layers run on
    the lazily-built local executor — bitwise-equal to a ref-executor
    refresh — and the counters record the routing decision."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    twins = {}
    for name, ex, cut in (("cut", _FakeDist(), 10 ** 9), ("ref", "ref", 0)):
        ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn",
                              params, executor=ex, local_cutover=cut)
        store = store_from_inference(
            X, DeltaReinference(lgs, "gcn", params).full_levels(X)[1:],
            n_shards=4)
        g2 = g
        rng = np.random.default_rng(11)
        for _ in range(2):
            batch = _mutate(rng, src, dst).drain()
            g2 = apply_edge_mutations(g2, batch)
            stats = ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
                               batch.affected_dsts())
        twins[name] = (store, stats)
    store_c, stats_c = twins["cut"]
    store_r, _ = twins["ref"]
    assert stats_c["n_local_cutovers"] > 0
    assert stats_c["n_dist_layers"] == 0
    assert stats_c["local_cutover"] == 10 ** 9
    ids = np.arange(N)
    for lvl in range(L + 1):
        np.testing.assert_array_equal(store_c.lookup(ids, lvl),
                                      store_r.lookup(ids, lvl))


def test_cutover_disabled_takes_dist_path(world):
    """local_cutover=0 (the default) must leave routing untouched —
    run_rows is reached, preserving dist-vs-dist bitwise equivalence."""
    g, src, dst, lgs, X = world
    params = _params("gcn")
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params,
                          executor=_FakeDist())
    store = store_from_inference(
        X, DeltaReinference(lgs, "gcn", params).full_levels(X)[1:],
        n_shards=4)
    rng = np.random.default_rng(11)
    batch = _mutate(rng, src, dst).drain()
    g2 = apply_edge_mutations(g, batch)
    with pytest.raises(AssertionError, match="dist path taken"):
        ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
                   batch.affected_dsts())
