"""Executor equivalence: one declarative layer spec, interchangeable
backends.

  * RefExecutor must match hand-rolled jnp math (the spec cannot drift);
  * PallasExecutor (interpret mode) must match ref within dtype tolerance
    for every model, on NON-ALIGNED N/D shapes (the executor pads to
    kernel blocks internally), float32 and bfloat16;
  * delta refresh through the pallas executor must stay bitwise-equal to
    a full epoch through the same executor (the dist twin of this check
    lives in tests/helpers/dist_check.py — meshes need a subprocess).
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gnn_models import (init_gat, init_gcn, init_sage,
                                   mean_weights, model_spec)
from repro.core.graph import csr_from_edges, rmat_edges
from repro.core.layerwise import LOCAL_ENGINES
from repro.core.ops import (DenseIO, PallasExecutor, RefExecutor,
                            get_executor, run_model)
from repro.core.sampler import sample_layer_graphs

N, D = 200, 48              # deliberately non-aligned (not pow2/128-mult)
FANOUT, L = 6, 2
DIMS = [D, 48, 40]          # head-major gat: every width % heads == 0

ATOL = {jnp.float32: 2e-4, jnp.bfloat16: 0.25}


@pytest.fixture(scope="module")
def world():
    src, dst = rmat_edges(N, N * 8, seed=7)
    g = csr_from_edges(src, dst, N)
    lgs = sample_layer_graphs(g, fanout=FANOUT, n_layers=L, seed=3)
    X = np.random.default_rng(1).standard_normal((N, D)).astype(np.float32)
    return lgs, X


def _params(model, heads=4):
    key = jax.random.PRNGKey(0)
    return {"gcn": lambda: init_gcn(key, DIMS),
            "sage": lambda: init_sage(key, DIMS),
            "gat": lambda: init_gat(key, DIMS, heads=heads)}[model]()


def test_ref_executor_matches_manual_gcn(world):
    """Guard the spec against drift: hand-rolled jnp math inline."""
    lgs, X = world
    params = _params("gcn")
    got = np.asarray(run_model(
        RefExecutor(), model_spec("gcn", params),
        [DenseIO.from_layer_graph(lg) for lg in lgs], X))
    H = jnp.asarray(X)
    for l, w in enumerate(params["w"]):
        lg = lgs[l]
        wts = jnp.asarray(mean_weights(lg.mask))
        H = jnp.dot(H, w, preferred_element_type=jnp.float32)
        vals = jnp.take(H, jnp.asarray(lg.nbr).reshape(-1), axis=0)
        vals = vals.reshape(lg.nbr.shape + (H.shape[-1],))
        H = (vals * (wts * lg.mask)[..., None]).sum(axis=1)
        if l < L - 1:
            H = jax.nn.relu(H)
    np.testing.assert_allclose(got, np.asarray(H), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_ref(world, model, dtype):
    lgs, X = world
    params = _params(model)
    Xd = jnp.asarray(X, dtype)
    want = np.asarray(LOCAL_ENGINES[model](lgs, Xd, params), np.float32)
    got = np.asarray(LOCAL_ENGINES[model](lgs, Xd, params,
                                          executor="pallas"), np.float32)
    np.testing.assert_allclose(got, want, atol=ATOL[dtype], rtol=3e-2)


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_spec_single_definition(model):
    """Every engine consumes the same spec object shape — one definition
    of the layer math per model."""
    spec = model_spec(model, _params(model))
    assert len(spec.layers) == L
    kinds = [op.kind for op in spec.layers[0].ops]
    assert kinds == {"gcn": ["gemm", "spmm"],
                     "sage": ["spmm", "gemm", "gemm", "add"],
                     "gat": ["gemm", "gemm", "gemm", "attn_scores",
                             "edge_softmax", "attend"]}[model]


def test_delta_refresh_pallas_bitwise(world):
    """Delta refresh through the pallas executor == full epoch through
    the pallas executor, bitwise (mirrors the ref-executor guarantee)."""
    from repro.gnnserve import (DeltaReinference, MutationLog,
                                apply_edge_mutations, store_from_inference)
    src, dst = rmat_edges(128, 128 * 8, seed=5)
    g = csr_from_edges(src, dst, 128)
    lgs = sample_layer_graphs(g, fanout=4, n_layers=2, seed=2)
    rng = np.random.default_rng(0)
    X = rng.standard_normal((128, 32)).astype(np.float32)
    params = init_gcn(jax.random.PRNGKey(1), [32, 32, 32])
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params,
                          executor="pallas")
    levels = ri.full_levels(X)
    store = store_from_inference(X, levels[1:], n_shards=4)
    log = MutationLog()
    log.add_edges(rng.integers(0, 128, 6), rng.integers(0, 128, 6))
    batch = log.drain()
    g2 = apply_edge_mutations(g, batch)
    ri.refresh(store, g2, batch.feat_ids, batch.feat_rows,
               batch.affected_dsts())
    oracle = DeltaReinference(ri.layer_graphs, "gcn", params,
                              executor="pallas").full_levels(X)
    for lvl in range(1, 3):
        np.testing.assert_array_equal(store.lookup(np.arange(128), lvl),
                                      oracle[lvl])


def test_executor_factory():
    assert isinstance(get_executor("ref"), RefExecutor)
    assert isinstance(get_executor("pallas"), PallasExecutor)
    ex = RefExecutor()
    assert get_executor(ex) is ex
    with pytest.raises(ValueError):
        get_executor("dist")            # needs a mesh
    with pytest.raises(ValueError):
        get_executor("nope")
