"""End-to-end system behaviour: the full DEAL pipeline (Fig 2) and the
dry-run artifact contract."""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def test_end_to_end_pipeline_local():
    """edge list -> distributed CSR -> sample -> partition -> all-node
    inference, single host."""
    from repro.launch.infer_gnn import run
    H = run("ogbn-products", model="gcn", p=2, m=1, fanout=4, n_layers=2,
            d_feature=16, distributed=False)
    assert H.shape[1] == 16 and np.isfinite(H).all()


@pytest.mark.slow
def test_end_to_end_pipeline_distributed():
    """Same pipeline on an 8-device mesh, via subprocess."""
    code = (
        "import os; "
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'; "
        "import sys; sys.path.insert(0, 'src'); "
        "from repro.launch.infer_gnn import run; "
        "import numpy as np; "
        "H = run('ogbn-products', model='gcn', p=4, m=2, fanout=4, "
        "n_layers=2, d_feature=16, distributed=True); "
        "assert np.isfinite(H).all(); print('E2E-DIST-OK')"
    )
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200,
                         cwd=str(pathlib.Path(__file__).resolve().parents[1]))
    assert res.returncode == 0, res.stdout + res.stderr[-2000:]
    assert "E2E-DIST-OK" in res.stdout


def test_dryrun_artifacts_schema():
    """Every present dry-run record is status=ok with roofline terms."""
    files = list(RESULTS.glob("*.json"))
    if not files:
        pytest.skip("dry-run not executed yet")
    bad = []
    for f in files:
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            bad.append((f.name, d.get("error", "?")))
            continue
        r = d["roofline"]
        assert r["compute_s"] >= 0 and r["memory_s"] >= 0
        assert d["collectives"]["total"] >= 0
        assert d["n_chips"] in (256, 512)
    assert not bad, bad


def test_serve_launcher_runs():
    from repro.launch.serve import run
    reqs = run("smollm-360m", n_requests=3, max_new=4, batch_slots=2,
               max_seq=64)
    assert all(r.done for r in reqs)
