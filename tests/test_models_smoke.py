"""Per-arch smoke: reduced variant, one forward/train step + one decode
step on CPU; output shapes + no NaNs.  Also decode<->prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_cache, init_params)
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import train_step


def _batch(cfg, B, S, rng, with_labels=False):
    if cfg.family == "audio":
        d = {"frames": jnp.asarray(
                rng.standard_normal((B, 16, cfg.frontend_dim)),
                jnp.bfloat16),
             "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
        if with_labels:
            d["labels"] = d["tokens"]
        return d
    if cfg.family == "vlm":
        n_img = cfg.n_frontend_tokens
        d = {"patches": jnp.asarray(
                rng.standard_normal((B, n_img, cfg.frontend_dim)),
                jnp.bfloat16),
             "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S - n_img)),
                jnp.int32)}
        if with_labels:
            d["labels"] = d["tokens"]
        return d
    d = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if with_labels:
        d["labels"] = d["tokens"]
    return d


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch, rng):
    cfg = get_config(arch).reduced()
    p = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    logits, aux = forward(cfg, p, _batch(cfg, B, S, rng), mode="train")
    # vlm: logits cover image + text positions (total S); loss slices text
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    cache = init_cache(cfg, B, S, enc_len=16)
    lg, cache2 = decode_step(cfg, p, cache,
                             {"token": jnp.zeros((B, 1), jnp.int32),
                              "pos": jnp.int32(3)})
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    p = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(p, opt_cfg)
    batch = _batch(cfg, 2, 32, rng, with_labels=True)
    p2, opt2, metrics = train_step(cfg, opt_cfg, p, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)
                                                ).sum()), p, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen2.5-14b", "gemma3-4b",
                                  "mamba2-1.3b", "deepseek-v2-236b",
                                  "zamba2-7b", "llama4-maverick-400b-a17b"])
def test_decode_matches_teacher_forcing(arch, rng):
    """Step-by-step decode logits == full-forward logits (same positions).

    MoE capacity is raised so no token drops: capacity-based prefill
    routing vs per-token decode routing only agree when nothing is dropped
    (the standard train/serve skew of capacity MoEs)."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    p = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full, _ = forward(cfg, p, {"tokens": tokens}, mode="prefill",
                      remat=False)
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = decode_step(cfg, p, cache,
                                {"token": tokens[:, t:t + 1],
                                 "pos": jnp.int32(t)})
        outs.append(np.asarray(lg)[:, 0])
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, np.asarray(full), atol=2e-3, rtol=2e-3)
