"""Property tests on the gnnserve store/engine invariants.

Random interleavings of ``begin_update`` / ``write_rows`` / ``commit`` /
``abort`` / ``snapshot`` / ``lookup`` / ``evict`` against a shadow model
(a plain never-evicted copy of every level) check, after EVERY op:

  1. committed reads are bitwise-equal to the shadow — eviction plus
     recompute-on-miss is invisible (no torn epochs);
  2. snapshot reads are version-stable: a pinned snapshot keeps serving
     its epoch bitwise across later commits AND evictions, and an
     unpinned read after the epoch moved on either serves the OLD epoch
     or raises ``SnapshotMiss`` — never mixes epochs;
  3. the memory budget holds: every evictable level stays at or under
     ``budget_rows`` resident rows at every API boundary;
  4. the residency bitmap is truthful: every row it marks resident holds
     exactly the shadow's bytes for the matching view;
  5. the staging overlay gives read-your-writes (``lookup_staged``)
     while committed reads stay on the old epoch, and ``abort`` discards
     every staged byte including recompute-admitted ones;
  6. ``MutationLog`` drain -> requeue (the ``engine.refresh`` failure
     path) preserves the pending set, the op ORDER, and therefore the
     net CSR effect;
  7. ``splice_reverse_index`` over random mutation chains equals a
     from-scratch ``build_reverse_index``, indptr and rows bitwise.

The suite runs with or without hypothesis: when the package is absent
(some local sandboxes) each property degrades to a fixed seed sweep, so
CI and local runs never skip-collect the invariants.
"""
import numpy as np
import pytest

from repro.core.graph import csr_from_edges, rmat_edges
from repro.gnnserve import (EmbeddingStore, MutationLog, SnapshotMiss,
                            apply_edge_mutations)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False


def seed_property(max_examples: int = 25, fallback: int = 10):
    """``@given(seed)`` under hypothesis, a seed sweep without it."""
    if HAVE_HYPOTHESIS:
        def deco(f):
            return settings(max_examples=max_examples, deadline=None)(
                given(st.integers(0, 2 ** 32 - 1))(f))
        return deco
    return pytest.mark.parametrize("seed", range(fallback))


N, D, LEVELS, SHARDS = 64, 4, 3, 4          # features + 2 layers


class Shadow:
    """Never-evicted twin: the ground truth every view must match."""

    def __init__(self, rng):
        self.committed = [rng.standard_normal((N, D)).astype(np.float32)
                          for _ in range(LEVELS)]
        self.staged = None
        self.version = 0
        self.history = {0: [a.copy() for a in self.committed]}

    def view(self, staged):
        return (self.staged if staged and self.staged is not None
                else self.committed)

    def begin(self):
        self.staged = [a.copy() for a in self.committed]

    def commit(self):
        self.committed, self.staged = self.staged, None
        self.version += 1
        self.history[self.version] = [a.copy() for a in self.committed]

    def abort(self):
        self.staged = None


def _mk_store(shadow, budget, policy):
    store = EmbeddingStore([a.copy() for a in shadow.committed],
                           n_shards=SHARDS, budget_rows=budget,
                           evict_policy=policy)
    # oracle hook: what a never-evicted store would hold for that view
    store.recompute = lambda level, ids, staged: \
        shadow.view(staged)[level][ids]
    return store


def _rand_ids(rng, unique=False):
    k = int(rng.integers(1, N // 2))
    ids = rng.integers(0, N, k)
    return np.unique(ids) if unique else ids


def _check_all(store, shadow, rng, budget):
    # (3) budget cap at every API boundary
    if budget is not None:
        for level in range(1, LEVELS):
            assert store.resident_rows(level) <= budget, level
    # (4) residency bitmap truthfulness, random shard spot-check — and
    # the incremental popcount counters must agree with the bitmaps
    level = int(rng.integers(0, LEVELS))
    s = int(rng.integers(0, SHARDS))
    data, mask = store._view_shard(level, s, staged=False)
    if data is not None and mask.any():
        lo = store.bounds[s]
        rows = np.nonzero(mask)[0]
        np.testing.assert_array_equal(
            data[rows], shadow.committed[level][rows + lo])
    assert store.resident_rows(level) == \
        sum(int(m.sum()) for m in store._mask[level])


@seed_property()
@pytest.mark.parametrize("policy", ["heat", "lru"])
def test_interleaved_ops_never_tear(policy, seed):
    rng = np.random.default_rng(seed)
    shadow = Shadow(rng)
    budget = int(rng.integers(N // 4, N))       # 25%..100% of a level
    store = _mk_store(shadow, budget, policy)
    snaps = []

    for _ in range(40):
        op = rng.choice(["lookup", "staged_lookup", "begin", "write",
                         "commit", "abort", "evict", "snapshot",
                         "snap_read"])
        open_ = store._staged is not None
        if op == "lookup":
            ids = _rand_ids(rng)
            level = int(rng.integers(0, LEVELS))
            got = store.lookup(ids, level)
            np.testing.assert_array_equal(            # (1) no torn epochs
                got, shadow.committed[level][ids])
        elif op == "staged_lookup" and open_:
            ids = _rand_ids(rng)
            level = int(rng.integers(0, LEVELS))
            np.testing.assert_array_equal(            # (5) read-your-writes
                store.lookup_staged(ids, level),
                shadow.view(True)[level][ids])
        elif op == "begin" and not open_:
            store.begin_update()
            shadow.begin()
        elif op == "write" and open_:
            ids = _rand_ids(rng, unique=True)
            level = int(rng.integers(0, LEVELS))
            rows = rng.standard_normal((ids.size, D)).astype(np.float32)
            store.write_rows(level, ids, rows)
            shadow.staged[level][ids] = rows
            # (5) committed reads stay on the old epoch
            np.testing.assert_array_equal(
                store.lookup(ids, level), shadow.committed[level][ids])
        elif op == "commit" and open_:
            store.commit()
            shadow.commit()
            assert store.version == shadow.version
        elif op == "abort" and open_:
            store.abort()
            shadow.abort()
        elif op == "evict":
            store.evict(int(rng.integers(1, LEVELS)),
                        int(rng.integers(0, SHARDS)))
        elif op == "snapshot":
            ids = _rand_ids(rng, unique=True)
            level = int(rng.integers(0, LEVELS))
            snap = store.pinned_snapshot(ids, level)
            snaps.append((snap, ids, level,
                          shadow.committed[level][ids].copy()))
        elif op == "snap_read" and snaps:
            snap, ids, level, want = snaps[int(rng.integers(len(snaps)))]
            # (2) pinned rows: version-stable across commits + evictions
            np.testing.assert_array_equal(snap.lookup(ids, level), want)
            # (2) unpinned rows: the snapshot's OWN epoch or SnapshotMiss
            other = _rand_ids(rng)
            lvl2 = int(rng.integers(0, LEVELS))
            try:
                got = snap.lookup(other, lvl2)
            except SnapshotMiss:
                assert snap.version != store.version
            else:
                np.testing.assert_array_equal(
                    got, shadow.history[snap.version][lvl2][other])
        _check_all(store, shadow, rng, budget)

    if store._staged is not None:               # (5) abort discards all
        store.abort()
        shadow.abort()
    all_ids = np.arange(N)
    for level in range(LEVELS):
        np.testing.assert_array_equal(store.lookup(all_ids, level),
                                      shadow.committed[level])


@seed_property()
def test_eviction_without_hook_raises_instead_of_tearing(seed):
    """A budgeted store with no recompute hook must fail loudly on a
    miss, never serve stale or zero rows."""
    from repro.gnnserve import EvictedRowMiss
    rng = np.random.default_rng(seed)
    shadow = Shadow(rng)
    store = EmbeddingStore([a.copy() for a in shadow.committed],
                           n_shards=SHARDS)
    level = int(rng.integers(1, LEVELS))
    s = int(rng.integers(0, SHARDS))
    n_evicted = store.evict(level, s)
    assert n_evicted == N // SHARDS
    hit = np.arange(store.bounds[s], store.bounds[s + 1])
    with pytest.raises(EvictedRowMiss):
        store.lookup(hit, level)
    # other shards still serve, and level 0 is never evictable
    other = (s + 1) % SHARDS
    ids = np.arange(store.bounds[other], store.bounds[other + 1])
    np.testing.assert_array_equal(store.lookup(ids, level),
                                  shadow.committed[level][ids])
    with pytest.raises(AssertionError):
        store.evict(0, s)


def _random_log(rng, n_nodes):
    log = MutationLog()
    pairs = [(int(rng.integers(0, n_nodes)), int(rng.integers(0, n_nodes)))
             for _ in range(int(rng.integers(1, 20)))]
    for s, d in pairs:
        # bias toward repeated ops on the same pair: the order-sensitive
        # cases (add-then-del vs del-then-add) must round-trip exactly
        for _ in range(int(rng.integers(1, 3))):
            if rng.random() < 0.5:
                log.add_edge(s, d)
            else:
                log.remove_edge(s, d)
    n_feat = int(rng.integers(0, 5))
    if n_feat:
        ids = rng.integers(0, n_nodes, n_feat)      # dups: last-writer-wins
        log.update_features(ids, rng.standard_normal((n_feat, D))
                            .astype(np.float32))
    return log


@seed_property()
def test_mutation_log_drain_requeue_roundtrip(seed):
    """(6) drain -> requeue -> drain preserves the pending set AND the
    op order, so the re-applied batch has the same net CSR effect — the
    ``engine.refresh`` failure path loses nothing and reorders nothing."""
    rng = np.random.default_rng(seed)
    n_nodes = 32
    log = _random_log(rng, n_nodes)
    pending = log.pending
    batch1 = log.drain()
    assert log.pending == 0
    log.requeue(batch1)
    assert log.pending == pending
    batch2 = log.drain()
    assert batch2.edge_ops == batch1.edge_ops       # exact order
    f1 = dict(zip(batch1.feat_ids.tolist(), map(bytes, batch1.feat_rows)))
    f2 = dict(zip(batch2.feat_ids.tolist(), map(bytes, batch2.feat_rows)))
    assert f1 == f2
    assert batch1.n_new_nodes == batch2.n_new_nodes

    # same net effect on a real CSR
    src, dst = rmat_edges(n_nodes, n_nodes * 4, seed=seed % 1000)
    g = csr_from_edges(src, dst, n_nodes)
    g1 = apply_edge_mutations(g, batch1)
    g2 = apply_edge_mutations(g, batch2)
    np.testing.assert_array_equal(g1.indptr, g2.indptr)
    for v in range(n_nodes):
        assert sorted(g1.neighbors(v)) == sorted(g2.neighbors(v)), v


@seed_property()
def test_reverse_index_splice_equals_rebuild(seed):
    """(7) incremental reverse-index maintenance: splicing only the
    resampled rows' old/new entries equals the O(N*F) rebuild, bitwise,
    across a chain of random edge mutations."""
    from repro.core.sampler import sample_layer_graphs
    from repro.gnnserve import (build_reverse_index, resample_rows,
                                splice_reverse_index)
    rng = np.random.default_rng(seed)
    n = 48
    src, dst = rmat_edges(n, n * 6, seed=seed % 997)
    g = csr_from_edges(src, dst, n)
    lgs = sample_layer_graphs(g, fanout=3, n_layers=2, seed=seed % 13)
    rev = [build_reverse_index(lg) for lg in lgs]
    gm = g
    for _ in range(3):
        log = MutationLog()
        k = int(rng.integers(1, 8))
        log.add_edges(rng.integers(0, n, k), rng.integers(0, n, k))
        if rng.random() < 0.7:
            pick = rng.choice(src.size, int(rng.integers(1, 5)),
                              replace=False)
            log.remove_edges(src[pick], dst[pick])   # may be absent: noop
        batch = log.drain()
        gm = apply_edge_mutations(gm, batch)
        rows = batch.affected_dsts()
        old = [(lg.nbr[rows].copy(), lg.mask[rows].copy()) for lg in lgs]
        resample_rows(gm, lgs, rows, seed=1)
        for l, lg in enumerate(lgs):
            rev[l] = splice_reverse_index(rev[l], rows, old[l][0],
                                          old[l][1], lg.nbr[rows],
                                          lg.mask[rows])
            fresh = build_reverse_index(lg)
            np.testing.assert_array_equal(rev[l].indptr, fresh.indptr)
            np.testing.assert_array_equal(rev[l].rows, fresh.rows)
