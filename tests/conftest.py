"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device; the
multi-device distributed checks run in subprocesses (tests/helpers)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def small_graph():
    from repro.core.graph import csr_from_edges, rmat_edges
    src, dst = rmat_edges(256, 2048, seed=7)
    return csr_from_edges(src, dst, 256)


@pytest.fixture(scope="session")
def layer_graphs(small_graph):
    from repro.core.sampler import sample_layer_graphs
    return sample_layer_graphs(small_graph, fanout=8, n_layers=3, seed=3)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
