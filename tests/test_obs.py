"""The obs layer: deterministic-clock span nesting/ordering, the no-op
zero-allocation guarantee, exporter golden files, the QoS stats key-drift
guard, trace validation, and the bitwise proof that instrumentation never
changes inference outputs (ref + pallas)."""
import json
import sys

import numpy as np
import pytest

from repro import obs
from repro.api import DealConfig, GraphSpec, ModelSpec, QoSSpec, Session
from repro.obs import compat
from repro.obs.validate import validate_trace


def _tel():
    return obs.Telemetry(enabled=True, clock=obs.FakeClock(0, 1000))


# ----------------------------------------------------------------------
# spans: nesting, ordering, deterministic clock
# ----------------------------------------------------------------------

def test_span_records_name_duration_depth():
    tel = _tel()
    with tel.span("a"):
        pass
    # FakeClock(step=1000): enter + exit = 2 reads -> dur 1000ns
    (name, t0, dur, depth, attrs), = tel.tracer.events
    assert (name, t0, dur, depth, attrs) == ("a", 0, 1000, 0, None)


def test_span_nesting_depths_and_order():
    tel = _tel()
    with tel.span("outer"):
        with tel.span("inner1"):
            pass
        with tel.span("inner2") as sp:
            sp.set(rows=7)
    # recorded at EXIT: children first, parent last
    names = [e[0] for e in tel.tracer.events_in_order()]
    assert names == ["inner1", "inner2", "outer"]
    depths = {e[0]: e[3] for e in tel.tracer.events}
    assert depths == {"outer": 0, "inner1": 1, "inner2": 1}
    attrs = {e[0]: e[4] for e in tel.tracer.events}
    assert attrs["inner2"] == {"rows": 7}
    # parent's interval contains the children's
    ev = {e[0]: e for e in tel.tracer.events}
    for child in ("inner1", "inner2"):
        assert ev["outer"][1] <= ev[child][1]
        assert (ev[child][1] + ev[child][2]
                <= ev["outer"][1] + ev["outer"][2])


def test_span_ring_buffer_drops_oldest():
    tel = obs.Telemetry(enabled=True, clock=obs.FakeClock(0, 1000),
                        capacity=3)
    for i in range(5):
        with tel.span(f"s{i}"):
            pass
    assert tel.tracer.n_dropped == 2
    assert [e[0] for e in tel.tracer.events_in_order()] == \
        ["s2", "s3", "s4"]


def test_span_feeds_duration_histogram_with_executor_attribution():
    tel = _tel()
    with tel.span("ops.spmm") as sp:
        sp.set(executor="pallas")
    d = tel.metrics.to_dict()
    assert d["ops.spmm_ms.count"] == 1
    assert d["ops.spmm.pallas_ms.count"] == 1
    assert d["ops.spmm_ms.sum"] == pytest.approx(1e-3)   # 1000ns


def test_coverage_interval_union():
    tel = _tel()
    clk = tel.tracer.clock
    with tel.span("a"):        # [0, 1000]
        pass
    clk.advance(8000)          # gap [2000, 10000]
    with tel.span("b"):        # [10000, 11000]
        pass
    lo, hi = tel.tracer.window_ns()
    assert (lo, hi) == (0, 11000)
    assert tel.tracer.covered_ns() == 2000
    assert tel.tracer.coverage() == pytest.approx(2000 / 11000)


def test_use_scopes_and_restores():
    tel = _tel()
    assert not obs.enabled()
    with obs.use(tel):
        assert obs.enabled() and obs.current() is tel
        with obs.span("x"):
            pass
        obs.add("c", 2)
    assert not obs.enabled()
    assert [e[0] for e in tel.tracer.events] == ["x"]
    assert tel.metrics.counter("c").value == 2


# ----------------------------------------------------------------------
# no-op mode: falsy spans, zero allocation
# ----------------------------------------------------------------------

def test_disabled_span_is_shared_falsy_noop():
    assert obs.span("anything") is obs.NOOP_SPAN
    assert not obs.NOOP_SPAN
    with obs.span("anything") as sp:
        assert sp is obs.NOOP_SPAN
        sp.set(rows=1)          # swallowed


def test_disabled_hot_path_allocates_nothing():
    def hot():
        with obs.span("x") as sp:
            if sp:
                sp.set(rows=1)
        obs.add("c")
        obs.observe("h", 1.0)
        obs.gauge("g", 2.0)

    hot()                       # warm any lazy interpreter state
    deltas = []
    for _ in range(5):
        before = sys.getallocatedblocks()
        hot()
        deltas.append(sys.getallocatedblocks() - before)
    # min over trials: unrelated interpreter churn can add blocks in
    # some trials, but a true no-op must manage zero in at least one
    assert min(deltas) <= 0


# ----------------------------------------------------------------------
# exporters: golden files under the deterministic clock
# ----------------------------------------------------------------------

def _golden_tel():
    tel = _tel()
    with tel.span("serve.step"):
        with tel.span("store.gather") as sp:
            sp.set(rows=4, level=1)
    tel.add("store.evictions", 2)
    tel.observe("serve.queue_wait_ms", 1.5)
    tel.observe("serve.queue_wait_ms", 2.5)
    return tel


def test_chrome_trace_golden(tmp_path):
    tel = _golden_tel()
    doc = obs.dump_chrome_trace(tel.tracer, tmp_path / "t.json",
                                tel.metrics, process_name="deal.test")
    assert doc == json.loads((tmp_path / "t.json").read_text())
    assert doc["displayTimeUnit"] == "ms"
    meta, gather, step = doc["traceEvents"]
    assert meta == {"name": "process_name", "ph": "M", "pid": 0,
                    "tid": 0, "args": {"name": "deal.test"}}
    # clock reads: step-enter(0) gather-enter(1000) gather-exit(2000)
    # step-exit(3000); ts/dur in us
    assert gather == {"name": "store.gather", "cat": "store", "ph": "X",
                      "ts": 1.0, "dur": 1.0, "pid": 0, "tid": 0,
                      "args": {"rows": 4, "level": 1, "depth": 1}}
    assert step == {"name": "serve.step", "cat": "serve", "ph": "X",
                    "ts": 0.0, "dur": 3.0, "pid": 0, "tid": 0,
                    "args": {"depth": 0}}
    assert doc["deal_metrics"]["store.evictions"] == 2
    assert doc["deal_metrics"]["serve.queue_wait_ms.count"] == 2


def test_prometheus_text_golden():
    tel = _golden_tel()
    text = obs.prometheus_text(tel.metrics)
    assert "# TYPE deal_store_evictions counter\n" \
           "deal_store_evictions 2" in text
    assert "# TYPE deal_serve_queue_wait_ms summary" in text
    assert 'deal_serve_queue_wait_ms{quantile="0.5"} 1.5' in text
    assert 'deal_serve_queue_wait_ms{quantile="0.95"} 2.5' in text
    assert "deal_serve_queue_wait_ms_sum 4" in text
    assert "deal_serve_queue_wait_ms_count 2" in text
    # span-derived histograms ride along, dots sanitized
    assert "deal_serve_step_ms_count 1" in text


def test_metrics_registry_strict_typing():
    tel = _tel()
    tel.add("x", 1)
    with pytest.raises(TypeError, match="counter"):
        tel.metrics.histogram("x")


# ----------------------------------------------------------------------
# trace validation (the CI smoke gate)
# ----------------------------------------------------------------------

def test_validate_trace_accepts_golden():
    tel = _golden_tel()
    doc = obs.chrome_trace(tel.tracer, tel.metrics)
    problems, summary = validate_trace(doc, min_coverage=0.9,
                                       require_cats=("serve", "store"))
    assert problems == []
    assert summary["n_spans"] == 2
    assert summary["coverage"] == pytest.approx(1.0)


def test_validate_trace_rejects_bad_docs():
    assert validate_trace({"traceEvents": "nope"})[0]
    bad_event = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": -1, "dur": 2,
         "pid": 0, "tid": 0}]}
    assert any("ts" in p for p in validate_trace(bad_event)[0])
    missing_cat = obs.chrome_trace(_golden_tel().tracer)
    problems, _ = validate_trace(missing_cat, require_cats=("ops",))
    assert any("ops" in p for p in problems)


# ----------------------------------------------------------------------
# stats unification: compat aliases + the QoS key-drift guard
# ----------------------------------------------------------------------

def test_qos_stats_contract_matches_consumers():
    """bench_qos.py and serve_embeddings.drive read these tenant fields
    — QoSScheduler.stats() must keep emitting every one (this is the
    key-drift guard), and the compat map must translate each."""
    from repro.gnnserve.qos import (QoSScheduler, TenantRegistry,
                                    TenantSpec)
    reg = TenantRegistry([TenantSpec(name="t0", priority=1.0,
                                     slot_quota=1, rate=0,
                                     staleness_slo=8)])
    stats = QoSScheduler(reg, batch_slots=2, rows_per_step=8).stats()
    tenant = stats["t0"]
    missing = compat.TENANT_CONSUMED_FIELDS - set(tenant)
    assert not missing, f"QoS stats dropped consumed keys: {missing}"
    untranslated = set(tenant) - set(compat.TENANT_MAP.values())
    assert not untranslated, \
        f"tenant stats keys missing a unified alias: {untranslated}"


def test_unified_from_engine_translates_all_shapes():
    engine_stats = {"n_served": 3, "n_gather_steps": 5,
                    "store_n_evictions": 2, "store_hits": 10,
                    "store_recompute_s": 0.25,
                    "tenants": {"batch": {"wait_p95_steps": 4.0,
                                          "n_preemptions": 1}}}
    uni = compat.unified_from_engine(engine_stats)
    assert uni["serve.queries"] == 3
    assert uni["store.evictions"] == 2
    assert uni["store.recompute_ms"] == pytest.approx(250.0)
    assert uni["qos.tenant.batch.p95_wait_steps"] == 4.0
    assert uni["qos.tenant.batch.preemptions"] == 1


# ----------------------------------------------------------------------
# end-to-end: Session telemetry + the bitwise neutrality proof
# ----------------------------------------------------------------------

def _small_cfg(executor="ref", telemetry=False):
    cfg = DealConfig(
        graph=GraphSpec(dataset="rmat", n_nodes=256, avg_degree=8,
                        fanout=4),
        model=ModelSpec(name="gcn", n_layers=2, d_feature=16),
        qos=QoSSpec(staleness_bound=8))
    cfg.executor.name = executor
    cfg.telemetry.enabled = telemetry
    return cfg


@pytest.mark.parametrize("executor", ["ref", "pallas"])
def test_instrumentation_is_bitwise_neutral(executor):
    with Session.build(_small_cfg(executor)) as off:
        H_off = off.infer_all().copy()
    with Session.build(_small_cfg(executor, telemetry=True)) as on:
        H_on = on.infer_all().copy()
        assert len(on.telemetry.tracer.events) > 0
    assert H_off.dtype == H_on.dtype
    assert np.array_equal(H_off, H_on)      # bitwise, not approx


def test_session_stats_surfaces_plan_cache_and_frontiers():
    with Session.build(_small_cfg(telemetry=True)) as s:
        s.serve()
        s.apply_mutations().add_edges(np.array([1, 2]), np.array([3, 4]))
        s.refresh()
        st = s.stats()
    assert {"hits", "misses"} <= set(st["plan_cache"])
    m = st["metrics"]
    assert "plan_cache.hits" in m and "plan_cache.misses" in m
    assert "delta.frontier_rows.layer0" in m
    assert m["serve.refreshes"] == 1
    # live telemetry histograms merged on top of the derived aliases
    assert m["refresh.layer_ms.count"] >= 1


def test_session_dump_trace_is_valid_and_covering(tmp_path):
    with Session.build(_small_cfg(telemetry=True)) as s:
        s.infer_all()
        s.serve()
        doc = s.dump_trace(tmp_path / "trace.json")
        assert s.prometheus_text().startswith("# TYPE")
    problems, summary = validate_trace(
        doc, min_coverage=0.9,
        require_cats=("construct", "sample", "featprep", "ops", "serve"))
    assert problems == []
    assert summary["coverage"] >= 0.9


def test_dump_trace_without_telemetry_raises():
    from repro.api import ConfigError
    with Session.build(_small_cfg()) as s:
        assert s.telemetry is None
        with pytest.raises(ConfigError, match="telemetry"):
            s.dump_trace("/tmp/never.json")
        assert s.prometheus_text() == ""


def test_session_installs_and_restores_current_telemetry():
    assert obs.current() is obs.DISABLED
    with Session.build(_small_cfg(telemetry=True)) as s:
        assert obs.current() is s.telemetry
    assert obs.current() is obs.DISABLED


def test_telemetry_spec_roundtrip_and_validation():
    from repro.api import ConfigError
    cfg = _small_cfg(telemetry=True)
    cfg.telemetry.clock = "fake"
    cfg2 = DealConfig.from_json(cfg.to_json())
    assert cfg2.telemetry == cfg.telemetry
    tel = cfg2.telemetry.build()
    assert isinstance(tel.tracer.clock, obs.FakeClock)
    cfg.telemetry.clock = "sundial"
    with pytest.raises(ConfigError, match="clock"):
        cfg.validate()
