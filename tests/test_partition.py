"""Partitioner invariants: every sampled edge lands in exactly one group,
send/recv sets are consistent, comm volumes ordered as the paper claims."""
import numpy as np
import pytest

from repro.core.partition import build_plan, comm_volume


@pytest.mark.parametrize("P,M", [(2, 1), (4, 2), (8, 2)])
def test_edge_coverage(P, M, layer_graphs):
    plan = build_plan(layer_graphs, P, M)
    for li, lp in enumerate(plan.layers):
        lg = layer_graphs[li]
        n_local = lp.n_local
        covered = np.zeros(lg.nbr.shape, bool)
        for p in range(P):
            for k in range(P):
                m = lp.edge_mask[p, k]
                d = lp.edge_dst[p, k][m] + p * n_local
                s = lp.edge_slot[p, k][m]
                assert not covered[d, s].any(), "edge in two groups"
                covered[d, s] = True
        assert np.array_equal(covered, lg.mask)


@pytest.mark.parametrize("P", [2, 4])
def test_recv_buffer_resolves_to_right_rows(P, layer_graphs):
    """edge_pos into the (sent) request buffer must reproduce the global
    neighbor id."""
    plan = build_plan(layer_graphs, P, 1)
    n_local = plan.layers[0].n_local
    for li, lp in enumerate(plan.layers):
        lg = layer_graphs[li]
        for p in range(P):
            for k in range(1, P):
                q = (p + k) % P
                # rows sender q ships to p at step k:
                cnt = lp.send_count[q, k]
                buf_global = lp.send_local[q, k][:cnt] + q * n_local
                m = lp.edge_mask[p, k]
                got = buf_global[lp.edge_pos[p, k][m]]
                want = lg.nbr[lp.edge_dst[p, k][m] + p * n_local,
                              lp.edge_slot[p, k][m]]
                assert np.array_equal(got, want)


def test_unique_rows_fewer_than_edges(layer_graphs):
    """DEAL's win: requested unique rows <= duplicated per-edge rows."""
    plan = build_plan(layer_graphs, 4, 2)
    vols = comm_volume(plan, d_feature=64)
    for v in vols.values():
        assert v["unique_rows"] <= v["duplicated_edge_rows"]
        assert v["deal_feature_exchange_B"] <= v["graph_exchange_B"]


def test_bad_partition_rejected(layer_graphs):
    with pytest.raises(AssertionError):
        build_plan(layer_graphs, 7, 1)   # 256 % 7 != 0


def test_subset_plan_cache_hits_and_invalidation(layer_graphs):
    """Repeated recompute of the same hot frontier must reuse the cached
    plan (signature: sorted row ids + partition geometry); an in-place
    resample must invalidate it."""
    import copy

    from repro.core.partition import (SUBSET_PLAN_CACHE,
                                      build_subset_plan,
                                      build_subset_plan_cached,
                                      invalidate_subset_plans)
    lg = copy.deepcopy(layer_graphs[0])
    rows = np.arange(0, lg.n_nodes, 3, dtype=np.int64)
    before = dict(SUBSET_PLAN_CACHE)
    p1 = build_subset_plan_cached(lg, rows, 4)
    assert SUBSET_PLAN_CACHE["misses"] == before["misses"] + 1
    p2 = build_subset_plan_cached(lg, rows, 4)
    assert SUBSET_PLAN_CACHE["hits"] == before["hits"] + 1
    assert p2 is p1
    # cached plan is the real plan
    fresh = build_subset_plan(lg, rows, 4)
    np.testing.assert_array_equal(p1.row_ids, fresh.row_ids)
    np.testing.assert_array_equal(p1.edge_pos, fresh.edge_pos)
    np.testing.assert_array_equal(p1.send_local, fresh.send_local)

    # different frontier or geometry -> different cache slot
    assert build_subset_plan_cached(lg, rows[:-1], 4) is not p1
    assert build_subset_plan_cached(lg, rows, 2) is not p1
    assert build_subset_plan_cached(lg, rows, 4) is p1   # p1 still cached

    # in-place mutation (what resample_rows does) must invalidate
    invalidate_subset_plans(lg)
    assert build_subset_plan_cached(lg, rows, 4) is not p1


def test_resample_rows_invalidates_subset_plans(layer_graphs, small_graph):
    """The delta engine's resample path must not serve stale plans."""
    import copy

    from repro.core.partition import build_subset_plan_cached
    from repro.gnnserve import resample_rows
    lgs = [copy.deepcopy(lg) for lg in layer_graphs]
    rows = np.arange(0, lgs[0].n_nodes, 2, dtype=np.int64)
    p1 = build_subset_plan_cached(lgs[0], rows, 4)
    resample_rows(small_graph, lgs, rows[:5], seed=9)
    p2 = build_subset_plan_cached(lgs[0], rows, 4)
    assert p2 is not p1
