"""Partitioner invariants: every sampled edge lands in exactly one group,
send/recv sets are consistent, comm volumes ordered as the paper claims."""
import numpy as np
import pytest

from repro.core.partition import build_plan, comm_volume


@pytest.mark.parametrize("P,M", [(2, 1), (4, 2), (8, 2)])
def test_edge_coverage(P, M, layer_graphs):
    plan = build_plan(layer_graphs, P, M)
    for li, lp in enumerate(plan.layers):
        lg = layer_graphs[li]
        n_local = lp.n_local
        covered = np.zeros(lg.nbr.shape, bool)
        for p in range(P):
            for k in range(P):
                m = lp.edge_mask[p, k]
                d = lp.edge_dst[p, k][m] + p * n_local
                s = lp.edge_slot[p, k][m]
                assert not covered[d, s].any(), "edge in two groups"
                covered[d, s] = True
        assert np.array_equal(covered, lg.mask)


@pytest.mark.parametrize("P", [2, 4])
def test_recv_buffer_resolves_to_right_rows(P, layer_graphs):
    """edge_pos into the (sent) request buffer must reproduce the global
    neighbor id."""
    plan = build_plan(layer_graphs, P, 1)
    n_local = plan.layers[0].n_local
    for li, lp in enumerate(plan.layers):
        lg = layer_graphs[li]
        for p in range(P):
            for k in range(1, P):
                q = (p + k) % P
                # rows sender q ships to p at step k:
                cnt = lp.send_count[q, k]
                buf_global = lp.send_local[q, k][:cnt] + q * n_local
                m = lp.edge_mask[p, k]
                got = buf_global[lp.edge_pos[p, k][m]]
                want = lg.nbr[lp.edge_dst[p, k][m] + p * n_local,
                              lp.edge_slot[p, k][m]]
                assert np.array_equal(got, want)


def test_unique_rows_fewer_than_edges(layer_graphs):
    """DEAL's win: requested unique rows <= duplicated per-edge rows."""
    plan = build_plan(layer_graphs, 4, 2)
    vols = comm_volume(plan, d_feature=64)
    for v in vols.values():
        assert v["unique_rows"] <= v["duplicated_edge_rows"]
        assert v["deal_feature_exchange_B"] <= v["graph_exchange_B"]


def test_bad_partition_rejected(layer_graphs):
    with pytest.raises(AssertionError):
        build_plan(layer_graphs, 7, 1)   # 256 % 7 != 0
