"""CSR construction: single-machine vs distributed builder, RMAT."""
import numpy as np
import pytest

from repro.core.graph import (csr_from_edges, csr_from_edges_distributed,
                              make_dataset, rmat_edges)


def test_csr_correct():
    src = np.array([1, 2, 0, 3, 3, 1])
    dst = np.array([0, 0, 1, 1, 2, 3])
    g = csr_from_edges(src, dst, 4)
    assert sorted(g.neighbors(0).tolist()) == [1, 2]
    assert sorted(g.neighbors(1).tolist()) == [0, 3]
    assert g.neighbors(2).tolist() == [3]
    assert g.neighbors(3).tolist() == [1]
    assert g.n_edges == 6


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_distributed_matches_single(workers):
    src, dst = rmat_edges(512, 4096, seed=3)
    g1 = csr_from_edges(src, dst, 512)
    g2, stats = csr_from_edges_distributed(src, dst, 512,
                                           n_workers=workers)
    assert np.array_equal(g1.indptr, g2.indptr)
    for v in range(512):   # per-row multisets must agree
        assert sorted(g1.neighbors(v).tolist()) == \
            sorted(g2.neighbors(v).tolist())
    if workers > 1:
        assert stats["exchanged_bytes"] > 0


def test_rmat_shape_and_skew():
    src, dst = rmat_edges(1024, 20480, seed=0)
    assert src.shape == (20480,) and dst.max() < 1024
    deg = np.bincount(dst, minlength=1024)
    # power-law-ish: the hottest node way above the mean
    assert deg.max() > 5 * deg.mean()


def test_datasets():
    for name in ("ogbn-products", "social-spammer", "ogbn-papers100M"):
        src, dst, n = make_dataset(name, scale=0.25)
        assert n > 0 and src.shape == dst.shape
