"""Incremental node onboarding: tail partitions in the store, delta
refresh over grown layer graphs, fold at the next full epoch, and the
failure rollback (ROADMAP item "gnnserve incremental node onboarding")."""
import copy

import numpy as np
import pytest

N, D, LAYERS, FANOUT = 256, 16, 3, 4


def _world(onboarding="tail", budget_rows=0, executor="ref", seed=0,
           tenants=None, chunk_rows=0):
    import jax

    from repro.core.gnn_models import init_gcn
    from repro.core.graph import csr_from_edges, rmat_edges
    from repro.core.sampler import sample_layer_graphs
    from repro.gnnserve import (DeltaReinference, EmbeddingServeEngine,
                                attach_recompute, store_from_inference)
    src, dst = rmat_edges(N, N * 8, seed=seed)
    g = csr_from_edges(src, dst, N)
    lgs = sample_layer_graphs(g, fanout=FANOUT, n_layers=LAYERS, seed=seed)
    X = np.random.default_rng(seed).standard_normal((N, D),
                                                    dtype=np.float32)
    params = init_gcn(jax.random.PRNGKey(seed), [D] * (LAYERS + 1))
    ri = DeltaReinference([copy.deepcopy(l) for l in lgs], "gcn", params,
                          executor=executor)
    store = store_from_inference(X, ri.full_levels(X)[1:], n_shards=4,
                                 budget_rows=budget_rows or None,
                                 onboarding=onboarding)
    if budget_rows:
        attach_recompute(store, ri)
    eng = EmbeddingServeEngine(store, ri, g, staleness_bound=4,
                               rows_per_step=64, tenants=tenants,
                               refresh_chunk_rows=chunk_rows)
    return eng, params


def _onboard(eng, k, seed=1):
    """k new nodes with features, wired into the graph both ways."""
    rng = np.random.default_rng(seed)
    n = eng.store.n_nodes
    rows = rng.standard_normal((k, D), dtype=np.float32)
    eng.mutate().add_nodes(k, rows)
    new = np.arange(n, n + k)
    eng.mutate().add_edges(rng.integers(0, n, 2 * k), np.repeat(new, 2))
    eng.mutate().add_edges(new, rng.integers(0, n, k))
    return rows


def _oracle_levels(eng, params, executor="ref"):
    """A from-scratch full epoch on the engine's CURRENT layer graphs —
    the bitwise reference for every onboarded store."""
    from repro.gnnserve import DeltaReinference
    n = eng.store.n_nodes
    X = eng.store.lookup(np.arange(n), 0)
    return DeltaReinference(eng.reinfer.layer_graphs, "gcn", params,
                            executor=executor).full_levels(X)


def test_refuses_without_tail_onboarding():
    eng, _ = _world(onboarding="none")
    eng.mutate().add_nodes(2)
    with pytest.raises(NotImplementedError):
        eng.refresh()
    assert eng.log.pending > 0          # nothing was discarded
    assert eng.store.n_nodes == N


@pytest.mark.parametrize("executor", ["ref", "pallas"])
def test_tail_onboarding_bitwise_equals_full_epoch(executor):
    eng, params = _world(executor=executor)
    rows = _onboard(eng, 3)
    stats = eng.refresh()
    assert stats["n_onboarded"] == 3
    st = eng.store
    assert st.n_nodes == N + 3
    assert st.n_shards == 5 and st.n_tail_shards == 1
    assert np.array_equal(st.lookup(np.arange(N, N + 3), 0), rows)
    oracle = _oracle_levels(eng, params, executor)
    all_ids = np.arange(N + 3)
    for lvl in range(1, LAYERS + 1):
        np.testing.assert_array_equal(st.lookup(all_ids, lvl),
                                      oracle[lvl])


def test_serving_and_repeated_onboarding():
    from repro.gnnserve import Query
    eng, params = _world()
    _onboard(eng, 2, seed=1)
    eng.refresh()
    _onboard(eng, 3, seed=2)            # a second batch => second tail
    eng.refresh()
    st = eng.store
    assert st.n_nodes == N + 5 and st.n_tail_shards == 2
    q = Query(uid=0, node_ids=np.arange(N - 2, N + 5))
    eng.submit(q)
    eng.run()
    oracle = _oracle_levels(eng, params)
    np.testing.assert_array_equal(q.out, oracle[-1][N - 2:N + 5])


def test_full_epoch_folds_tail_bitwise():
    eng, params = _world()
    _onboard(eng, 4)
    eng.refresh()
    oracle = _oracle_levels(eng, params)
    fold = eng.full_epoch()
    st = eng.store
    assert st.n_tail_shards == 0 and st.n_shards == 4
    np.testing.assert_array_equal(
        st.bounds, np.linspace(0, N + 4, 5).astype(np.int64))
    assert fold["version"] == st.version
    all_ids = np.arange(N + 4)
    for lvl in range(1, LAYERS + 1):
        np.testing.assert_array_equal(st.lookup(all_ids, lvl),
                                      oracle[lvl])


def test_full_epoch_drains_pending_mutations_first():
    eng, params = _world()
    _onboard(eng, 2)
    eng.full_epoch()                    # refresh + fold in one call
    assert eng.store.n_nodes == N + 2 and eng.store.n_tail_shards == 0
    assert eng.log.pending == 0


def test_full_epoch_folds_node_adds_without_tail_onboarding():
    """full_epoch IS the re-partition event: pending node adds fold
    there even on an onboarding=\"none\" store (where refresh refuses)."""
    eng, params = _world(onboarding="none")
    _onboard(eng, 3)
    with pytest.raises(NotImplementedError):
        eng.refresh()                   # the delta path still refuses
    eng.full_epoch()
    st = eng.store
    assert st.n_nodes == N + 3 and st.n_tail_shards == 0
    assert eng.log.pending == 0
    oracle = _oracle_levels(eng, params)
    np.testing.assert_array_equal(st.lookup(np.arange(N + 3), -1),
                                  oracle[-1])


def test_full_epoch_poisons_swapped_out_store():
    """Snapshots of the pre-fold store must SnapshotMiss on rows they
    never pinned — not silently recompute against layer graphs that
    later refreshes mutate."""
    from repro.gnnserve import SnapshotMiss
    eng, _ = _world(budget_rows=N // 4)     # most shards non-resident
    old = eng.store
    snap = old.snapshot()
    eng.full_epoch()
    assert eng.store is not old and old.version != snap.version
    with pytest.raises(SnapshotMiss):
        snap.lookup(np.arange(N), 1)


def test_onboarding_on_budgeted_store_recomputes_tail():
    eng, params = _world(budget_rows=N // 4)
    rows = _onboard(eng, 3)
    eng.refresh()
    st = eng.store
    # evict the tail shard explicitly: recompute-on-miss must rebuild
    # the onboarded rows from their (pinned) tail features
    st.evict(1, st.n_shards - 1)
    oracle = _oracle_levels(eng, params)
    all_ids = np.arange(N + 3)
    for lvl in range(1, LAYERS + 1):
        np.testing.assert_array_equal(st.lookup(all_ids, lvl),
                                      oracle[lvl])
    assert st.rows_recomputed > 0


def test_failed_onboarding_rolls_back_everything():
    eng, _ = _world()
    lg0_rows = eng.reinfer.layer_graphs[0].nbr.shape[0]
    eng.mutate().add_nodes(2)
    # an edge whose SOURCE is far beyond even the grown node range makes
    # apply_edge_mutations fail after the tail was appended
    eng.mutate().add_edges(np.array([N + 100]), np.array([0]))
    pending = eng.log.pending
    with pytest.raises(AssertionError):
        eng.refresh()
    st = eng.store
    assert st.n_nodes == N and st.n_shards == 4 and st.n_tail_shards == 0
    assert eng.reinfer.layer_graphs[0].nbr.shape[0] == lg0_rows
    assert eng.log.pending == pending   # requeued, nothing lost
    assert eng.graph.n_nodes == N


def test_bad_feature_width_rolls_back_cleanly():
    eng, _ = _world()
    eng.mutate().add_edges(np.array([1]), np.array([2]))   # good op
    eng.mutate().add_nodes(2, np.zeros((2, D + 5), np.float32))
    pending = eng.log.pending
    with pytest.raises(AssertionError):
        eng.refresh()
    st = eng.store
    assert st.n_nodes == N and st.n_shards == 4 and st.n_tail_shards == 0
    assert eng.reinfer.layer_graphs[0].nbr.shape[0] == N
    assert eng.log.pending == pending   # the good edge op survived too


def test_add_nodes_rows_survive_drain_requeue():
    from repro.gnnserve import MutationLog
    log = MutationLog()
    rows = np.random.default_rng(0).standard_normal((3, D),
                                                    dtype=np.float32)
    log.add_nodes(2, rows[:2])
    log.add_nodes(1, rows[2:])
    batch = log.drain()
    assert batch.n_new_nodes == 3
    np.testing.assert_array_equal(batch.new_node_rows, rows)
    log.requeue(batch)
    again = log.drain()
    assert again.n_new_nodes == 3
    np.testing.assert_array_equal(again.new_node_rows, rows)


def test_add_nodes_mixed_rows_and_zero_fill():
    from repro.gnnserve import MutationLog
    log = MutationLog()
    rows = np.ones((2, D), np.float32)
    log.add_nodes(1)                    # no features: zero-filled
    log.add_nodes(2, rows)
    batch = log.drain()
    assert batch.new_node_rows.shape == (3, D)
    np.testing.assert_array_equal(batch.new_node_rows[0],
                                  np.zeros(D, np.float32))
    np.testing.assert_array_equal(batch.new_node_rows[1:], rows)


def test_session_exposes_onboarding():
    import dataclasses

    from repro.api import (DealConfig, GraphSpec, ModelSpec, QoSSpec,
                           Session, StoreSpec)
    cfg = DealConfig(
        graph=GraphSpec(dataset="rmat", n_nodes=N, avg_degree=8,
                        fanout=FANOUT),
        model=ModelSpec(name="gcn", n_layers=2, d_feature=D),
        store=StoreSpec(onboarding="tail"),
        qos=QoSSpec(staleness_bound=4))
    s = Session.build(cfg)
    eng = s.serve()
    _onboard(eng, 2)
    s.refresh()
    assert s.store.n_nodes == N + 2 and s.store.n_tail_shards == 1
    before = s.store
    s.full_epoch()
    assert s.store is not before        # the fold rebuilt the store
    assert s.store.n_tail_shards == 0


def _qos_world(tenants="ui:4:2:0:2,batch:1:1:0:1000", chunk_rows=0,
               seed=0):
    """A tail-onboarding engine under QoS: strict ui tenant (forces
    refreshes), loose batch tenant (its view lags behind appends)."""
    from repro.gnnserve import parse_tenants
    return _world(seed=seed, tenants=parse_tenants(tenants),
                  chunk_rows=chunk_rows)


def test_qos_onboarding_lagged_view_keeps_pre_append_epoch():
    """Node adds under QoS: the refresh onboards the tail, but only due
    tenants' views advance — a loose tenant's old-id reads keep their
    pre-append epoch bits at their pre-append version."""
    from repro.gnnserve import Query
    eng, params = _qos_world()
    pre = eng.store.lookup(np.arange(N), -1).copy()
    _onboard(eng, 3)
    rng = np.random.default_rng(11)
    batch_qs = []
    for tick in range(4):
        qb = Query(uid=tick, node_ids=rng.integers(0, N, 48),
                   tenant="batch")
        eng.submit(qb)
        batch_qs.append(qb)
        eng.submit(Query(uid=100 + tick,
                         node_ids=rng.integers(0, N, 16), tenant="ui"))
        eng.run()
    assert eng.n_onboarded == 3 and eng.store.n_nodes == N + 3
    ts = eng.stats()["tenants"]
    assert ts["ui"]["view_version"] == eng.store.version
    assert ts["batch"]["view_version"] < eng.store.version
    for q in batch_qs:                  # old ids: pre-append bits, v0
        assert q.done and q.served_version == 0
        np.testing.assert_array_equal(q.out, pre[q.node_ids])


def test_qos_onboarding_tail_ids_serve_at_append_version():
    """A lagged view predates the tail append: queries touching tail
    ids serve on the CURRENT epoch (fresher than the SLO requires,
    never staler), counted as a view restart; the tenant's old-id
    queries keep their pre-append bits."""
    from repro.gnnserve import Query
    eng, params = _qos_world()
    _onboard(eng, 3)
    rng = np.random.default_rng(13)
    eng.submit(Query(uid=0, node_ids=rng.integers(0, N, 16),
                     tenant="ui"))
    eng.run()                           # ui's SLO forced the onboarding
    assert eng.store.n_nodes == N + 3
    qt = Query(uid=1, node_ids=np.arange(N - 2, N + 3), tenant="batch")
    eng.submit(qt)
    eng.run()
    assert qt.done and qt.served_version == eng.store.version
    oracle = _oracle_levels(eng, params)
    np.testing.assert_array_equal(qt.out, oracle[-1][N - 2:N + 3])
    assert eng.stats()["tenants"]["batch"]["n_view_restarts"] >= 1


def test_qos_full_epoch_folds_tail():
    """full_epoch works under QoS: pending mutations (node adds
    included) drain first, the tail folds back into the main
    partitioning, and tenants keep serving."""
    from repro.gnnserve import Query
    eng, params = _qos_world()
    _onboard(eng, 4)
    eng.full_epoch()
    st = eng.store
    assert st.n_nodes == N + 4 and st.n_tail_shards == 0
    assert eng.log.pending == 0
    oracle = _oracle_levels(eng, params)
    q = Query(uid=0, node_ids=np.arange(N, N + 4), tenant="ui")
    eng.submit(q)
    eng.run()
    np.testing.assert_array_equal(q.out, oracle[-1][N:N + 4])


def test_qos_engine_still_refuses_without_tail_onboarding():
    """The remaining refusal is the onboarding mode, not QoS: node adds
    on an onboarding=\"none\" store defer to full_epoch as before."""
    from repro.gnnserve import parse_tenants
    eng, _ = _world(onboarding="none",
                    tenants=parse_tenants("ui:1:1:0:4"))
    eng.mutate().add_nodes(1)
    with pytest.raises(NotImplementedError):
        eng.refresh()
    assert eng.log.pending > 0          # nothing was discarded


def test_session_onboarding_under_qos():
    """The exact configuration the engine used to refuse: tail
    onboarding + tenants, through the Session facade."""
    from repro.api import (DealConfig, GraphSpec, ModelSpec, QoSSpec,
                           Session, StoreSpec, tenants_from_string)
    cfg = DealConfig(
        graph=GraphSpec(dataset="rmat", n_nodes=N, avg_degree=8,
                        fanout=FANOUT),
        model=ModelSpec(name="gcn", n_layers=2, d_feature=D),
        store=StoreSpec(onboarding="tail"),
        qos=QoSSpec(tenants=tenants_from_string("ui:1:1:0:4")))
    with Session.build(cfg) as s:
        eng = s.serve()
        eng.mutate().add_nodes(2)
        stats = eng.refresh()
        assert stats["n_onboarded"] == 2
        assert eng.store.n_nodes == N + 2
        fold = s.full_epoch()
        assert fold["version"] == eng.store.version
        assert s.store.n_tail_shards == 0