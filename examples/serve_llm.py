"""Serve a small model with batched requests: vectorized batched prefill +
continuous-batching decode through the ServeEngine.

  PYTHONPATH=src python examples/serve_llm.py [--arch qwen2.5-14b]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.step import prefill_step

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # --- vectorized batched prefill (the prefill_32k dry-run path) ---
    B, S = 4, 32
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    t0 = time.time()
    logits, cache = jax.jit(lambda p, b: prefill_step(cfg, p, b))(
        params, {"tokens": tokens})
    jax.block_until_ready(logits)
    print(f"batched prefill: {B}x{S} tokens -> last-pos logits "
          f"{logits.shape} in {time.time()-t0:.1f}s (cache filled)")

    # --- continuous-batching decode over ragged requests ---
    eng = ServeEngine(cfg, params, batch_slots=args.slots, max_seq=64)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.integers(3, 12))
        r = Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        plen).astype(np.int32),
                    max_new_tokens=args.max_new)
        reqs.append(r)
        eng.submit(r)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} ragged requests "
          f"({args.slots} slots): {total} tokens, "
          f"{eng.n_decode_steps} decode steps, {total/dt:.1f} tok/s")
    for r in reqs[:4]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
